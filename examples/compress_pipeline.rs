//! End-to-end driver (DESIGN.md "end-to-end validation"): loads the trained
//! opt-mini-m checkpoint + calibration from artifacts/, compresses it with
//! the Table 2 method set in rust, and evaluates perplexity of every
//! variant through the AOT-compiled PJRT scoring program — the full
//! L1 (Pallas kernels inside the HLO) → L2 (JAX-lowered program) →
//! L3 (rust compression + serving runtime) stack in one run.
//!
//! Run: cargo run --release --example compress_pipeline -- [artifacts-dir]

use anyhow::Result;
use latentllm::compress::pipeline::{compress_model, Method};
use latentllm::data::{CalibSet, Corpus};
use latentllm::model::config::mini_by_name;
use latentllm::model::Weights;
use latentllm::reports::TextTable;
use latentllm::runtime::Engine;
use latentllm::{eval, flops};

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let model = "opt-mini-m";
    let cfg = mini_by_name(model).unwrap();
    let engine = Engine::new(&artifacts)?;
    let weights = Weights::load(format!("{artifacts}/model_{model}.ltw"))?;
    let calib = CalibSet::load(format!("{artifacts}/calib_{model}.ltw"),
                               cfg.n_layers)?;
    let corpora: Vec<Corpus> = ["synthwiki", "synthptb", "synthc4"].iter()
        .map(|n| Corpus::load(format!("{artifacts}/corpora.ltw"), n, "test"))
        .collect::<Result<_>>()?;
    let program = format!("score_{model}");
    let eval_ppl = |w: &Weights| -> Result<Vec<f64>> {
        corpora.iter()
            .map(|c| Ok(eval::perplexity(&engine, &program, w, c, 8, 128,
                                         12)?.ppl))
            .collect()
    };

    let mut table = TextTable::new(&["method", "ratio", "synthwiki",
                                     "synthptb", "synthc4", "linear params",
                                     "secs"]);
    let base = eval_ppl(&weights)?;
    table.row(vec!["original".into(), "0%".into(),
                   format!("{:.2}", base[0]), format!("{:.2}", base[1]),
                   format!("{:.2}", base[2]),
                   flops::human(cfg.linear_params() as f64), "-".into()]);

    for method in [Method::Plain, Method::AsvdRootCov, Method::LatentLlm] {
        for ratio in [0.2f64, 0.4] {
            let t0 = std::time::Instant::now();
            let (nw, rep) = compress_model(cfg, &weights, &calib, method,
                                           ratio, 8, 4)?;
            let secs = t0.elapsed().as_secs_f64();
            let ppls = eval_ppl(&nw)?;
            table.row(vec![
                method.label().into(),
                format!("{:.0}%", ratio * 100.0),
                format!("{:.2}", ppls[0]), format!("{:.2}", ppls[1]),
                format!("{:.2}", ppls[2]),
                flops::human(rep.new_linear_params as f64),
                format!("{secs:.1}"),
            ]);
            println!("done: {} @ {:.0}%  ppl {:?}", method.label(),
                     ratio * 100.0, ppls);
        }
    }
    println!("\n{}", table.render());
    println!("expected shape (paper Table 2): plain ≫ rootcov > latentllm,\n\
              all above the original; gaps widen with ratio.");
    Ok(())
}
