//! Tiny std-only HTTP client for the CI http-smoke job: points at a
//! running `latentllm serve --http ADDR`, exercises every endpoint
//! (health, score, plain + streamed completions, metrics), then asks
//! the server to drain via `/admin/shutdown`. Prints one summary line
//! ending in `failed=N` and exits nonzero when N > 0.
//!
//! Run: cargo run --release --example http_client -- 127.0.0.1:PORT

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use latentllm::util::json;

fn main() -> Result<()> {
    let addr = std::env::args().nth(1)
        .context("usage: http_client ADDR (e.g. 127.0.0.1:8080)")?;
    wait_healthy(&addr, Duration::from_secs(30))?;

    let checks: [(&str, fn(&str) -> Result<String>); 5] = [
        ("score", score),
        ("completion", completion),
        ("stream", streamed),
        ("metrics", metrics),
        ("shutdown", shutdown),
    ];
    let mut failed = 0usize;
    for (name, check) in checks {
        match check(&addr) {
            Ok(msg) => println!("  {name}: ok ({msg})"),
            Err(e) => {
                failed += 1;
                println!("  {name}: FAILED ({e:#})");
            }
        }
    }

    println!("http client: 5 checks failed={failed}");
    if failed > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// Send one request (`Connection: close`) and return (status, body with
/// chunked transfer decoded).
fn request(addr: &str, method: &str, path: &str, body: &str)
           -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    s.set_read_timeout(Some(Duration::from_secs(120)))?;
    write!(s, "{method} {path} HTTP/1.1\r\nHost: ci\r\n\
               Connection: close\r\nContent-Length: {}\r\n\r\n{body}",
           body.len())?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).context("read response")?;
    let split = raw.windows(4).position(|w| w == b"\r\n\r\n")
        .context("no header/body split in response")?;
    let head = std::str::from_utf8(&raw[..split])?;
    let status: u16 = head.split_whitespace().nth(1)
        .context("no status code")?.parse()?;
    let chunked = head.lines().any(
        |l| l.to_ascii_lowercase()
            .starts_with("transfer-encoding: chunked"));
    let body = if chunked {
        dechunk(&raw[split + 4..])?
    } else {
        raw[split + 4..].to_vec()
    };
    Ok((status, String::from_utf8(body)?))
}

fn dechunk(raw: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut pos = 0;
    loop {
        let nl = raw[pos..].windows(2).position(|w| w == b"\r\n")
            .context("chunked body missing a size line")?;
        let size = usize::from_str_radix(
            std::str::from_utf8(&raw[pos..pos + nl])?.trim(), 16)
            .context("bad chunk size")?;
        pos += nl + 2;
        if size == 0 {
            return Ok(out);
        }
        if pos + size > raw.len() {
            bail!("truncated chunk");
        }
        out.extend_from_slice(&raw[pos..pos + size]);
        pos += size + 2;
    }
}

fn wait_healthy(addr: &str, budget: Duration) -> Result<()> {
    let t0 = Instant::now();
    loop {
        match request(addr, "GET", "/healthz", "") {
            Ok((200, _)) => return Ok(()),
            Ok((code, _)) if t0.elapsed() > budget => {
                bail!("server still unhealthy ({code}) after {budget:?}")
            }
            Err(e) if t0.elapsed() > budget => {
                bail!("server unreachable after {budget:?}: {e:#}")
            }
            _ => std::thread::sleep(Duration::from_millis(200)),
        }
    }
}

fn score(addr: &str) -> Result<String> {
    let (status, body) = request(addr, "POST", "/v1/score",
                                 "{\"tokens\": [1, 2, 3, 5, 7, 11]}")?;
    if status != 200 {
        bail!("status {status}: {body}");
    }
    let v = json::parse(&body)?;
    let nll = v.get("nll").and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow!("no nll in {body}"))?;
    if !nll.is_finite() {
        bail!("non-finite nll {nll}");
    }
    Ok(format!("nll {nll:.3}"))
}

fn completion(addr: &str) -> Result<String> {
    let (status, body) = request(
        addr, "POST", "/v1/completions",
        "{\"prompt\": [1, 2, 3], \"max_new\": 8}")?;
    if status != 200 {
        bail!("status {status}: {body}");
    }
    let v = json::parse(&body)?;
    let n = v.get("tokens").and_then(|t| t.as_arr())
        .ok_or_else(|| anyhow!("no tokens in {body}"))?.len();
    if n != 8 {
        bail!("wanted 8 tokens, got {n}");
    }
    Ok(format!("{n} tokens"))
}

fn streamed(addr: &str) -> Result<String> {
    let (status, body) = request(
        addr, "POST", "/v1/completions",
        "{\"prompt\": [2, 3, 5], \"max_new\": 8, \"stream\": true}")?;
    if status != 200 {
        bail!("status {status}: {body}");
    }
    let events: Vec<&str> = body.split("\n\n")
        .filter_map(|ev| ev.trim().strip_prefix("data: "))
        .collect();
    if events.last() != Some(&"[DONE]") {
        bail!("stream did not end with [DONE]: {events:?}");
    }
    let tokens = events.iter().filter(|e| e.contains("\"token\""))
        .count();
    if tokens != 8 {
        bail!("wanted 8 streamed tokens, got {tokens}: {events:?}");
    }
    let done = json::parse(events[events.len() - 2])?;
    if done.get("error").is_some() {
        bail!("terminal event carried an error: {}",
              events[events.len() - 2]);
    }
    Ok(format!("{tokens} tokens + done event"))
}

fn metrics(addr: &str) -> Result<String> {
    let (status, body) = request(addr, "GET", "/metrics", "")?;
    if status != 200 {
        bail!("status {status}");
    }
    let samples = body.lines()
        .filter(|l| l.starts_with("latentllm_"))
        .count();
    if samples < 5 {
        bail!("only {samples} samples:\n{body}");
    }
    for want in ["latentllm_requests_total",
                 "latentllm_http_requests_total"] {
        if !body.contains(want) {
            bail!("missing {want}");
        }
    }
    Ok(format!("{samples} samples"))
}

fn shutdown(addr: &str) -> Result<String> {
    let (status, body) = request(addr, "POST", "/admin/shutdown", "")?;
    if status != 200 {
        bail!("status {status}: {body}");
    }
    let v = json::parse(&body)?;
    if v.get("status").and_then(|s| s.as_str()) != Some("draining") {
        bail!("unexpected shutdown reply {body}");
    }
    Ok("draining".to_string())
}
