//! Multimodal reasoning demo (the Table 4 path): loads llava-mini + the
//! synthetic ScienceQA test set, compresses BOTH towers (ViT + LM) in rust
//! with three methods, and prints the accuracy breakdown by subject /
//! context modality / grade.
//!
//! Run: cargo run --release --example multimodal_reasoning -- [artifacts]

use anyhow::{Context, Result};
use latentllm::compress::pipeline::Method;
use latentllm::reports::tables::{table4, TableCtx};
use latentllm::runtime::Engine;

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()));
    let engine = Engine::new(&artifacts).context("engine")?;
    let ctx = TableCtx {
        engine: &engine,
        artifacts: artifacts.clone(),
        max_batches: 8,
        qk_iters: 4,
        ud_iters: 2,
    };
    println!("llava-mini synthetic-ScienceQA accuracy \
              (NAT/SOC/LAN | TXT/IMG/NO | G1-6/G7-12 | Avg):\n");
    let v = table4(&ctx, &[0.3],
                   &[Method::Plain.plan(), Method::AsvdRootCov.plan(),
                     Method::LatentLlm.plan()])?;
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/mm_example.json", v.to_string_pretty())?;
    println!("\nexpected shape (paper Table 4): plain collapses, rootcov \
              holds, latentllm closest to the original; NO-context and \
              higher-grade questions degrade first.");
    Ok(())
}
