//! Quickstart: compress a single linear layer with every pre-conditioner
//! and see the paper's §3.2/3.3 story in 30 lines — the optimal root
//! covariance wins, and the block-identity junction gives the same loss
//! with r² fewer parameters.
//!
//! Run: cargo run --release --example quickstart

use latentllm::compress::asvd::{self, AsvdOpts};
use latentllm::compress::junction::Junction;
use latentllm::compress::precond::{Precond, ALL};
use latentllm::util::rng::{decaying_covariance, wishart, Rng};

fn main() {
    let d = 64;
    let rank = 24;
    let mut rng = Rng::new(0xC0FFEE);
    let w = rng.normal_matrix(d, d);
    // activation statistics: Wishart-correlated tokens (paper Fig 7 setup)
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);

    println!("compressing a {d}x{d} layer to rank {rank} \
              (activation-aware loss, lower is better)\n");
    println!("{:<14} {:>14} {:>12}", "preconditioner", "rel-loss",
             "params");
    for kind in ALL {
        let opts = AsvdOpts { kind, junction: Junction::Left,
                              ..Default::default() };
        let res = asvd::compress_with_cov(&w, rank, &c, &vec![0.0; d],
                                          &opts);
        println!("{:<14} {:>14.6} {:>12}", kind.name(), res.rel_loss,
                 res.params);
    }

    // the junction trick: same loss, r² fewer parameters
    println!("\njunction matrices (paper §3.3) at P = rootcov:");
    for junction in [Junction::Left, Junction::Sym, Junction::BlockId] {
        let opts = AsvdOpts { kind: Precond::RootCov, junction,
                              ..Default::default() };
        let res = asvd::compress_with_cov(&w, rank, &c, &vec![0.0; d],
                                          &opts);
        println!("  {:?}: rel-loss {:.6}  params {}  (dense would be {})",
                 junction, res.rel_loss, res.params, d * d);
    }
    println!("\nblock identity saves r² = {} params at identical loss — \
              r(d+d')−r² < d·d' for every r < d.", rank * rank);
}
