//! Serving demo: the coordinator runs dense-MHA and latent-MLA variants of
//! opt-mini-m side by side, with a cache-aware router and dynamic batcher,
//! and reports throughput, latency quantiles, and the KV-cache capacity
//! story (paper benefit (ii): the MLA cache holds ~(2d)/(r_k+r_v)× more
//! sequences at the same byte budget).
//!
//! Run: cargo run --release --example serve_latent -- [artifacts-dir] [N]

use std::path::PathBuf;

use anyhow::Result;
use latentllm::compress::pipeline::{compress_model, Method};
use latentllm::compress::rank;
use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::scheduler::SchedulerConfig;
use latentllm::coordinator::server::{Drain, GenerateParams, ScoreParams,
                                     Server, ServerConfig};
use latentllm::data::{CalibSet, Corpus};
use latentllm::model::config::mini_by_name;
use latentllm::model::Weights;

fn main() -> Result<()> {
    let artifacts = PathBuf::from(std::env::args().nth(1)
        .unwrap_or_else(|| "artifacts".to_string()));
    let n_requests: usize = std::env::args().nth(2)
        .and_then(|v| v.parse().ok()).unwrap_or(96);
    let model = "opt-mini-m";
    let cfg = mini_by_name(model).unwrap();
    let weights = Weights::load(artifacts.join(
        format!("model_{model}.ltw")))?;
    let calib = CalibSet::load(artifacts.join(format!("calib_{model}.ltw")),
                               cfg.n_layers)?;

    println!("building latent variant (LatentLLM @30%)...");
    let (latent_w, rep) = compress_model(cfg, &weights, &calib,
                                         Method::LatentLlm, 0.3, 4, 2)?;
    println!("  achieved ratio {:.3}", rep.achieved_ratio());

    let r_lat = rank::local_rank(cfg.d, cfg.d, 0.7, true);
    let budget = 4 << 20; // 4 MiB of KV pages per variant
    // one SchedulerConfig drives both the scheduler AND the page size
    // the variants' pools are built with — they must agree
    let sched = SchedulerConfig::default();
    let dense_cache = KvCacheManager::with_block_tokens(
        CacheKind::Dense { d: cfg.d }, cfg.n_layers, 2, budget,
        sched.block_tokens);
    let latent_cache = KvCacheManager::with_block_tokens(
        CacheKind::Latent { rk: r_lat, rv: r_lat }, cfg.n_layers, 2,
        budget, sched.block_tokens);
    println!("KV cache accounting at a {budget}-byte budget:");
    println!("  dense : {} bytes/token  -> {} token capacity",
             dense_cache.bytes_per_token(), dense_cache.capacity_tokens());
    println!("  latent: {} bytes/token  -> {} token capacity ({:.1}x)",
             latent_cache.bytes_per_token(), latent_cache.capacity_tokens(),
             latent_cache.capacity_tokens() as f64
                 / dense_cache.capacity_tokens() as f64);
    println!("  pages : {} dense blocks of {} B vs {} latent blocks of \
              {} B — same budget, more live latent sessions",
             dense_cache.total_blocks(), dense_cache.block_bytes(),
             latent_cache.total_blocks(), latent_cache.block_bytes());

    let variants = vec![
        ModelVariant { name: "dense".into(),
                       score_program: format!("score_{model}"),
                       step_program: format!("step_{model}"),
                       weights: std::sync::Arc::new(weights),
                       cache: dense_cache },
        ModelVariant { name: "latent30".into(),
                       score_program: format!("score_{model}"),
                       step_program: format!("step_{model}"),
                       weights: std::sync::Arc::new(latent_w),
                       cache: latent_cache },
    ];
    let server = Server::start(
        artifacts.clone(),
        Router::new(variants, Policy::CacheAware),
        ServerConfig {
            batcher: BatcherConfig::default(),
            policy: Policy::CacheAware,
            program_batch: 8,
            seq_len: 128,
            workers: 2,
            // continuous batching: decode requests share each worker's
            // iteration as a live session set over the paged KV pool
            sched: Some(sched),
        })?;

    let corpus = Corpus::load(artifacts.join("corpora.ltw"), "synthwiki",
                              "test")?;
    let reqs = corpus.calibration(n_requests, 128, 1234);
    println!("\nsubmitting {n_requests} scoring requests across {} \
              workers...", server.live_workers());
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for tokens in reqs {
        rxs.push(server.submit_score(ScoreParams { tokens })?);
    }
    // decode sessions ride the same queue: each request prefills its
    // prompt into real per-layer cache state under the KV budget above
    let gen_prompts = corpus.calibration(8, 16, 4321);
    let mut gen_rxs = Vec::new();
    for (i, prompt) in gen_prompts.into_iter().enumerate() {
        gen_rxs.push(server.submit_generate(GenerateParams {
            prompt,
            max_new: 16,
            temperature: 0.0,
            seed: i as u64,
        })?);
    }
    let mut per_variant = std::collections::BTreeMap::new();
    for rx in rxs {
        let resp = rx.recv()?;
        *per_variant.entry(resp.variant).or_insert(0usize) += 1;
    }
    let n_generate = gen_rxs.len();
    let mut gen_ok = 0;
    for rx in gen_rxs {
        if rx.recv()?.error().is_none() {
            gen_ok += 1;
        }
    }
    let dt = t0.elapsed();
    println!("served {n_requests} requests in {:.2}s ({:.1} req/s)",
             dt.as_secs_f64(), n_requests as f64 / dt.as_secs_f64());
    println!("decoded {gen_ok}/{n_generate} generate requests through \
              cached sessions");
    println!("variant placement: {per_variant:?}");
    let metrics = server.shutdown(Drain::Graceful);
    println!("metrics:\n{}", metrics.summary());
    Ok(())
}
