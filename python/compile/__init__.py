"""Build-time python package for the LatentLLM reproduction.

Everything here runs ONCE at `make artifacts` time: trains the mini models,
runs the reference compression implementation, lowers the JAX/Pallas programs
to HLO text, and exports weights/calibration/goldens for the rust
coordinator. Nothing is imported at request time.
"""
