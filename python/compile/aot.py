"""AOT artifact builder — the single build-time entrypoint (`make artifacts`).

Emits everything the rust coordinator needs into artifacts/:

  corpora.ltw            synthetic corpora (train/test token streams)
  model_<size>.ltw       trained opt-mini weights
  calib_<size>.ltw       per-layer calibration activations (paper §5)
  score_<size>.hlo.txt   dense scoring program  (tokens, *W) -> NLL[B]
  step_<size>.hlo.txt    dense serving program  (tokens, lens, *W) -> logits
  latent_*.hlo.txt       MLA-architecture programs (factored weights)
  latent_model_*.ltw     latent factors for the serving demo
  mm_model.ltw/mm_data.ltw/mm_score_*.hlo.txt   llava-mini (Table 4)
  goldens.json           python-side losses/ppl for rust cross-checks
  manifest.json          configs, program param orders, rank signatures
  training_log.json      loss curves (EXPERIMENTS.md provenance)

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Weights are *parameters* of every program, so rust can evaluate any weight
set — in particular weights compressed by the rust pipeline — through one
compiled executable per architecture signature.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, data, ltw, model, multimodal, train
from .latentllm import pipeline, rank

SCORE_B, SEQ_LEN = 8, 128
MM_B = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _write_hlo(path, fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)", flush=True)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_lm_programs(out, cfg):
    """Dense score/step programs with weights as ordered parameters."""
    names = cfg.param_names()
    shapes = cfg.shapes()
    wspecs = [_spec(shapes[n], jnp.float32) for n in names]

    def score(tokens, *ws):
        params = dict(zip(names, ws))
        return (model.batch_nll(cfg, params, tokens, use_pallas=True),)

    def step(tokens, lens, *ws):
        params = dict(zip(names, ws))
        return (model.step_logits(cfg, params, tokens, lens,
                                  use_pallas=True),)

    tok = _spec((SCORE_B, SEQ_LEN), jnp.int32)
    _write_hlo(os.path.join(out, f"score_{cfg.name}.hlo.txt"),
               score, tok, *wspecs)
    _write_hlo(os.path.join(out, f"step_{cfg.name}.hlo.txt"),
               step, tok, _spec((SCORE_B,), jnp.int32), *wspecs)
    return {"score": ["tokens"] + names,
            "step": ["tokens", "lens"] + names}


def emit_latent_programs(out, cfg, ranks, tag):
    names = model.latent_param_names(cfg, ranks)
    shapes = model.latent_shapes(cfg, ranks)
    wspecs = [_spec(shapes[n], jnp.float32) for n in names]

    def score(tokens, *ws):
        params = dict(zip(names, ws))
        return (model.latent_batch_nll(cfg, params, tokens,
                                       use_pallas=True),)

    def step(tokens, lens, *ws):
        params = dict(zip(names, ws))
        return (model.latent_step_logits(cfg, params, tokens, lens,
                                         use_pallas=True),)

    tok = _spec((SCORE_B, SEQ_LEN), jnp.int32)
    _write_hlo(os.path.join(out, f"latent_score_{tag}.hlo.txt"),
               score, tok, *wspecs)
    _write_hlo(os.path.join(out, f"latent_step_{tag}.hlo.txt"),
               step, tok, _spec((SCORE_B,), jnp.int32), *wspecs)
    return {"latent_score": ["tokens"] + names,
            "latent_step": ["tokens", "lens"] + names}


def latent_params_from_report(cfg, weights, report, ranks):
    """Map pipeline factors -> the latent architecture's parameter dict."""
    out = {"tok_emb": weights["tok_emb"], "pos_emb": weights["pos_emb"],
           "lnf.g": weights["lnf.g"], "lnf.b": weights["lnf.b"]}
    h, dh = cfg.n_heads, cfg.d_h
    for i, lrep in enumerate(report["layers"]):
        p = f"layers.{i}."
        for nm in ("ln1.g", "ln1.b", "ln2.g", "ln2.b"):
            out[p + nm] = weights[p + nm]
        jq = lrep["qk_factors"]
        out[p + "attn.aq"] = np.asarray(jq["Aq"], np.float32)
        out[p + "attn.bq_heads"] = np.stack(jq["Bq"]).astype(np.float32)
        out[p + "attn.bq"] = np.asarray(jq["bq"], np.float32)
        out[p + "attn.ak"] = np.asarray(jq["Ak"], np.float32)
        out[p + "attn.bk_heads"] = np.stack(jq["Bk"]).astype(np.float32)
        out[p + "attn.bk"] = np.asarray(jq["bk"], np.float32)
        vo = lrep["vo_factors"]
        out[p + "attn.av"] = np.asarray(vo["v"]["A"], np.float32)
        out[p + "attn.bv_heads"] = np.asarray(
            vo["v"]["B"], np.float32).reshape(h, dh, -1)
        out[p + "attn.bv"] = np.asarray(vo["v"]["bias"], np.float32)
        out[p + "attn.ao_heads"] = np.asarray(vo["o"]["A"], np.float32)
        out[p + "attn.bo_mat"] = np.asarray(vo["o"]["B"], np.float32)
        out[p + "attn.bo"] = np.asarray(vo["o"]["bias"], np.float32)
        ud = lrep["ud_factors"]
        out[p + "mlp.au"] = np.asarray(ud["res_u"]["A"], np.float32)
        out[p + "mlp.bu_mat"] = np.asarray(ud["res_u"]["B"], np.float32)
        out[p + "mlp.bu"] = np.asarray(ud["bu"], np.float32)
        out[p + "mlp.ad"] = np.asarray(ud["res_d"]["A"], np.float32)
        out[p + "mlp.bd_mat"] = np.asarray(ud["res_d"]["B"], np.float32)
        out[p + "mlp.bd"] = np.asarray(ud["bd"], np.float32)
    return out


def emit_mm_program(out, mm):
    names = multimodal.param_names(mm)

    def score(images, tokens, *ws):
        params = dict(zip(names, ws))
        return (multimodal.batch_logits(mm, params, images, tokens),)

    p0 = multimodal.init_params(mm)
    wspecs = [_spec(p0[n].shape, jnp.float32) for n in names]
    _write_hlo(os.path.join(out, f"mm_score_{mm.name}.hlo.txt"), score,
               _spec((MM_B, 16, 16), jnp.float32),
               _spec((MM_B, multimodal.TEXT_LEN), jnp.int32), *wspecs)
    return {f"mm_score_{mm.name}": ["images", "tokens"] + names}


def flatten_calib(cal):
    return {f"{layer}.{k}": v for layer, d in cal.items()
            for k, v in d.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budget (CI smoke)")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    t_start = time.time()
    manifest = {"seq_len": SEQ_LEN, "score_batch": SCORE_B, "mm_batch": MM_B,
                "programs": {}, "models": {}, "corpora": {},
                "vocab": data.VOCAB}
    tlog = {}

    # ------------------------------------------------------------------ data
    print("== corpora ==", flush=True)
    corp = {}
    streams = {}
    for name in data.CORPORA:
        n_train = 200_000 if name == "synthwiki" else 2_000
        if args.quick:
            n_train = min(n_train, 40_000)
        tr, te = data.splits(name, n_train=n_train, n_test=24_576)
        streams[name] = (tr, te)
        corp[f"{name}.train"] = tr
        corp[f"{name}.test"] = te
        manifest["corpora"][name] = {"train": len(tr), "test": len(te)}
    ltw.write_ltw(os.path.join(out, "corpora.ltw"), corp)

    train_tokens = streams["synthwiki"][0]
    calib_tokens = data.calibration(train_tokens, n_samples=64,
                                    seq_len=SEQ_LEN)

    # ------------------------------------------------------------- LM models
    steps = {"opt-mini-s": 700, "opt-mini-m": 500, "opt-mini-l": 400}
    family = configs.MINI_FAMILY
    weights_by_size = {}
    calib_by_size = {}
    for cfg in family:
        n = 60 if args.quick else steps[cfg.name]
        print(f"== train {cfg.name} ({n} steps) ==", flush=True)
        params, curve = train.train_lm(cfg, train_tokens, steps=n, lr=3e-3,
                                       log_every=max(n // 4, 1))
        tlog[cfg.name] = curve
        weights_by_size[cfg.name] = params
        ltw.write_ltw(os.path.join(out, f"model_{cfg.name}.ltw"), params)
        cal = train.collect_calibration(cfg, params, calib_tokens,
                                        max_cols=1024)
        calib_by_size[cfg.name] = cal
        ltw.write_ltw(os.path.join(out, f"calib_{cfg.name}.ltw"),
                      flatten_calib(cal))
        ppls = {nm: train.eval_ppl(cfg, params, streams[nm][1],
                                   batch=SCORE_B, seq_len=SEQ_LEN,
                                   max_batches=24)
                for nm in data.CORPORA}
        manifest["models"][cfg.name] = {
            "config": cfg.to_dict(), "base_ppl": ppls,
            "param_names": cfg.param_names(),
            "n_params": int(sum(np.asarray(v).size
                                for v in params.values()))}
        print(f"  base ppl: {ppls}", flush=True)
        manifest["programs"].update(
            {f"{k}_{cfg.name}": v
             for k, v in emit_lm_programs(out, cfg).items()})

    # ------------------------------------------------- latent (MLA) programs
    demo = configs.OPT_MINI_M
    demo_ratio = 0.3
    keep = 1.0 - demo_ratio
    d, dh, h, di = demo.d, demo.d_h, demo.n_heads, demo.d_i
    r_qk = rank.joint_qk_rank(d, dh, h, h, keep, blockid=True)
    ranks = {"rq": r_qk, "rk": r_qk,
             "rv": rank.local_rank(d, d, keep, True),
             "ro": rank.local_rank(d, d, keep, True),
             "ru": rank.local_rank(di, d, keep, True),
             "rd": rank.local_rank(d, di, keep, True)}
    tag = f"{demo.name}_r{int(demo_ratio * 100)}"
    print(f"== latent demo {tag} ranks={ranks} ==", flush=True)
    pf64 = {k: np.asarray(v, np.float64)
            for k, v in weights_by_size[demo.name].items()}
    new_w, rep = pipeline.compress_model(demo, pf64, calib_by_size[demo.name],
                                         "latentllm", demo_ratio)
    lat_params = latent_params_from_report(demo, weights_by_size[demo.name],
                                           rep, ranks)
    ltw.write_ltw(os.path.join(out, f"latent_model_{tag}.ltw"),
                  {k: np.asarray(v, np.float32)
                   for k, v in lat_params.items()})
    manifest["latent_demo"] = {
        "model": demo.name, "ratio": demo_ratio, "ranks": ranks, "tag": tag,
        "param_names": model.latent_param_names(demo, ranks),
        "achieved_ratio": rep["achieved_ratio"]}
    manifest["programs"].update(
        {f"{k}_{tag}": v
         for k, v in emit_latent_programs(out, demo, ranks, tag).items()})
    # sanity: latent forward == reconstructed dense forward (ppl-level)
    lat_ppl = float(np.exp(np.mean(np.asarray(model.latent_batch_nll(
        demo, {k: jnp.asarray(v) for k, v in lat_params.items()},
        jnp.asarray(calib_tokens[:SCORE_B]), use_pallas=False)))))
    rec_ppl = float(np.exp(np.mean(np.asarray(model.batch_nll(
        demo, {k: jnp.asarray(np.asarray(v, np.float32))
               for k, v in new_w.items()},
        jnp.asarray(calib_tokens[:SCORE_B]), use_pallas=False)))))
    print(f"  latent ppl {lat_ppl:.3f} vs reconstructed {rec_ppl:.3f}")
    manifest["latent_demo"]["latent_vs_reconstructed_ppl"] = [lat_ppl,
                                                              rec_ppl]

    # ------------------------------------------------------------ multimodal
    mm = configs.LLAVA_MINI
    n_mm = 400 if args.quick else 6000
    mm_steps = 80 if args.quick else 2000
    print(f"== llava-mini ({mm_steps} steps) ==", flush=True)
    ds_train = multimodal.make_dataset(n_mm, seed=0)
    ds_test = multimodal.make_dataset(max(n_mm // 4, 200), seed=1)
    mm_params, mm_curve = multimodal.train_mm(mm, ds_train, steps=mm_steps,
                                              lr=3e-3,
                                              log_every=max(mm_steps // 5, 1))
    tlog["llava-mini"] = mm_curve
    acc = multimodal.evaluate(mm, mm_params, ds_test)
    print(f"  base accuracy: {acc}", flush=True)
    ltw.write_ltw(os.path.join(out, "mm_model.ltw"), mm_params)
    ltw.write_ltw(os.path.join(out, "mm_data.ltw"), {
        "images": ds_test["images"], "tokens": ds_test["tokens"],
        "labels": ds_test["labels"], "cats": ds_test["cats"]})
    mm_cal = multimodal.collect_calibration(mm, mm_params, ds_train)
    ltw.write_ltw(os.path.join(out, "mm_calib.ltw"), flatten_calib(mm_cal))
    manifest["mm"] = {"config": mm.to_dict(), "base_acc": acc,
                      "param_names": multimodal.param_names(mm),
                      "text_len": multimodal.TEXT_LEN,
                      "n_test": int(ds_test["images"].shape[0])}
    manifest["programs"].update(emit_mm_program(out, mm))

    # --------------------------------------------------------------- goldens
    print("== goldens ==", flush=True)
    gcfg = configs.OPT_MINI_S
    gparams = {k: np.asarray(v, np.float64)
               for k, v in weights_by_size[gcfg.name].items()}
    gold = {"model": gcfg.name, "entries": []}
    for method in ("plain", "asvd_rootcov", "latentllm"):
        for ratio in (0.2, 0.4):
            nw, rep2 = pipeline.compress_model(
                gcfg, gparams, calib_by_size[gcfg.name], method, ratio)
            nw32 = {k: np.asarray(v, np.float32) for k, v in nw.items()}
            ppl = train.eval_ppl(gcfg, nw32, streams["synthwiki"][1],
                                 batch=SCORE_B, seq_len=SEQ_LEN,
                                 max_batches=24)
            gold["entries"].append({
                "method": method, "ratio": ratio, "ppl": ppl,
                "achieved_ratio": rep2["achieved_ratio"]})
            print(f"  {method} @{ratio}: ppl {ppl:.3f}", flush=True)
    with open(os.path.join(out, "goldens.json"), "w") as f:
        json.dump(gold, f, indent=1)

    with open(os.path.join(out, "training_log.json"), "w") as f:
        json.dump(tlog, f)
    manifest["build_seconds"] = time.time() - t_start
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== done in {manifest['build_seconds']:.0f}s ==", flush=True)


if __name__ == "__main__":
    main()
