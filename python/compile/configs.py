"""Model configurations.

`MiniConfig` is the OPT-style architecture used for the trained-from-scratch
reproduction models (see DESIGN.md §2 for the substitution rationale): ReLU
MLP, pre-LN, learned positional embeddings, biases on all linear layers —
architecturally an OPT model at reduced scale (paper Table 5).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class MiniConfig:
    name: str
    vocab: int = 512
    d: int = 128              # hidden size
    n_layers: int = 4
    n_heads: int = 4
    d_i: int = 512            # intermediate (4d like OPT)
    max_len: int = 128        # max sequence length / learned pos-emb rows
    tie_embeddings: bool = True

    @property
    def d_h(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads

    def param_names(self):
        """Deterministic flat parameter order shared with rust (manifest)."""
        names = ["tok_emb", "pos_emb"]
        for i in range(self.n_layers):
            p = f"layers.{i}."
            names += [
                p + "ln1.g", p + "ln1.b",
                p + "attn.wq", p + "attn.bq",
                p + "attn.wk", p + "attn.bk",
                p + "attn.wv", p + "attn.bv",
                p + "attn.wo", p + "attn.bo",
                p + "ln2.g", p + "ln2.b",
                p + "mlp.wu", p + "mlp.bu",
                p + "mlp.wd", p + "mlp.bd",
            ]
        names += ["lnf.g", "lnf.b"]
        if not self.tie_embeddings:
            names += ["lm_head"]
        return names

    def shapes(self):
        """name -> shape, matching param_names order. Weight convention:
        w[out, in] (row-major out-features first), matching the paper's
        W ∈ R^{d' x d} acting as y = W x."""
        d, di, v = self.d, self.d_i, self.vocab
        s = {"tok_emb": (v, d), "pos_emb": (self.max_len, d)}
        for i in range(self.n_layers):
            p = f"layers.{i}."
            s[p + "ln1.g"] = (d,)
            s[p + "ln1.b"] = (d,)
            for m in ("wq", "wk", "wv", "wo"):
                s[p + f"attn.{m}"] = (d, d)
            for m in ("bq", "bk", "bv", "bo"):
                s[p + f"attn.{m}"] = (d,)
            s[p + "ln2.g"] = (d,)
            s[p + "ln2.b"] = (d,)
            s[p + "mlp.wu"] = (di, d)
            s[p + "mlp.bu"] = (di,)
            s[p + "mlp.wd"] = (d, di)
            s[p + "mlp.bd"] = (d,)
        s["lnf.g"] = (d,)
        s["lnf.b"] = (d,)
        if not self.tie_embeddings:
            s["lm_head"] = (v, d)
        return s

    def n_params(self) -> int:
        return sum(
            int.__mul__(*(list(sh) + [1])[:2]) if len(sh) == 2 else sh[0]
            for sh in self.shapes().values()
        )

    def to_dict(self):
        return asdict(self)


# The reproduction family — stand-ins for OPT-125M/350M/1.3B (Table 5),
# scaled so all of them train + evaluate in seconds on CPU.
OPT_MINI_S = MiniConfig(name="opt-mini-s", d=96, n_layers=2, n_heads=4, d_i=384)
OPT_MINI_M = MiniConfig(name="opt-mini-m", d=128, n_layers=4, n_heads=4, d_i=512)
OPT_MINI_L = MiniConfig(name="opt-mini-l", d=192, n_layers=6, n_heads=6, d_i=768)

MINI_FAMILY = [OPT_MINI_S, OPT_MINI_M, OPT_MINI_L]


@dataclass(frozen=True)
class VisionConfig:
    """Tiny CLIP-style ViT for the llava-mini multimodal model."""
    img: int = 16
    patch: int = 4
    d: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_i: int = 256

    @property
    def n_patches(self) -> int:
        return (self.img // self.patch) ** 2  # 16

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch


@dataclass(frozen=True)
class LlavaMiniConfig:
    name: str = "llava-mini"
    lm: MiniConfig = field(
        default_factory=lambda: MiniConfig(
            name="llava-mini-lm", vocab=256, d=96, n_layers=3, n_heads=4,
            d_i=384, max_len=64)
    )
    vision: VisionConfig = field(default_factory=VisionConfig)
    n_answers: int = 8  # class-concept answers (see multimodal.py docstring)

    def to_dict(self):
        return {"name": self.name, "lm": self.lm.to_dict(),
                "vision": asdict(self.vision), "n_answers": self.n_answers}


LLAVA_MINI = LlavaMiniConfig()
