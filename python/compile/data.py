"""Synthetic corpora — stand-ins for WikiText-2 / PTB / C4 (DESIGN.md §2).

A seeded topic-switching bigram (Markov) generator over a Zipf-shaped
vocabulary: per topic, every token has a small successor table with heavy-
tailed transition probabilities, so a small transformer learns real
structure and perplexity differences between compression methods are
meaningful. The three corpora use different seeds/topologies, mirroring the
paper's calibrate-on-C4 / evaluate-on-{WT2, PTB, C4} zero-shot protocol.
"""

import numpy as np

VOCAB = 512
BASE_SEED = 20250607          # the shared "language" (bigram tables)
MAX_TOPICS, MAX_BRANCH = 6, 10
CORPORA = {
    # name: (seed, n_topics, branch, zipf_a, switch_prob, perturb)
    # All corpora share the same base successor tables (the "language");
    # per-corpus style = topic subset, branch cut, Zipf temperature, and a
    # perturbed fraction of transitions — so a model trained on synthwiki
    # transfers to the others with moderately higher perplexity, mirroring
    # the paper's WT2/PTB/C4 relationship.
    "synthwiki": (1234, 4, 8, 1.3, 0.02, 0.0),
    "synthptb": (5678, 3, 6, 1.5, 0.03, 0.15),
    "synthc4": (9012, 6, 10, 1.1, 0.015, 0.10),
}


def _successor_tables(name):
    """Per-corpus view of the shared tables + zipf cumulative probs."""
    seed, n_topics, branch, zipf_a, switch, perturb = CORPORA[name]
    base_rng = np.random.default_rng(BASE_SEED)
    base = base_rng.integers(0, VOCAB,
                             size=(MAX_TOPICS, VOCAB, MAX_BRANCH))
    tables = base[:n_topics, :, :branch].copy()
    if perturb > 0:
        prng = np.random.default_rng(seed)
        mask = prng.random(tables.shape) < perturb
        tables[mask] = prng.integers(0, VOCAB, size=int(mask.sum()))
    probs = (1.0 / np.arange(1, branch + 1) ** zipf_a)
    probs /= probs.sum()
    return tables, np.cumsum(probs), switch


def generate(name, n_tokens, split_seed=0):
    """Generate `n_tokens` int32 tokens of corpus `name`."""
    seed = CORPORA[name][0]
    n_topics, branch = CORPORA[name][1], CORPORA[name][2]
    tables, cum, switch = _successor_tables(name)
    srng = np.random.default_rng(seed * 7919 + split_seed + 1)  # the walk
    u_tok = srng.random(n_tokens)
    u_sw = srng.random(n_tokens)
    u_topic = srng.integers(0, n_topics, size=n_tokens)
    out = np.empty(n_tokens, dtype=np.int32)
    tok = int(srng.integers(0, VOCAB))
    topic = 0
    for i in range(n_tokens):
        if u_sw[i] < switch:
            topic = int(u_topic[i])
        slot = int(np.searchsorted(cum, u_tok[i]))
        tok = int(tables[topic, tok, min(slot, branch - 1)])
        out[i] = tok
    return out


def splits(name, n_train=200_000, n_test=24_576):
    """(train, test) token streams; test uses a disjoint walk seed."""
    return generate(name, n_train, split_seed=0), \
        generate(name, n_test, split_seed=1)


def batches(tokens, batch, seq_len, rng=None, n_batches=None):
    """Yield [batch, seq_len] int32 windows; random if rng else sequential."""
    tokens = np.asarray(tokens, dtype=np.int32)
    max_start = len(tokens) - seq_len - 1
    if rng is not None:
        while True:
            starts = rng.integers(0, max_start, size=batch)
            yield np.stack([tokens[s:s + seq_len] for s in starts])
    else:
        n = (max_start // seq_len) if n_batches is None else n_batches * batch
        windows = [tokens[s:s + seq_len]
                   for s in range(0, max_start, seq_len)]
        for i in range(0, len(windows) - batch + 1, batch):
            yield np.stack(windows[i:i + batch])


def calibration(tokens, n_samples=64, seq_len=128, seed=42):
    """The paper's calibration protocol: n random seq_len-token segments."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(tokens) - seq_len - 1, size=n_samples)
    return np.stack([tokens[s:s + seq_len] for s in starts]).astype(np.int32)
