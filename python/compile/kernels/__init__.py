"""Layer-1 Pallas kernels (interpret=True on CPU; see DESIGN.md
§Hardware-Adaptation for the TPU mapping) and their jnp oracles."""

from . import attention, gram, lowrank, ref  # noqa: F401
