"""Pallas attention kernels: dense causal MHA and the latent (MLA) variant.

The MLA kernel is the inference payoff of the paper's joint QK/VO
compression: scores are computed *in latent space*, sᵢ = (q_lat Hᵢ) c_kᵀ,
against the shared latent KV cache (r_k + r_v floats per token instead of
2·d — the DeepSeek-V3 style cache saving), and values are decompressed
per head only after the attention weighting.

Grid: one program per head; at this repo's scales a whole [t × d_h] head
fits VMEM comfortably (t ≤ 128). On a real TPU the same kernels would tile
t into MXU-aligned blocks with an online-softmax accumulator; interpret=True
keeps CPU numerics exact instead.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    d_h = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) \
        / jnp.sqrt(jnp.float32(d_h))
    t = q.shape[0]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask, s, _NEG)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def mha(q, k, v, interpret=True):
    """Causal multi-head attention. q,k,v: [h, t, d_h] → [h, t, d_h]."""
    h, t, d_h = q.shape
    return pl.pallas_call(
        _mha_kernel,
        grid=(h,),
        in_specs=[pl.BlockSpec((1, t, d_h), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1, t, d_h), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d_h), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def _latent_kernel(q_ref, ck_ref, cv_ref, h_ref, bv_ref, o_ref):
    q_lat = q_ref[...]          # [t, rq]
    ck = ck_ref[...]            # [t, rk]
    cv = cv_ref[...]            # [t, rv]
    h_core = h_ref[0]           # [rq, rk]
    bv = bv_ref[0]              # [d_h, rv]
    d_h = bv.shape[0]
    qh = jnp.dot(q_lat, h_core, preferred_element_type=jnp.float32)
    s = jnp.dot(qh, ck.T, preferred_element_type=jnp.float32) \
        / jnp.sqrt(jnp.float32(d_h))
    t = q_lat.shape[0]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask, s, _NEG)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    ctx_lat = jnp.dot(p, cv, preferred_element_type=jnp.float32)  # [t, rv]
    o_ref[0] = jnp.dot(ctx_lat, bv.T, preferred_element_type=jnp.float32)


def latent_attention(q_lat, ck, cv, h_core, bv, interpret=True):
    """MLA: q_lat:[t,rq], ck:[t,rk], cv:[t,rv], h_core:[h,rq,rk],
    bv:[h,d_h,rv] → [h,t,d_h]. The latent KV (ck, cv) is what a serving
    stack caches per token."""
    h, rq, rk = h_core.shape
    t = q_lat.shape[0]
    rv = cv.shape[1]
    d_h = bv.shape[1]
    return pl.pallas_call(
        _latent_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((t, rq), lambda i: (0, 0)),
            pl.BlockSpec((t, rk), lambda i: (0, 0)),
            pl.BlockSpec((t, rv), lambda i: (0, 0)),
            pl.BlockSpec((1, rq, rk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d_h, rv), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, d_h), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d_h), jnp.float32),
        interpret=interpret,
    )(q_lat, ck, cv, h_core, bv)


def kv_cache_bytes(t, d, n_layers, dtype_bytes=2):
    """Dense MHA cache: 2·d floats per token per layer."""
    return t * n_layers * 2 * d * dtype_bytes


def latent_kv_cache_bytes(t, rk, rv, n_layers, dtype_bytes=2):
    """MLA cache: (r_k + r_v) floats per token per layer (paper benefit ii)."""
    return t * n_layers * (rk + rv) * dtype_bytes
