"""Pallas kernel: streaming Gram/auto-correlation accumulation  C = X Xᵀ.

This is the calibration pass's hot spot (paper §3.2: C = XXᵀ + λI): the
token axis `l` is large (#calibration samples × seq len) while d is small,
so the kernel streams token tiles HBM→VMEM and accumulates the d×d Gram
matrix in an f32 VMEM-resident output block (classic reduction-over-grid
pattern — on TPU this is the bf16-in / f32-accumulate MXU idiom).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [d, bl] token tile
    o_ref[...] += jnp.dot(x, x.T, preferred_element_type=jnp.float32)


def gram(x, bl=256, interpret=True):
    """C = X Xᵀ for x: [d, l], streamed over l in tiles of bl."""
    d, l = x.shape
    lp = ((l + bl - 1) // bl) * bl
    if lp != l:
        x = jnp.pad(x, ((0, 0), (0, lp - l)))  # zero pad: no effect on XXᵀ
    grid = (lp // bl,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((d, bl), lambda i: (0, i))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(x)
