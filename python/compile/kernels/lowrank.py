"""Pallas kernel: fused low-rank projection  y = B (A x) + bias.

The paper's latent linear layer (§3.2/3.3). Two variants:
  * dense factors  A[r×d_in], B[d_out×r];
  * block-identity A = [I  A₂] (Eq 9) where the identity block costs no
    FLOPs — the kernel only multiplies the A₂ tail and adds the passthrough.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks token tiles of
size `bt` (HBM→VMEM streaming); the factor matrices are VMEM-resident
(r·d_in + d_out·r floats, well under the ~16 MB VMEM budget for every config
in this repo); both matmuls feed the MXU back-to-back without an HBM
round-trip for the latent intermediate — that fusion is the point of the
kernel. interpret=True everywhere (CPU correctness path).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(x_ref, a_ref, b_ref, bias_ref, o_ref):
    lat = jnp.dot(x_ref[...], a_ref[...].T,
                  preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(lat, b_ref[...].T,
                         preferred_element_type=jnp.float32) + bias_ref[...]


def _lowrank_blockid_kernel(x_ref, a2_ref, b_ref, bias_ref, o_ref, *, r):
    x = x_ref[...]
    # identity block: free passthrough of the first r features (Eq 9)
    lat = x[:, :r] + jnp.dot(x[:, r:], a2_ref[...].T,
                             preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(lat, b_ref[...].T,
                         preferred_element_type=jnp.float32) + bias_ref[...]


def _pad_tokens(x, bt):
    t = x.shape[0]
    tp = ((t + bt - 1) // bt) * bt
    if tp != t:
        x = jnp.pad(x, ((0, tp - t), (0, 0)))
    return x, t


def lowrank_matmul(x, a, b, bias=None, bt=64, interpret=True):
    """x:[t,d_in] @ A[r,d_in]ᵀ @ B[d_out,r]ᵀ + bias, tiled over tokens."""
    r, d_in = a.shape
    d_out = b.shape[0]
    if bias is None:
        bias = jnp.zeros((d_out,), dtype=x.dtype)
    xp, t = _pad_tokens(x, bt)
    grid = (xp.shape[0] // bt,)
    out = pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
            pl.BlockSpec((r, d_in), lambda i: (0, 0)),
            pl.BlockSpec((d_out, r), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], d_out), jnp.float32),
        interpret=interpret,
    )(xp, a, b, bias)
    return out[:t]


def lowrank_matmul_blockid(x, a2, b, bias=None, bt=64, interpret=True):
    """Block-identity variant: a2:[r, d_in−r]; A = [I a2] implicitly."""
    r = a2.shape[0]
    d_in = r + a2.shape[1]
    d_out = b.shape[0]
    assert x.shape[1] == d_in
    if bias is None:
        bias = jnp.zeros((d_out,), dtype=x.dtype)
    xp, t = _pad_tokens(x, bt)
    grid = (xp.shape[0] // bt,)
    out = pl.pallas_call(
        functools.partial(_lowrank_blockid_kernel, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
            pl.BlockSpec((r, d_in - r), lambda i: (0, 0)),
            pl.BlockSpec((d_out, r), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], d_out), jnp.float32),
        interpret=interpret,
    )(xp, a2, b, bias)
    return out[:t]


def vmem_bytes(t_block, d_in, d_out, r, dtype_bytes=4):
    """Static VMEM footprint estimate used by the §Perf analysis."""
    return dtype_bytes * (t_block * d_in          # x tile
                          + r * d_in + d_out * r  # factors
                          + t_block * r           # latent intermediate
                          + t_block * d_out       # output tile
                          + d_out)                # bias
