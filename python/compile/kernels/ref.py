"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package has a matching reference here; pytest +
hypothesis sweep shapes and assert allclose (see python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def lowrank_matmul(x, a, b, bias=None):
    """y = (x Aᵀ) Bᵀ + bias.   x:[t,d_in], a:[r,d_in], b:[d_out,r]."""
    y = (x @ a.T) @ b.T
    if bias is not None:
        y = y + bias
    return y


def lowrank_matmul_blockid(x, a2, b, bias=None, perm=None):
    """Block-identity fast path (paper Eq 9): A = [I  A₂] (optionally with a
    column permutation from the pivoting of Remark 4).

    x:[t,d_in], a2:[r, d_in-r], b:[d_out,r].
    """
    r = a2.shape[0]
    if perm is not None:
        x = x[:, perm]
    lat = x[:, :r] + x[:, r:] @ a2.T
    y = lat @ b.T
    if bias is not None:
        y = y + bias
    return y


def mha(q, k, v, causal=True):
    """softmax(q kᵀ/√d_h + mask) v per head.  q,k,v: [h, t, d_h]."""
    d_h = q.shape[-1]
    s = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(d_h))
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hts,hsd->htd", p, v)


def latent_attention(q_lat, ck, cv, h_core, bv, causal=True):
    """Multi-head *latent* attention (paper §4.1/4.2 inference path).

    q_lat:[t,rq] shared query latent; ck:[t,rk], cv:[t,rv] latent KV cache;
    h_core:[h,rq,rk] absorbed Bq,iᵀBk,i; bv:[h,d_h,rv] value decompression.
    Scores are computed directly in latent space: sᵢ = (q_lat Hᵢ) ckᵀ —
    the MLA trick that never materializes full K.
    Returns [h, t, d_h].
    """
    d_h = bv.shape[1]
    s = jnp.einsum("tq,hqk,sk->hts", q_lat, h_core, ck) \
        / jnp.sqrt(jnp.float32(d_h))
    if causal:
        t = q_lat.shape[0]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    ctx_lat = jnp.einsum("hts,sv->htv", p, cv)           # [h,t,rv]
    return jnp.einsum("htv,hdv->htd", ctx_lat, bv)       # decompress


def gram(x):
    """C = X Xᵀ over the token axis.  x: [d, l]."""
    return x @ x.T
