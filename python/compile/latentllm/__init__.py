"""LatentLLM reference compression algorithms (numpy). See DESIGN.md."""
