"""Local activation-aware SVD compression of a single linear layer
(paper §3.2 + App A/B).

Given W ∈ R^{d'×d}, calibration activations X ∈ R^{d×l} (or covariance C)
and a target rank r:

    B A P = svd_r[W P]          (Eq 3)

with the pre-conditioner P from `precond.py` and a junction from
`junction.py`. With a bias term the loss is minimized by centering (App
B.2): compress against C₀ = (X−μ1ᵀ)(X−μ1ᵀ)ᵀ and update
b̂ = b + (W − BA) μ   (Eq 45).
"""

import numpy as np

from . import junction, linalg, precond


def compress(w, rank, kind="rootcov", junction_kind="blockid",
             x=None, c=None, bias=None, mu=None, lam_rel=1e-6):
    """Compress one linear layer.

    Returns dict with B, A, bias, info, and the achieved activation loss
    (relative, against the pre-conditioner's own covariance).
    """
    w = np.asarray(w, dtype=np.float64)
    d_out, d_in = w.shape

    use_center = bias is not None
    if c is None and x is not None:
        if use_center:
            c, mu = linalg.centered_covariance(x, lam_rel=lam_rel)
        else:
            c = linalg.covariance(x, lam_rel=lam_rel)
    if c is None:
        c = np.eye(d_in)
    if mu is None:
        mu = np.zeros(d_in)

    p, p_inv = precond.build(kind, x=x, c=c, lam_rel=lam_rel)
    rank = int(min(rank, d_out, d_in))
    u, s, vt = linalg.svd_truncated(w @ p, rank)
    b, a, info = junction.apply(u, s, vt, p_inv, kind=junction_kind)

    w_hat = b @ a
    new_bias = None
    if bias is not None:
        new_bias = np.asarray(bias, dtype=np.float64) + (w - w_hat) @ mu

    loss = linalg.act_loss(w, w_hat, c)
    denom = linalg.act_loss(w, np.zeros_like(w), c)
    return {
        "B": b, "A": a, "bias": new_bias, "info": info,
        "w_hat": w_hat, "rank": rank,
        "loss": loss, "rel_loss": loss / max(denom, 1e-30),
        "params": junction.factor_params(d_out, d_in, rank,
                                         junction_kind == "blockid"),
    }


def compress_stacked(ws, rank, kind="rootcov", junction_kind="blockid",
                     x=None, c=None, lam_rel=1e-6):
    """Joint-QKV style compression (App C): stack several weights that share
    the same input and factor them with a SHARED compression matrix A and a
    stacked dense decompression B. Returns per-weight blocks of B."""
    w = np.concatenate([np.asarray(wi, dtype=np.float64) for wi in ws], axis=0)
    res = compress(w, rank, kind=kind, junction_kind=junction_kind,
                   x=x, c=c, lam_rel=lam_rel)
    outs, off = [], 0
    for wi in ws:
        outs.append(res["B"][off:off + wi.shape[0]])
        off += wi.shape[0]
    res["B_blocks"] = outs
    return res


def split_head_compress(w, n_heads, rank_total, kind="rootcov",
                        junction_kind="left", x=None, c=None, lam_rel=1e-6):
    """Per-head independent compression (App D) — the ablation that shows
    block-diagonal B is wasteful. rank_total is divided across heads."""
    w = np.asarray(w, dtype=np.float64)
    d_out = w.shape[0]
    dh = d_out // n_heads
    rh = max(1, rank_total // n_heads)
    blocks = []
    loss = 0.0
    for i in range(n_heads):
        wi = w[i * dh:(i + 1) * dh]
        r = compress(wi, rh, kind=kind, junction_kind=junction_kind,
                     x=x, c=c, lam_rel=lam_rel)
        blocks.append(r)
        loss += r["loss"]
    w_hat = np.concatenate([r["w_hat"] for r in blocks], axis=0)
    return {"blocks": blocks, "w_hat": w_hat, "loss": loss}
