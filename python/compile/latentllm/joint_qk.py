"""Joint QK compression → multi-head latent attention (paper §4.1, Alg 1,
App E).

Minimizes the attention-map error

    L₂ = Σᵢ ‖Mᵢ − M̂ᵢ‖² = Σᵢ ‖C½ Gᵢ C½ − (Aq C½)ᵀ Hᵢ (Ak C½)‖²,
    Gᵢ = Wq,iᵀ Wk,i                                   (Eq 13)

a 3-mode Tucker decomposition solved by alternating symmetric
eigendecompositions (HOSVD, Eqs 74–77):

    Ak ← RightSingular_rk[Σᵢ G̃ᵢᵀ Aqᵀ Aq G̃ᵢ]
    Aq ← RightSingular_rq[Σᵢ G̃ᵢ Akᵀ Ak G̃ᵢᵀ]         (whitened G̃ᵢ = P Gᵢ P)

with cores Hᵢ = Aq G̃ᵢ Akᵀ (Eq 64) and per-head factors
Bq,i = Jᵢᵀ Wq,i Aqᵀ Jq,  Bk,i = Jᵢ⁺ Wk,i Akᵀ Jk (Alg 1 output). Junction
matrices Jq/Jk/Jᵢ are free; the block-identity choice saves
rq² + rk² + d_h²·h parameters (paper §4.1).

GQA (App E.3) is supported through `group_size`: Wq carries
group_size × n_kv_heads heads, Wk carries n_kv_heads.

Bias awareness (App E.2): with QK biases and token mean μ, the alternating
matrices gain the rank-1 term Σᵢ C₀½Wq,iᵀ(Wk,iμ+bk,i)(·)ᵀWq,iC₀½ (Eq 140),
and the HOSVD runs on the centered covariance C₀.
"""

import numpy as np

from . import linalg, precond


def _split_heads(w, n, dh):
    w = np.asarray(w, dtype=np.float64)
    assert w.shape[0] == n * dh, (w.shape, n, dh)
    return [w[i * dh:(i + 1) * dh] for i in range(n)]


def attention_map_loss(g_list_white, aq, ak):
    """L = Σᵢ ‖Gᵢ‖² − ‖Aq Gᵢ Akᵀ‖² for orthonormal Aq/Ak rows (Eq 68)."""
    total = 0.0
    for g in g_list_white:
        total += linalg.frob2(g) - linalg.frob2(aq @ g @ ak.T)
    return total


def compress(wq, wk, n_kv_heads, d_h, rq, rk, n_iter=8,
             kind="rootcov", x=None, c=None, group_size=1,
             bq=None, bk=None, mu=None, lam_rel=1e-6,
             blockid=True):
    """Run Algorithm 1. Returns factors + effective reconstructed weights.

    wq: [group_size*n_kv_heads*d_h, d], wk: [n_kv_heads*d_h, d].
    """
    wq = np.asarray(wq, dtype=np.float64)
    wk = np.asarray(wk, dtype=np.float64)
    d = wq.shape[1]
    rq = int(min(rq, d))
    rk = int(min(rk, d))

    bias_aware = bq is not None and bk is not None and mu is not None
    if c is None:
        if x is not None:
            if bias_aware:
                c, mu = linalg.centered_covariance(x, lam_rel=lam_rel)
            else:
                c = linalg.covariance(x, lam_rel=lam_rel)
        else:
            c = np.eye(d)

    p, p_inv = precond.build(kind, x=x, c=c, lam_rel=lam_rel)

    q_heads = _split_heads(wq, group_size * n_kv_heads, d_h)
    k_heads = _split_heads(wk, n_kv_heads, d_h)
    bq_heads = _split_heads(bq.reshape(-1, 1), group_size * n_kv_heads, d_h) \
        if bias_aware else None
    bk_heads = _split_heads(bk.reshape(-1, 1), n_kv_heads, d_h) \
        if bias_aware else None

    # Whitened per-pair attention kernels G̃_{i,j} = (Wq,ij P)ᵀ (Wk,i P)
    pairs = []  # (q_idx, k_idx)
    g_white = []
    for i in range(n_kv_heads):
        for j in range(group_size):
            qi = i * group_size + j
            g = (q_heads[qi] @ p).T @ (k_heads[i] @ p)
            pairs.append((qi, i))
            g_white.append(g)

    # Bias rank-1 augmentation terms (Eq 140/142): in whitened coords,
    # u_q = P Wqᵀ (Wk μ + bk),  u_k = P Wkᵀ (Wq μ + bq).
    uq_terms = np.zeros((d, d))
    uk_terms = np.zeros((d, d))
    if bias_aware:
        for (qi, ki) in pairs:
            vk = k_heads[ki] @ mu + bk_heads[ki][:, 0] if bias_aware else None
            vq = q_heads[qi] @ mu + bq_heads[qi][:, 0]
            a_ = p @ q_heads[qi].T @ vk
            b_ = p @ k_heads[ki].T @ vq
            uq_terms += np.outer(a_, a_)
            uk_terms += np.outer(b_, b_)

    # Init Aq from Σ G G ᵀ (Alg 1 initialization line).
    acc = sum(g @ g.T for g in g_white) + uq_terms
    aq = linalg.topk_eigvecs(acc, rq)

    losses = [attention_map_loss(g_white, aq,
                                 linalg.topk_eigvecs(sum(g.T @ g for g in g_white), rk))]
    ak = None
    for _ in range(max(1, n_iter)):
        acc_k = sum(g.T @ (aq.T @ (aq @ g)) for g in g_white) + uk_terms
        ak = linalg.topk_eigvecs(acc_k, rk)
        acc_q = sum(g @ (ak.T @ (ak @ g.T)) for g in g_white) + uq_terms
        aq = linalg.topk_eigvecs(acc_q, rq)
        losses.append(attention_map_loss(g_white, aq, ak))

    # Cores + per-head decompression (Alg 1 output block), Jᵢ = I here;
    # the per-head block-identity transform is applied by the caller's
    # parameter accounting (rust mirrors this exactly).
    bq_f = [qh @ p @ aq.T for qh in q_heads]          # Wq,i P Aqᵀ  (d_h×rq)
    bk_f = [kh @ p @ ak.T for kh in k_heads]          # d_h×rk
    aq_f = aq @ p_inv                                  # rq×d
    ak_f = ak @ p_inv

    wq_hat = np.concatenate([b @ aq_f for b in bq_f], axis=0)
    wk_hat = np.concatenate([b @ ak_f for b in bk_f], axis=0)

    new_bq, new_bk = None, None
    if bias_aware:
        # First-order bias correction (Eq 121/122 with Jᵢ = I):
        # b̂ = b + (W − Ŵ) μ  keeps the mean attention logits unchanged.
        new_bq = np.asarray(bq, dtype=np.float64) + (wq - wq_hat) @ mu
        new_bk = np.asarray(bk, dtype=np.float64) + (wk - wk_hat) @ mu

    h_q = group_size * n_kv_heads
    params = (rq + rk) * d + h_q * d_h * rq + n_kv_heads * d_h * rk
    if blockid:
        params -= rq * rq + rk * rk + d_h * d_h * min(h_q, n_kv_heads)
    return {
        "Aq": aq_f, "Ak": ak_f, "Bq": bq_f, "Bk": bk_f,
        "bq": new_bq, "bk": new_bk,
        "wq_hat": wq_hat, "wk_hat": wk_hat,
        "losses": losses, "loss": losses[-1],
        "params": params, "rq": rq, "rk": rk,
    }
