"""Joint Up/Down (MLP) compression via SparseLLM-style decoupling
(paper §4.3, App H).

2-layer ReLU MLP:  Z = Wu X + bu,  Z′ = σ(Z),  Y = Wd Z′ + bd.
Decoupled loss (Eq 20):

    L₄ = α‖WuX − Z‖² + β‖Z′ − σ(Z)‖² + γ‖WdZ′ − Y‖²

alternating closed-form updates with auxiliary (Z, Z′):

  Z′ = (γ Ŵdᵀ Ŵd + β I)⁺ (β σ(Z) + γ Ŵdᵀ (Y − b̂d))        (Eq 21)
  Z  elementwise:  z₋ = Ŵu X + b̂u  if that branch (σ(z)=0) wins,
                   z₊ = (α z₋ + β z′)/(α+β) if the positive branch wins
                   — choose by the smaller pointwise decoupled loss (Eq 22)
  Ŵu = svd_r[(Z − μz1ᵀ)(X − μx1ᵀ)⁺ · Cx^{1/2}]             (App H)
  Ŵd = svd_r[(Y − μy1ᵀ)(Z′ − μz′1ᵀ)⁺ · Cz′^{1/2}]

The effective-weight regression (Z X⁺) + root-cov ASVD is exactly the
paper's "SVD of Z X⁺ C^{1/2}" with the bias handled by centering (App B.2).
"""

import numpy as np

from . import asvd, linalg


def _relu(z):
    return np.maximum(z, 0.0)


def _fit_effective(target, x, rank, junction_kind, lam_rel):
    """Ridge-fit W_eff: target ≈ W_eff x + b, then root-cov ASVD compress."""
    x = np.asarray(x, dtype=np.float64)
    t = np.asarray(target, dtype=np.float64)
    mu_x = x.mean(axis=1, keepdims=True)
    mu_t = t.mean(axis=1, keepdims=True)
    xc = x - mu_x
    tc = t - mu_t
    c = linalg.covariance(xc, lam_rel=max(lam_rel, 1e-8))
    l = x.shape[1]
    w_eff = (tc @ xc.T / l) @ linalg.pinv(c)
    b_eff = (mu_t - w_eff @ mu_x)[:, 0]
    res = asvd.compress(w_eff, rank, kind="rootcov",
                        junction_kind=junction_kind, c=c,
                        bias=b_eff, mu=np.zeros(x.shape[0]),
                        lam_rel=lam_rel)
    return res["w_hat"], b_eff, res


def mlp_loss(wu, bu, wd, bd, x, y):
    yh = wd @ _relu(wu @ x + bu[:, None]) + bd[:, None]
    return linalg.frob2(yh - y)


def compress(wu, bu, wd, bd, x, ru, rd, n_iter=4,
             junction_kind="blockid", alpha=1.0, beta=1.0, gamma=1.0,
             lam_rel=1e-6):
    """Jointly compress (Wu, Wd) given calibration input X [d×l].

    Returns factored results for both projections + per-iteration MLP loss.
    """
    wu = np.asarray(wu, dtype=np.float64)
    wd = np.asarray(wd, dtype=np.float64)
    bu = np.zeros(wu.shape[0]) if bu is None else np.asarray(bu, np.float64)
    bd = np.zeros(wd.shape[0]) if bd is None else np.asarray(bd, np.float64)
    x = np.asarray(x, dtype=np.float64)

    z_teacher = wu @ x + bu[:, None]
    zp_teacher = _relu(z_teacher)
    y = wd @ zp_teacher + bd[:, None]

    # Init: local root-cov ASVD of both layers (the non-joint baseline).
    res_u = asvd.compress(wu, ru, kind="rootcov", junction_kind=junction_kind,
                          x=x, bias=bu, lam_rel=lam_rel)
    res_d = asvd.compress(wd, rd, kind="rootcov", junction_kind=junction_kind,
                          x=zp_teacher, bias=bd, lam_rel=lam_rel)
    wu_hat, bu_hat = res_u["w_hat"], res_u["bias"]
    wd_hat, bd_hat = res_d["w_hat"], res_d["bias"]

    losses = [mlp_loss(wu_hat, bu_hat, wd_hat, bd_hat, x, y)]
    z = wu_hat @ x + bu_hat[:, None]

    best = (losses[0], wu_hat, bu_hat, wd_hat, bd_hat, res_u, res_d)
    for _ in range(max(0, n_iter)):
        # --- Z′ update (Eq 21) given Ŵd, Z.
        di = wd_hat.shape[1]
        m = gamma * (wd_hat.T @ wd_hat) + beta * np.eye(di)
        rhs = beta * _relu(z) + gamma * (wd_hat.T @ (y - bd_hat[:, None]))
        zp = np.linalg.solve(m, rhs)

        # --- Z update (Eq 22), branch chosen by pointwise decoupled loss.
        z_lin = wu_hat @ x + bu_hat[:, None]
        z_pos = (alpha * z_lin + beta * zp) / (alpha + beta)
        z_pos = np.maximum(z_pos, 0.0)   # positive branch must satisfy z≥0
        z_neg = np.minimum(z_lin, 0.0)   # negative branch must satisfy z≤0
        loss_pos = alpha * (z_pos - z_lin) ** 2 + beta * (zp - z_pos) ** 2
        loss_neg = alpha * (z_neg - z_lin) ** 2 + beta * zp ** 2
        z = np.where(loss_pos <= loss_neg, z_pos, z_neg)

        # --- Refit Ŵu from (X → Z) and Ŵd from (Z′ → Y), App H.
        wu_hat, bu_hat, res_u = _fit_effective(z, x, ru, junction_kind, lam_rel)
        wd_hat, bd_hat, res_d = _fit_effective(y, zp, rd, junction_kind, lam_rel)

        cur = mlp_loss(wu_hat, bu_hat, wd_hat, bd_hat, x, y)
        losses.append(cur)
        if cur < best[0]:
            best = (cur, wu_hat, bu_hat, wd_hat, bd_hat, res_u, res_d)

    _, wu_hat, bu_hat, wd_hat, bd_hat, res_u, res_d = best
    return {
        "wu_hat": wu_hat, "bu": bu_hat, "wd_hat": wd_hat, "bd": bd_hat,
        "res_u": res_u, "res_d": res_d,
        "losses": losses, "loss": best[0],
        "params": res_u["params"] + res_d["params"],
    }
