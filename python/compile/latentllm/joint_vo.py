"""Joint VO compression (paper §4.2, App G).

Per-head loss for arbitrary attention weights (Eq 184):

    L₃ = Σᵢ ‖ Wo,i Wv,i C½ − Bo (Ao,i Bv,i) (Av C½) ‖²,
    Gᵢ = Wo,i Wv,i C½  ∈ R^{d'×d}

solved by alternating HOSVD (Eqs 185–188):

    Bo  = top-ro eigvecs[Σᵢ Gᵢ Av′ᵀ Av′ Gᵢᵀ]   (columns, d'×ro)
    Av′ = top-rv eigvecs[Σᵢ Gᵢᵀ Bo Boᵀ Gᵢ]     (rows,    rv×d)
    Ao,i = Boᵀ Wo,i Jᵢ,   Bv,i = Jᵢ⁺ (Wv,i C½) Av′ᵀ,   Av = Av′ C^{-½}

Bias update (App G.1): run on the centered covariance C₀ and set
b̂o = bo + Σᵢ[Wo,i(Wv,iμ+bv,i) − Ŵo,i(Ŵv,iμ+bv,i)] (Eq 193; b̂v absorbed).

`combined()` is the single-SVD variant of Eq 183 (all heads merged), and the
contraction-order FLOP analysis of Eqs 17/18 lives in `contraction_flops`.
Remark 11: joint VO is typically *not* better than split V/O — we implement
both and the pipeline default follows the paper (split V/O); this module
backs the ablation bench.
"""

import numpy as np

from . import linalg, precond


def _split_heads(w, n, dh, axis):
    w = np.asarray(w, dtype=np.float64)
    if axis == 0:
        return [w[i * dh:(i + 1) * dh] for i in range(n)]
    return [w[:, i * dh:(i + 1) * dh] for i in range(n)]


def compress(wv, wo, n_heads, d_h, rv, ro, n_iter=4, kind="rootcov",
             x=None, c=None, bv=None, bo=None, mu=None, lam_rel=1e-6,
             blockid=True):
    """wv: [h*d_h, d] value proj; wo: [d', h*d_h] output proj."""
    wv = np.asarray(wv, dtype=np.float64)
    wo = np.asarray(wo, dtype=np.float64)
    d = wv.shape[1]
    d_out = wo.shape[0]
    rv = int(min(rv, d))
    ro = int(min(ro, d_out))

    bias_aware = bv is not None and bo is not None and mu is not None
    if c is None:
        if x is not None:
            if bias_aware:
                c, mu = linalg.centered_covariance(x, lam_rel=lam_rel)
            else:
                c = linalg.covariance(x, lam_rel=lam_rel)
        else:
            c = np.eye(d)
    p, p_inv = precond.build(kind, x=x, c=c, lam_rel=lam_rel)

    v_heads = _split_heads(wv, n_heads, d_h, axis=0)
    o_heads = _split_heads(wo, n_heads, d_h, axis=1)
    g = [o_heads[i] @ (v_heads[i] @ p) for i in range(n_heads)]  # d'×d

    # Init Av′ from Σ Gᵀ G.
    av = linalg.topk_eigvecs(sum(gi.T @ gi for gi in g), rv)
    bo_m = None
    losses = []
    for _ in range(max(1, n_iter)):
        bo_m = linalg.topk_eigvecs(sum(gi @ (av.T @ (av @ gi.T)) for gi in g),
                                   ro).T  # d'×ro orthonormal columns
        av = linalg.topk_eigvecs(sum(gi.T @ (bo_m @ (bo_m.T @ gi)) for gi in g),
                                 rv)
        loss = sum(linalg.frob2(gi) - linalg.frob2(bo_m.T @ gi @ av.T)
                   for gi in g)
        losses.append(loss)

    ao = [bo_m.T @ oh for oh in o_heads]              # ro×d_h
    bv_f = [(vh @ p) @ av.T for vh in v_heads]        # d_h×rv
    av_f = av @ p_inv                                  # rv×d

    wv_hat = np.concatenate([b @ av_f for b in bv_f], axis=0)
    wo_hat = np.concatenate([bo_m @ a for a in ao], axis=1)

    new_bo = None
    if bias_aware:
        bv_heads = _split_heads(np.asarray(bv, dtype=np.float64).reshape(-1, 1),
                                n_heads, d_h, axis=0)
        vo_hat_heads = _split_heads(wv_hat, n_heads, d_h, axis=0)
        oo_hat_heads = _split_heads(wo_hat, n_heads, d_h, axis=1)
        new_bo = np.asarray(bo, dtype=np.float64).copy()
        for i in range(n_heads):
            new_bo += o_heads[i] @ (v_heads[i] @ mu + bv_heads[i][:, 0])
            new_bo -= oo_hat_heads[i] @ (vo_hat_heads[i] @ mu + bv_heads[i][:, 0])

    params = rv * d + ro * d_out + n_heads * d_h * (rv + ro)
    if blockid:
        params -= rv * rv + ro * ro + d_h * d_h * n_heads
    return {
        "Av": av_f, "Bv": bv_f, "Ao": ao, "Bo": bo_m,
        "bv": None if bv is None else np.asarray(bv, dtype=np.float64),
        "bo": new_bo,
        "wv_hat": wv_hat, "wo_hat": wo_hat,
        "losses": losses, "loss": losses[-1] if losses else None,
        "params": params, "rv": rv, "ro": ro,
    }


def combined(wv, wo, rank, kind="rootcov", x=None, c=None, lam_rel=1e-6):
    """Single-SVD joint VO (Eq 183): factor Wo Wv C½ with one rank-r SVD."""
    wv = np.asarray(wv, dtype=np.float64)
    wo = np.asarray(wo, dtype=np.float64)
    d = wv.shape[1]
    if c is None:
        c = linalg.covariance(x, lam_rel=lam_rel) if x is not None else np.eye(d)
    p, p_inv = precond.build(kind, x=x, c=c, lam_rel=lam_rel)
    m = wo @ wv @ p
    u, s, vt = linalg.svd_truncated(m, int(rank))
    w_hat = (u * s) @ vt @ p_inv   # effective Wo·Wv product
    loss = linalg.frob2(m) - float(np.sum(s**2))
    return {"w_hat_product": w_hat, "loss": loss, "rank": int(rank)}


def contraction_flops(d, d_h, h, l, rv, ro):
    """MLA contraction-order complexities of Eq 17 vs Eq 18 (MAC counts).

    Returns (order_a, order_b, reduction): order_a applies attention after
    per-head value decompression (Eq 17); order_b applies attention on the
    shared latent and defers Bo (Eq 18). The paper's rule: if h·ro < rv the
    weighting should happen on the output compression side.
    """
    order_a = l * d * rv + h * d_h * l * rv + h * d_h * l * l \
        + h * d_h * l * ro + h * d * l * ro
    order_b = l * d * rv + rv * l * l + h * d_h * l * rv \
        + h * d_h * l * ro + d * l * ro
    return order_a, order_b, order_a - order_b
