"""Junction matrices J (paper §3.3, App A.2).

Given the truncated SVD  U S V = svd_r[W P],  any J with S J J⁺ = S yields a
valid factorization  B = U S J,  A = J⁺ V P⁺  with identical loss. The
*block-identity* choice J = V₁ (left r×r block of V P⁺) turns A into
[I  V₁⁺V₂], saving r² parameters and r² MACs per token (paper Eq 9) — that
is the parameter accounting that makes low-rank compression always shrink
the model (r(d+d')−r² < d·d' for all r < min(d,d')).

Pivoting (Remark 4): when V₁ is ill-conditioned we greedily permute columns
(rank-revealing Gram-Schmidt) so the leading block is well conditioned; the
permutation costs no FLOPs at inference, only the stored index vector.
"""

import numpy as np

JUNCTIONS = ("left", "right", "sym", "blockid")


def _greedy_pivot(m, r):
    """Pick r column indices of m (r×d) making m[:, idx] well conditioned.

    Greedy rank-revealing selection: repeatedly take the column with the
    largest residual after projecting out the span of already-chosen ones.
    Returns an index array of length r.
    """
    m = np.asarray(m, dtype=np.float64)
    d = m.shape[1]
    q = np.zeros((m.shape[0], 0))
    resid = m.copy()
    chosen = []
    for _ in range(r):
        norms = np.sum(resid**2, axis=0)
        norms[chosen] = -1.0
        j = int(np.argmax(norms))
        chosen.append(j)
        v = m[:, j] - q @ (q.T @ m[:, j]) if q.shape[1] else m[:, j].copy()
        n = np.linalg.norm(v)
        if n < 1e-12:
            break
        v /= n
        q = np.concatenate([q, v[:, None]], axis=1)
        resid = resid - np.outer(v, v @ resid)
    while len(chosen) < r:  # degenerate fallback
        for j in range(d):
            if j not in chosen:
                chosen.append(j)
                break
    return np.array(chosen[:r], dtype=np.int64)


def apply(u, s, vt, p_inv, kind="blockid", pivot=True):
    """Build (B, A, info) from a truncated whitened SVD.

    u [d'×r], s [r], vt [r×d]: svd_r[W P];  p_inv: P⁺ [d×d].
    Returns B [d'×r], A [r×d] with Ŵ = B A, plus an info dict carrying the
    identity-block metadata for parameter/FLOP accounting.
    """
    r = s.shape[0]
    m = vt @ p_inv  # V P⁺, the "whitened right-singular" rows (r×d)
    info = {"kind": kind, "rank": r, "identity_cols": None, "perm": None}

    if kind == "left":
        return (u * s), m, info
    if kind == "right":
        return u, (m * s[:, None]), info
    if kind == "sym":
        rs = np.sqrt(s)
        return (u * rs), (m * rs[:, None]), info
    if kind == "blockid":
        if pivot:
            idx = _greedy_pivot(m, r)
        else:
            idx = np.arange(r)
        v1 = m[:, idx]
        # J = V₁  →  A = V₁⁺ [V₁ V₂] has an exact identity block at `idx`.
        v1_inv = np.linalg.pinv(v1)
        a = v1_inv @ m
        a[:, idx] = np.eye(r)  # exact by construction; kill fp residue
        b = (u * s) @ v1
        info["identity_cols"] = idx
        info["perm"] = idx
        return b, a, info
    raise ValueError(f"unknown junction {kind!r}")


def factor_params(d_out, d_in, r, blockid):
    """Parameter count of a (B,A) factor pair (paper §3.3)."""
    n = r * (d_out + d_in)
    return n - r * r if blockid else n
