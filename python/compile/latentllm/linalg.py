"""Small linear-algebra helpers shared by the compression algorithms.

All computations are float64 numpy; weights enter as W[out, in] matching the
paper's W ∈ R^{d'×d} acting on column activations y = W x.
"""

import numpy as np


def sym(c):
    return 0.5 * (c + c.T)


def sqrtm_psd(c, eps=1e-12):
    """Symmetric PSD matrix square root via eigendecomposition."""
    w, v = np.linalg.eigh(sym(np.asarray(c, dtype=np.float64)))
    w = np.clip(w, 0.0, None)
    return (v * np.sqrt(w)) @ v.T


def invsqrtm_psd(c, eps=1e-10):
    """Pseudo-inverse square root of a symmetric PSD matrix."""
    w, v = np.linalg.eigh(sym(np.asarray(c, dtype=np.float64)))
    wmax = max(float(w[-1]), 0.0)
    inv = np.where(w > eps * max(wmax, 1.0), 1.0 / np.sqrt(np.clip(w, 0, None)), 0.0)
    return (v * inv) @ v.T


def pinv(a, rcond=1e-10):
    return np.linalg.pinv(np.asarray(a, dtype=np.float64), rcond=rcond)


def topk_eigvecs(c, k):
    """Top-k eigenvectors of a symmetric matrix, as rows (k×d).

    This is `RightSingular_k[.]` of Algorithm 1 applied to a symmetric PSD
    accumulation matrix: right-singular vectors == eigenvectors.
    """
    w, v = np.linalg.eigh(sym(np.asarray(c, dtype=np.float64)))
    idx = np.argsort(w)[::-1][:k]
    return v[:, idx].T


def svd_truncated(m, r):
    """Rank-r truncated SVD. Returns (U[d'×r], s[r], Vt[r×d])."""
    u, s, vt = np.linalg.svd(np.asarray(m, dtype=np.float64), full_matrices=False)
    return u[:, :r], s[:r], vt[:r, :]


def frob2(m):
    m = np.asarray(m)
    return float(np.sum(m.astype(np.float64) ** 2))


def act_loss(w, w_hat, c):
    """Activation-aware loss tr[(W−Ŵ) C (W−Ŵ)ᵀ]  (paper Eq 4/35)."""
    d = np.asarray(w, dtype=np.float64) - np.asarray(w_hat, dtype=np.float64)
    return float(np.trace(d @ np.asarray(c, dtype=np.float64) @ d.T))


def covariance(x, lam_rel=1e-6, normalize=True):
    """C = (XXᵀ + λI)/l — shrunk auto-correlation of activations (Remark 3).

    x: [d, l] column-token activations. λ is relative to mean diagonal.
    """
    x = np.asarray(x, dtype=np.float64)
    l = x.shape[1]
    c = x @ x.T
    tr = np.trace(c) / max(c.shape[0], 1)
    c += lam_rel * max(tr, 1e-12) * np.eye(c.shape[0])
    if normalize:
        c /= max(l, 1)
    return sym(c)


def centered_covariance(x, lam_rel=1e-6):
    """C₀ = (X−μ1ᵀ)(X−μ1ᵀ)ᵀ/l + λI — used with bias updates (App B.2)."""
    x = np.asarray(x, dtype=np.float64)
    mu = x.mean(axis=1, keepdims=True)
    return covariance(x - mu, lam_rel=lam_rel), mu[:, 0]
