"""Whole-model compression pipeline (paper §5 experimental protocol).

Methods (Table 2 rows):
  plain        — identity pre-conditioner, local SVD, dense factors
  asvd_hessian — diagonal-Hessian pre-conditioner, local, dense factors
  asvd_l1      — diagonal ℓ1 (original ASVD), local, dense factors
  asvd_l2      — diagonal ℓ2 (WandA-style), local, dense factors
  asvd_cov     — covariance (CorDA-style), local, dense factors
  asvd_rootcov — root covariance (optimal, §3.2), local, dense factors
  latentllm    — root covariance + block-identity junction (§3.3) +
                 joint QK HOSVD (§4.1) + split V/O + joint UD (§4.3)

All linear layers in MHA and MLP are compressed to the target ratio
(paper: "we followed existing work and compressed all linear layers");
embeddings / layer norms are untouched. Biases are updated per App B.2/E.2.
"""

import numpy as np

from . import asvd, joint_qk, joint_ud, joint_vo, linalg, rank

METHODS = ("plain", "asvd_hessian", "asvd_l1", "asvd_l2", "asvd_cov",
           "asvd_rootcov", "latentllm", "latentllm_jointvo")

_PRECOND = {
    "plain": "identity",
    "asvd_hessian": "diag_hessian",
    "asvd_l1": "diag_l1",
    "asvd_l2": "diag_l2",
    "asvd_cov": "cov",
    "asvd_rootcov": "rootcov",
    "latentllm": "rootcov",
    "latentllm_jointvo": "rootcov",
}


def compress_model(cfg, weights, calib, method, ratio,
                   qk_iters=8, ud_iters=4, lam_rel=1e-6):
    """Compress every MHA/MLP linear of a MiniConfig model.

    weights: dict name→np.ndarray (configs.MiniConfig naming).
    calib: dict f"layers.{i}" → {"attn_x": [d,l], "o_x": [d,l],
                                 "mlp_x": [d,l]} raw activations.
    Returns (new_weights, report) — new_weights carries *effective* dense
    Ŵ (+ updated biases) for evaluation through the dense scoring program;
    report carries factors, ranks, per-layer losses, and param accounting.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}")
    keep = 1.0 - ratio
    pk = _PRECOND[method]
    is_latent = method.startswith("latentllm")
    junction_kind = "blockid" if is_latent else "left"

    new_w = dict(weights)
    report = {"method": method, "ratio": ratio, "layers": [],
              "orig_linear_params": 0, "new_linear_params": 0}

    d, dh, h = cfg.d, cfg.d_h, cfg.n_heads
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        cal = calib[f"layers.{i}"]
        x_attn, x_o, x_mlp = cal["attn_x"], cal["o_x"], cal["mlp_x"]
        lrep = {"layer": i}

        wq, wk = weights[p + "attn.wq"], weights[p + "attn.wk"]
        wv, wo = weights[p + "attn.wv"], weights[p + "attn.wo"]
        bq, bk = weights[p + "attn.bq"], weights[p + "attn.bk"]
        bv, bo = weights[p + "attn.bv"], weights[p + "attn.bo"]
        wu, wd = weights[p + "mlp.wu"], weights[p + "mlp.wd"]
        bu, bd = weights[p + "mlp.bu"], weights[p + "mlp.bd"]

        report["orig_linear_params"] += 4 * d * d + 2 * d * cfg.d_i

        if is_latent:
            # --- joint QK (§4.1)
            r_qk = rank.joint_qk_rank(d, dh, h, h, keep, blockid=True)
            jq = joint_qk.compress(
                wq, wk, n_kv_heads=h, d_h=dh, rq=r_qk, rk=r_qk,
                n_iter=qk_iters, kind=pk, x=x_attn,
                bq=bq, bk=bk, mu=np.asarray(x_attn).mean(axis=1),
                lam_rel=lam_rel)
            new_w[p + "attn.wq"] = jq["wq_hat"].astype(np.float32)
            new_w[p + "attn.wk"] = jq["wk_hat"].astype(np.float32)
            new_w[p + "attn.bq"] = jq["bq"].astype(np.float32)
            new_w[p + "attn.bk"] = jq["bk"].astype(np.float32)
            qk_params = rank.joint_qk_params(d, dh, h, h, r_qk, r_qk, True)
            lrep["qk"] = {"rank": r_qk, "loss": jq["loss"],
                          "losses": jq["losses"], "params": qk_params}
            lrep["qk_factors"] = jq

            if method == "latentllm_jointvo":
                # ablation variant (Remark 11 says this is usually worse)
                r_vo = rank.local_rank(d, d, keep, True)
                jv = joint_vo.compress(
                    wv, wo, n_heads=h, d_h=dh, rv=r_vo, ro=r_vo,
                    n_iter=ud_iters, kind=pk, x=x_attn,
                    bv=bv, bo=bo, mu=np.asarray(x_attn).mean(axis=1),
                    lam_rel=lam_rel)
                new_w[p + "attn.wv"] = jv["wv_hat"].astype(np.float32)
                new_w[p + "attn.wo"] = jv["wo_hat"].astype(np.float32)
                new_w[p + "attn.bo"] = jv["bo"].astype(np.float32)
                vo_params = jv["params"]
                lrep["vo"] = {"rank": r_vo, "loss": jv["loss"],
                              "params": vo_params}
            else:
                # paper's default: split V/O with root-cov + block identity
                r_v = rank.local_rank(d, d, keep, True)
                rv_res = asvd.compress(wv, r_v, kind=pk,
                                       junction_kind="blockid", x=x_attn,
                                       bias=bv, lam_rel=lam_rel)
                r_o = rank.local_rank(d, d, keep, True)
                ro_res = asvd.compress(wo, r_o, kind=pk,
                                       junction_kind="blockid", x=x_o,
                                       bias=bo, lam_rel=lam_rel)
                new_w[p + "attn.wv"] = rv_res["w_hat"].astype(np.float32)
                new_w[p + "attn.bv"] = rv_res["bias"].astype(np.float32)
                new_w[p + "attn.wo"] = ro_res["w_hat"].astype(np.float32)
                new_w[p + "attn.bo"] = ro_res["bias"].astype(np.float32)
                vo_params = rv_res["params"] + ro_res["params"]
                lrep["v"] = {"rank": r_v, "loss": rv_res["loss"]}
                lrep["o"] = {"rank": r_o, "loss": ro_res["loss"]}
                lrep["vo_factors"] = {"v": rv_res, "o": ro_res}

            # --- joint UD (§4.3)
            r_u = rank.local_rank(cfg.d_i, d, keep, True)
            r_d = rank.local_rank(d, cfg.d_i, keep, True)
            ud = joint_ud.compress(wu, bu, wd, bd, x_mlp, r_u, r_d,
                                   n_iter=ud_iters, junction_kind="blockid",
                                   lam_rel=lam_rel)
            new_w[p + "mlp.wu"] = ud["wu_hat"].astype(np.float32)
            new_w[p + "mlp.bu"] = ud["bu"].astype(np.float32)
            new_w[p + "mlp.wd"] = ud["wd_hat"].astype(np.float32)
            new_w[p + "mlp.bd"] = ud["bd"].astype(np.float32)
            lrep["ud"] = {"ranks": (r_u, r_d), "loss": ud["loss"],
                          "losses": ud["losses"], "params": ud["params"]}
            lrep["ud_factors"] = ud
            report["new_linear_params"] += qk_params + vo_params + ud["params"]
        else:
            # local compression of each of the six linears
            total = 0
            for name, w, b, x in (
                ("attn.wq", wq, bq, x_attn), ("attn.wk", wk, bk, x_attn),
                ("attn.wv", wv, bv, x_attn), ("attn.wo", wo, bo, x_o),
                ("mlp.wu", wu, bu, x_mlp),
            ):
                r = rank.local_rank(w.shape[0], w.shape[1], keep, False)
                res = asvd.compress(w, r, kind=pk, junction_kind=junction_kind,
                                    x=x, bias=b, lam_rel=lam_rel)
                new_w[p + name] = res["w_hat"].astype(np.float32)
                bname = p + name.replace("w", "b")
                new_w[bname] = res["bias"].astype(np.float32)
                total += res["params"]
                lrep[name] = {"rank": r, "loss": res["loss"]}
            # wd sees σ(Wu_orig x + bu) activations
            z = np.maximum(wu @ np.asarray(x_mlp, np.float64)
                           + np.asarray(bu, np.float64)[:, None], 0.0)
            r = rank.local_rank(d, cfg.d_i, keep, False)
            res = asvd.compress(wd, r, kind=pk, junction_kind=junction_kind,
                                x=z, bias=bd, lam_rel=lam_rel)
            new_w[p + "mlp.wd"] = res["w_hat"].astype(np.float32)
            new_w[p + "mlp.bd"] = res["bias"].astype(np.float32)
            total += res["params"]
            lrep["mlp.wd"] = {"rank": r, "loss": res["loss"]}
            report["new_linear_params"] += total

        report["layers"].append(lrep)

    report["achieved_ratio"] = 1.0 - (report["new_linear_params"]
                                      / max(report["orig_linear_params"], 1))
    return new_w, report
