"""Pre-conditioning matrices P for activation-aware SVD (paper §3.2, Table 1).

Each builder maps calibration activations X ∈ R^{d×l} to P ∈ R^{d×d} used as
svd_r[W P]; the optimal choice is the root covariance P = C^{1/2}
(paper Eq 5 / App B.1). All others are sub-optimal baselines reproduced for
Table 2 / Figs 7 & 16.
"""

import numpy as np

from . import linalg

PRECONDITIONERS = (
    "identity",      # plain SVD              [Denton'14; Sainath'13]
    "diag_hessian",  # diag[(XXᵀ+λI)^{-1}]^{-1/2}   [OBS; GPTQ; SparseGPT]
    "diag_l1",       # diag[Σ_j |X_ij|]^α            [ASVD; AWQ]
    "diag_l2",       # diag[XXᵀ]^{1/2}               [WandA]
    "cov",           # XXᵀ + λI                      [CorDA]
    "rootcov",       # (XXᵀ + λI)^{1/2}              [LatentLLM — optimal]
)


def build(kind, x=None, c=None, lam_rel=1e-6, alpha=0.5):
    """Return (P, P⁺) for the given pre-conditioner kind.

    Either raw activations `x` [d×l] or a covariance `c` [d×d] must be given
    (diag_l1 needs raw activations; it falls back to sqrt-diag of C if only C
    is available, which matches the ℓ1≈ℓ2 diagonal family).
    """
    if c is None:
        if x is None:
            raise ValueError("need x or c")
        c = linalg.covariance(x, lam_rel=lam_rel)
    c = np.asarray(c, dtype=np.float64)
    d = c.shape[0]

    if kind == "identity":
        p = np.eye(d)
        return p, p
    if kind == "diag_hessian":
        h = np.linalg.inv(c + 1e-10 * np.eye(d))
        dg = np.clip(np.diag(h), 1e-30, None) ** -0.5
        return np.diag(dg), np.diag(1.0 / dg)
    if kind == "diag_l1":
        if x is not None:
            dg = np.abs(np.asarray(x, dtype=np.float64)).sum(axis=1)
            dg /= max(x.shape[1], 1)
        else:
            dg = np.sqrt(np.clip(np.diag(c), 0, None))
        dg = np.clip(dg, 1e-30, None) ** alpha
        return np.diag(dg), np.diag(1.0 / dg)
    if kind == "diag_l2":
        dg = np.sqrt(np.clip(np.diag(c), 1e-30, None))
        return np.diag(dg), np.diag(1.0 / dg)
    if kind == "cov":
        return c, linalg.pinv(c)
    if kind == "rootcov":
        p = linalg.sqrtm_psd(c)
        return p, linalg.invsqrtm_psd(c)
    raise ValueError(f"unknown preconditioner {kind!r}")
