"""Quantization-aware distillation of the low-rank factors (paper App I.1).

Chunk-wise q-bit uniform quantization (Eq 242) plus STE-style projected
gradient refinement of B, A against the activation loss — in a non-autograd
setting STE reduces to projected gradient descent with the quantizer as the
projection.
"""

import numpy as np

from . import linalg


def quantize_uniform(x, bits, chunk=64):
    """Chunk-wise min/max uniform quantization along the last axis."""
    x = np.asarray(x, dtype=np.float64)
    flat = x.reshape(-1)
    n = flat.size
    out = np.empty_like(flat)
    levels = (1 << bits) - 1
    for s in range(0, n, chunk):
        seg = flat[s:s + chunk]
        lo, hi = float(seg.min()), float(seg.max())
        if hi - lo < 1e-12:
            out[s:s + chunk] = seg
            continue
        scale = levels / (hi - lo)
        out[s:s + chunk] = np.round((seg - lo) * scale) / scale + lo
    return out.reshape(x.shape)


def quantize_factors(b, a, w, c, bits=4, chunk=64, n_iter=20):
    """Quantize (B, A) then STE-refine against ‖(BA−W)C½‖².

    Returns (Bq, Aq, history) where history[0] is the post-quantization loss
    (no refinement) and history[-1] the refined loss.
    """
    b = np.asarray(b, dtype=np.float64).copy()
    a = np.asarray(a, dtype=np.float64).copy()
    w = np.asarray(w, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)

    bq = quantize_uniform(b, bits, chunk)
    aq = quantize_uniform(a, bits, chunk)
    hist = [linalg.act_loss(w, bq @ aq, c)]
    lmax = float(np.linalg.eigvalsh(c)[-1])
    fb, fa = b.copy(), a.copy()   # latent full-precision shadows (STE state)
    for _ in range(n_iter):
        e = (bq @ aq - w) @ c
        gb = 2.0 * e @ aq.T
        ga = 2.0 * bq.T @ e
        lb = 2.0 * lmax * max(float(np.sum(aq * aq)), 1e-12)
        la = 2.0 * lmax * max(float(np.sum(bq * bq)), 1e-12)
        fb -= gb / lb
        fa -= ga / la
        bq = quantize_uniform(fb, bits, chunk)
        aq = quantize_uniform(fa, bits, chunk)
        hist.append(linalg.act_loss(w, bq @ aq, c))
    return bq, aq, hist
