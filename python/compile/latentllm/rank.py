"""Compression-ratio → rank solvers (paper §3.3 parameter accounting).

`keep` is the fraction of the original weight's parameters retained
(keep = 1 − compression_ratio). Counts follow the paper exactly:

  dense factors  : r (d + d')
  block identity : r (d + d') − r²                       (Eq 9)
  joint QK       : (rq+rk)(d + d_h·h) − rq² − rk² − d_h²·h   (§4.1)
"""

import math


def local_rank(d_out, d_in, keep, blockid):
    """Rank for one linear so factor params ≈ keep·d_out·d_in."""
    target = keep * d_out * d_in
    s = d_out + d_in
    if blockid:
        disc = s * s - 4.0 * target
        r = (s - math.sqrt(max(disc, 0.0))) / 2.0
    else:
        r = target / s
    r = int(round(r))
    return max(1, min(r, min(d_out, d_in)))


def local_params(d_out, d_in, r, blockid):
    n = r * (d_out + d_in)
    return n - r * r if blockid else n


def joint_qk_rank(d, d_h, n_q_heads, n_kv_heads, keep, blockid=True):
    """Shared rank rq = rk = r for the joint QK factorization."""
    orig = d * d_h * (n_q_heads + n_kv_heads)
    target = keep * orig
    s = 2 * d + d_h * (n_q_heads + n_kv_heads)
    credit = d_h * d_h * min(n_q_heads, n_kv_heads) if blockid else 0
    if blockid:
        # 2r² − s·r + (target + credit) = 0, take the smaller root.
        disc = s * s - 8.0 * (target + credit)
        if disc < 0:
            return min(d, d_h * min(n_q_heads, n_kv_heads))
        r = (s - math.sqrt(disc)) / 4.0
    else:
        r = target / s
    r = int(round(r))
    return max(1, min(r, d))


def joint_qk_params(d, d_h, n_q_heads, n_kv_heads, rq, rk, blockid=True):
    n = (rq + rk) * d + n_q_heads * d_h * rq + n_kv_heads * d_h * rk
    if blockid:
        n -= rq * rq + rk * rk + d_h * d_h * min(n_q_heads, n_kv_heads)
    return n
