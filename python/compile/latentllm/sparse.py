"""Sparse and low-rank+sparse approximation (paper App I).

Ŵ = B A + D with ‖D‖₀ ≤ κ, activation-aware loss ‖(Ŵ−W)C½‖².
Solvers:
  * FISTA with soft-shrinkage (Eqs 233–235),
  * projected gradient with hard-shrink top-κ (the STE variant, Eq 237 —
    in a non-autograd setting STE == projected GD),
  * soft-shrink gradient descent (the differentiable variant of Fig 13),
  * alternating low-rank + sparse (Fig 14) and sparsified-factor (Fig 15),
  * WandA-style diagonal-C ablation (Eq 238, Fig 16).
"""

import numpy as np

from . import linalg


def hard_topk(m, k):
    """Keep the k entries of largest magnitude (global), zero the rest."""
    m = np.asarray(m, dtype=np.float64)
    if k <= 0:
        return np.zeros_like(m)
    if k >= m.size:
        return m.copy()
    flat = np.abs(m).ravel()
    thresh = np.partition(flat, m.size - k)[m.size - k]
    out = np.where(np.abs(m) >= thresh, m, 0.0)
    # tie-breaking may keep a few extra entries; trim deterministically
    extra = int((out != 0).sum()) - k
    if extra > 0:
        idx = np.argwhere((np.abs(m) == thresh).ravel()).ravel()[:extra]
        flat_out = out.ravel()
        flat_out[idx] = 0.0
        out = flat_out.reshape(m.shape)
    return out


def soft_shrink(m, alpha):
    m = np.asarray(m, dtype=np.float64)
    return np.sign(m) * np.maximum(np.abs(m) - alpha, 0.0)


def sparse_loss(w, d, c, ba=None):
    ba = 0.0 if ba is None else ba
    return linalg.act_loss(w, d + ba, c)


def fista(w, c, kappa, ba=None, n_iter=50, lam=None):
    """FISTA soft-shrink solve of Eq 232. λ is auto-tuned to land near the
    target sparsity κ by bisection over a few outer rounds (the paper notes
    tuning λ is the method's weakness — reproduced faithfully)."""
    w = np.asarray(w, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    resid = w - (0.0 if ba is None else ba)
    lmax = float(np.linalg.eigvalsh(c)[-1])
    step = 1.0 / (2.0 * max(lmax, 1e-12))

    def run(lam_):
        d = np.zeros_like(w)
        yk = d.copy()
        t = 1.0
        for _ in range(n_iter):
            grad = 2.0 * (yk - resid) @ c
            d_new = soft_shrink(yk - step * grad, lam_ * step)
            t_new = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * t * t))
            yk = d_new + ((t - 1.0) / t_new) * (d_new - d)
            d, t = d_new, t_new
        return d

    if lam is not None:
        d = run(lam)
        return d, sparse_loss(w, d, c, ba)
    lo, hi = 1e-8, float(np.abs(2.0 * resid @ c).max()) + 1e-6
    d = np.zeros_like(w)
    for _ in range(12):
        mid = np.sqrt(lo * hi)
        d = run(mid)
        nnz = int((d != 0).sum())
        if nnz > kappa:
            lo = mid
        else:
            hi = mid
    d = run(hi)
    return d, sparse_loss(w, d, c, ba)


def projected_gd(w, c, kappa, ba=None, n_iter=60, shrink="hard"):
    """Projected gradient: D ← Π[D − η∇];  Π = hard top-κ (STE, Eq 237) or
    soft-shrink tuned to κ. Deterministic target sparsity, unlike FISTA."""
    w = np.asarray(w, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    resid = w - (0.0 if ba is None else ba)
    lmax = float(np.linalg.eigvalsh(c)[-1])
    step = 1.0 / (2.0 * max(lmax, 1e-12))
    d = hard_topk(resid, kappa)
    for _ in range(n_iter):
        grad = 2.0 * (d - resid) @ c
        d = d - step * grad
        if shrink == "hard":
            d = hard_topk(d, kappa)
        else:
            flat = np.abs(d).ravel()
            if kappa < d.size:
                alpha = np.partition(flat, d.size - kappa)[d.size - kappa]
                d = soft_shrink(d, alpha * 0.5)
                d = hard_topk(d, kappa)
    return d, sparse_loss(w, d, c, ba)


def wanda_diag(w, c, kappa):
    """WandA/SparseGPT-style one-shot: diagonal-C importance |W|·diag(C)^½
    (Eq 238 ablation — degraded vs full-C iterative, Fig 16)."""
    w = np.asarray(w, dtype=np.float64)
    imp = np.abs(w) * np.sqrt(np.clip(np.diag(c), 0, None))[None, :]
    mask = hard_topk(imp, kappa) != 0
    d = np.where(mask, w, 0.0)
    return d, sparse_loss(w, d, c)


def lowrank_plus_sparse(w, c, rank, kappa, n_iter=6, solver="hard"):
    """Alternate svd_r[(W−D)C½] and sparse fit of (W−BA) (App I / Fig 14)."""
    from . import asvd
    w = np.asarray(w, dtype=np.float64)
    d = np.zeros_like(w)
    ba = np.zeros_like(w)
    hist = []
    for _ in range(n_iter):
        res = asvd.compress(w - d, rank, kind="rootcov",
                            junction_kind="left", c=c)
        ba = res["w_hat"]
        if solver == "fista":
            d, _ = fista(w - ba, c, kappa, n_iter=30)
        else:
            d, _ = projected_gd(w - ba, c, kappa, n_iter=30)
        hist.append(linalg.act_loss(w, ba + d, c))
    return ba, d, hist


def sparsify_factors(b, a, w, c, keep_frac, n_iter=40):
    """Fig 15: hard-sparsify the low-rank factors B, A themselves with
    alternating projected refits against the activation loss."""
    b = np.asarray(b, dtype=np.float64).copy()
    a = np.asarray(a, dtype=np.float64).copy()
    w = np.asarray(w, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    kb = max(1, int(keep_frac * b.size))
    ka = max(1, int(keep_frac * a.size))
    lmax = float(np.linalg.eigvalsh(c)[-1])
    hist = []
    for _ in range(n_iter):
        # grad wrt B: 2 (BA−W) C Aᵀ ; wrt A: 2 Bᵀ (BA−W) C
        e = (b @ a - w) @ c
        gb = 2.0 * e @ a.T
        ga = 2.0 * b.T @ e
        lb = 2.0 * lmax * max(float(np.sum(a * a)), 1e-12)
        la = 2.0 * lmax * max(float(np.sum(b * b)), 1e-12)
        b = hard_topk(b - gb / lb, kb)
        a = hard_topk(a - ga / la, ka)
        hist.append(linalg.act_loss(w, b @ a, c))
    return b, a, hist
