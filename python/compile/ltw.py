"""LTW1 — the weight/tensor interchange format between python and rust.

Little-endian binary:
  magic b"LTW1" | u32 n_tensors | per tensor:
    u16 name_len | name utf-8 | u8 dtype (0=f32, 1=i32) | u8 ndim
    | u32 dims... | raw data (C order)
See DESIGN.md §5 and rust/src/model/io.rs (the reader).
"""

import struct

import numpy as np

MAGIC = b"LTW1"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_ltw(path, tensors):
    """tensors: dict[str, np.ndarray] (f32 or i32). Insertion order kept."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.tobytes())


def read_ltw(path):
    out = {}
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, f"bad magic in {path}"
    (n,) = struct.unpack_from("<I", data, 4)
    off = 8
    for _ in range(n):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode("utf-8")
        off += nlen
        code, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        dt = _DTYPES[code]
        count = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype=dt, count=count, offset=off)
        off += count * 4
        out[name] = arr.reshape(dims)
    return out
