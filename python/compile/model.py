"""L2: OPT-mini transformer in JAX (dense and latent/MLA variants).

Architecture (matches OPT, paper Table 5, at mini scale): learned positional
embeddings, pre-LN, ReLU MLP, biases on every linear, tied LM head.

Two execution paths:
  * `use_pallas=False` — pure jnp, used for training (fast under jit);
  * `use_pallas=True`  — routes matmul/attention through the L1 Pallas
    kernels (interpret=True); this is the path lowered by aot.py into the
    HLO artifacts the rust runtime executes, so the kernels are *in* the
    deployed program.

All weights follow the paper's convention W ∈ R^{d'×d}, y = W x, stored
[out, in]; activations inside the model are row-token matrices [.., t, d],
so applications read `x @ w.T + b`.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import configs
from .kernels import attention as attn_k
from .kernels import lowrank as lr_k


def init_params(cfg: configs.MiniConfig, seed=0):
    """He/scaled-normal init, numpy dict keyed per configs.param_names()."""
    rng = np.random.default_rng(seed)
    shapes = cfg.shapes()
    params = {}
    for name, shape in shapes.items():
        if name.endswith((".g",)):
            params[name] = np.ones(shape, dtype=np.float32)
        elif name.endswith((".b", "bq", "bk", "bv", "bo", "bu", "bd")) \
                and len(shape) == 1:
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            fan_in = shape[-1]
            scale = 1.0 / np.sqrt(fan_in)
            if name.endswith("attn.wo") or name.endswith("mlp.wd"):
                scale /= np.sqrt(2.0 * cfg.n_layers)  # GPT-2 style
            params[name] = rng.normal(0.0, scale, size=shape) \
                .astype(np.float32)
    return params


def _ln(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _heads(x, h):
    t, d = x.shape
    return x.reshape(t, h, d // h).transpose(1, 0, 2)  # [h, t, d_h]


def _unheads(x):
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def _mha_jnp(q, k, v):
    from .kernels import ref
    return ref.mha(q, k, v, causal=True)


def forward(cfg, params, tokens, use_pallas=False, collect=False):
    """Single-sequence forward. tokens: [t] int32 → logits [t, vocab].

    With collect=True also returns the calibration activations the
    compression pipeline needs: per layer attn_x / o_x / mlp_x as [d, t]
    column-token matrices (paper §5 calibration protocol).
    """
    t = tokens.shape[0]
    h = cfg.n_heads
    x = params["tok_emb"][tokens] + params["pos_emb"][:t]
    cal = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        xa = _ln(x, params[p + "ln1.g"], params[p + "ln1.b"])
        q = xa @ params[p + "attn.wq"].T + params[p + "attn.bq"]
        k = xa @ params[p + "attn.wk"].T + params[p + "attn.bk"]
        v = xa @ params[p + "attn.wv"].T + params[p + "attn.bv"]
        if use_pallas:
            ctx = attn_k.mha(_heads(q, h), _heads(k, h), _heads(v, h))
        else:
            ctx = _mha_jnp(_heads(q, h), _heads(k, h), _heads(v, h))
        ctx = _unheads(ctx)
        x = x + ctx @ params[p + "attn.wo"].T + params[p + "attn.bo"]

        xm = _ln(x, params[p + "ln2.g"], params[p + "ln2.b"])
        z = jnp.maximum(xm @ params[p + "mlp.wu"].T + params[p + "mlp.bu"],
                        0.0)
        x = x + z @ params[p + "mlp.wd"].T + params[p + "mlp.bd"]
        if collect:
            cal.append({"attn_x": xa.T, "o_x": ctx.T, "mlp_x": xm.T})
    x = _ln(x, params["lnf.g"], params["lnf.b"])
    logits = x @ params["tok_emb"].T
    return (logits, cal) if collect else logits


def nll(cfg, params, tokens, use_pallas=False):
    """Mean next-token negative log-likelihood of one sequence [t]."""
    logits = forward(cfg, params, tokens, use_pallas=use_pallas)
    lp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    return -jnp.take_along_axis(lp, tgt[:, None], axis=-1).mean()


def batch_nll(cfg, params, tokens, use_pallas=False):
    """tokens [b, t] → per-sequence mean NLL [b] (the `score` program)."""
    return jax.vmap(lambda s: nll(cfg, params, s, use_pallas=use_pallas))(
        tokens)


def step_logits(cfg, params, tokens, lens, use_pallas=False):
    """tokens [b, t] padded, lens [b] → next-token logits [b, vocab]
    (the `step` program used by the serving coordinator)."""
    logits = jax.vmap(
        lambda s: forward(cfg, params, s, use_pallas=use_pallas))(tokens)
    idx = jnp.clip(lens - 1, 0, tokens.shape[1] - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Latent (MLA) architecture — the deployed form of a LatentLLM-compressed
# model: shared compression planes A*, per-head cores/decompressors, latent
# KV cache semantics (paper §4.1/4.2, Fig 1b).
# ---------------------------------------------------------------------------

def latent_param_names(cfg, ranks):
    """Deterministic parameter order for the latent scoring/step programs.

    ranks: dict with rq, rk, rv, ro, ru, rd (uniform across layers)."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        names += [
            p + "ln1.g", p + "ln1.b",
            p + "attn.aq", p + "attn.bq_heads", p + "attn.bq",
            p + "attn.ak", p + "attn.bk_heads", p + "attn.bk",
            p + "attn.av", p + "attn.bv_heads", p + "attn.bv",
            p + "attn.ao_heads", p + "attn.bo_mat", p + "attn.bo",
            p + "ln2.g", p + "ln2.b",
            p + "mlp.au", p + "mlp.bu_mat", p + "mlp.bu",
            p + "mlp.ad", p + "mlp.bd_mat", p + "mlp.bd",
        ]
    names += ["lnf.g", "lnf.b"]
    return names


def latent_shapes(cfg, ranks):
    d, dh, h, di = cfg.d, cfg.d_h, cfg.n_heads, cfg.d_i
    rq, rk, rv, ro = ranks["rq"], ranks["rk"], ranks["rv"], ranks["ro"]
    ru, rd = ranks["ru"], ranks["rd"]
    s = {"tok_emb": (cfg.vocab, d), "pos_emb": (cfg.max_len, d)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        s[p + "ln1.g"] = (d,)
        s[p + "ln1.b"] = (d,)
        s[p + "attn.aq"] = (rq, d)
        s[p + "attn.bq_heads"] = (h, dh, rq)
        s[p + "attn.bq"] = (d,)
        s[p + "attn.ak"] = (rk, d)
        s[p + "attn.bk_heads"] = (h, dh, rk)
        s[p + "attn.bk"] = (d,)
        s[p + "attn.av"] = (rv, d)
        s[p + "attn.bv_heads"] = (h, dh, rv)
        s[p + "attn.bv"] = (d,)
        s[p + "attn.ao_heads"] = (ro, h * dh)
        s[p + "attn.bo_mat"] = (d, ro)
        s[p + "attn.bo"] = (d,)
        s[p + "ln2.g"] = (d,)
        s[p + "ln2.b"] = (d,)
        s[p + "mlp.au"] = (ru, d)
        s[p + "mlp.bu_mat"] = (di, ru)
        s[p + "mlp.bu"] = (di,)
        s[p + "mlp.ad"] = (rd, di)
        s[p + "mlp.bd_mat"] = (d, rd)
        s[p + "mlp.bd"] = (d,)
    s["lnf.g"] = (d,)
    s["lnf.b"] = (d,)
    return s


def latent_forward(cfg, params, tokens, use_pallas=True):
    """Latent/MLA forward for one sequence [t] → logits [t, vocab].

    Attention scores run in latent space through the absorbed cores
    Hᵢ = Bq,iᵀBk,i; the per-token KV state is (A_k x, A_v x) of size
    r_k + r_v — the cache the coordinator accounts for.
    """
    t = tokens.shape[0]
    h = cfg.n_heads
    x = params["tok_emb"][tokens] + params["pos_emb"][:t]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        xa = _ln(x, params[p + "ln1.g"], params[p + "ln1.b"])
        aq, ak, av = params[p + "attn.aq"], params[p + "attn.ak"], \
            params[p + "attn.av"]
        bqh, bkh, bvh = params[p + "attn.bq_heads"], \
            params[p + "attn.bk_heads"], params[p + "attn.bv_heads"]
        q_lat = xa @ aq.T                       # [t, rq]
        ck = xa @ ak.T                          # [t, rk]  latent K cache
        cv = xa @ av.T                          # [t, rv]  latent V cache
        # QKV biases survive the latent path through bilinear augmentation:
        # score = [q_lat;1]ᵀ [[Hᵢ, Bq,iᵀbk,i],[bq,iᵀBk,i, bq,iᵀbk,i]] [c_k;1]
        # and values via c̃v = [cv 1], B̃v,i = [Bv,i  bv,i].
        bq_h = params[p + "attn.bq"].reshape(h, cfg.d_h)
        bk_h = params[p + "attn.bk"].reshape(h, cfg.d_h)
        bv_h = params[p + "attn.bv"].reshape(h, cfg.d_h)
        h_core = jnp.einsum("hdq,hdk->hqk", bqh, bkh)
        top = jnp.concatenate(
            [h_core, jnp.einsum("hdq,hd->hq", bqh, bk_h)[:, :, None]],
            axis=2)
        bot = jnp.concatenate(
            [jnp.einsum("hd,hdk->hk", bq_h, bkh),
             jnp.einsum("hd,hd->h", bq_h, bk_h)[:, None]],
            axis=1)[:, None, :]
        h_aug = jnp.concatenate([top, bot], axis=1)      # [h, rq+1, rk+1]
        ones = jnp.ones((t, 1), dtype=x.dtype)
        q_aug = jnp.concatenate([q_lat, ones], axis=1)
        ck_aug = jnp.concatenate([ck, ones], axis=1)
        cv_aug = jnp.concatenate([cv, ones], axis=1)
        bv_aug = jnp.concatenate([bvh, bv_h[:, :, None]], axis=2)
        if use_pallas:
            ctx = attn_k.latent_attention(q_aug, ck_aug, cv_aug, h_aug,
                                          bv_aug)
        else:
            from .kernels import ref
            ctx = ref.latent_attention(q_aug, ck_aug, cv_aug, h_aug, bv_aug)
        ctx = _unheads(ctx)
        ao = params[p + "attn.ao_heads"]        # [ro, h*dh]
        bo = params[p + "attn.bo_mat"]          # [d, ro]
        x = x + (ctx @ ao.T) @ bo.T + params[p + "attn.bo"]

        xm = _ln(x, params[p + "ln2.g"], params[p + "ln2.b"])
        if use_pallas:
            z = lr_k.lowrank_matmul(xm, params[p + "mlp.au"],
                                    params[p + "mlp.bu_mat"],
                                    params[p + "mlp.bu"])
            z = jnp.maximum(z, 0.0)
            y = lr_k.lowrank_matmul(z, params[p + "mlp.ad"],
                                    params[p + "mlp.bd_mat"],
                                    params[p + "mlp.bd"])
        else:
            z = jnp.maximum((xm @ params[p + "mlp.au"].T)
                            @ params[p + "mlp.bu_mat"].T
                            + params[p + "mlp.bu"], 0.0)
            y = (z @ params[p + "mlp.ad"].T) @ params[p + "mlp.bd_mat"].T \
                + params[p + "mlp.bd"]
        x = x + y
    x = _ln(x, params["lnf.g"], params["lnf.b"])
    return x @ params["tok_emb"].T


def latent_batch_nll(cfg, params, tokens, use_pallas=True):
    def one(s):
        logits = latent_forward(cfg, params, s, use_pallas=use_pallas)
        lp = jax.nn.log_softmax(logits[:-1], axis=-1)
        return -jnp.take_along_axis(lp, s[1:, None], axis=-1).mean()
    return jax.vmap(one)(tokens)


def latent_step_logits(cfg, params, tokens, lens, use_pallas=True):
    logits = jax.vmap(
        lambda s: latent_forward(cfg, params, s, use_pallas=use_pallas))(
        tokens)
    idx = jnp.clip(lens - 1, 0, tokens.shape[1] - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
