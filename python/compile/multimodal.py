"""llava-mini: tiny LLaVa-style multimodal model + synthetic ScienceQA
(the Table 4 / Fig 6 substitution — DESIGN.md §2).

Structure mirrors LLaVa: a CLIP-style ViT encodes the image into patch
tokens, a projector maps them into the LM embedding space, they are
prepended to the question tokens, and the LM's final hidden state answers a
4-way multiple-choice question.

Synthetic ScienceQA: 8 image pattern classes; each question asks which
class is present, with the evidence delivered through one of three context
modalities — IMG (in the image), TXT (a context token names the class), or
NO (the class must be recalled from a memorized question-fact table). The
paper's category breakdown is reproduced: subjects NAT/SOC/LAN shift the
fact-space size and modality mix (LAN: more context-less questions, larger
fact space), grades G1-6/G7-12 control noise/fact difficulty — so accuracy
ordering NAT>SOC>LAN, TXT>IMG>NO, G1-6>G7-12 emerges for the same reasons
it does in the paper (harder evidence, not different code paths).

Simplification vs the paper's 4-option letter format: the answer head
predicts the *class concept* (8-way) rather than the option letter — a tiny
model learns concept retrieval but not letter/pointer binding within this
build budget; the compression-degradation story (what Table 4 measures) is
unchanged. Chance level is therefore 12.5%.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import configs
from .model import _heads, _ln, _unheads
from .train import adam_init, adam_step

N_CLASSES = 8
CLS_TOK = 10          # tokens 10..17 name the 8 classes
SUBJ_TOK = 30         # 30/31/32 = NAT/SOC/LAN
GRADE_TOK = 35        # 35/36 = G1-6/G7-12
NEUTRAL_TOK = 40
FACT_TOK = 50         # fact tokens 50.. (question identity for NO-context)
BOS = 1
TEXT_LEN = 24
SUBJECTS = ("NAT", "SOC", "LAN")
MODALITIES = ("TXT", "IMG", "NO")
GRADES = ("G1-6", "G7-12")

# per-subject: (p_txt, p_img, p_no, n_facts_easy, n_facts_hard)
_SUBJ = {
    0: (0.4, 0.4, 0.2, 16, 48),    # NAT
    1: (0.35, 0.35, 0.3, 24, 64),  # SOC
    2: (0.25, 0.25, 0.5, 32, 96),  # LAN
}


def render_image(cls, noise, rng):
    """16×16 pattern for one of the 8 classes."""
    i, j = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    pats = [
        (i // 2) % 2, (j // 2) % 2, ((i // 2) + (j // 2)) % 2,
        ((i + j) // 4) % 2, (i < 8).astype(int), (j < 8).astype(int),
        ((np.abs(i - 8) < 4) & (np.abs(j - 8) < 4)).astype(int),
        ((i < 2) | (i > 13) | (j < 2) | (j > 13)).astype(int),
    ]
    img = pats[cls].astype(np.float32) * 2.0 - 1.0
    return img + noise * rng.normal(size=(16, 16)).astype(np.float32)


def make_dataset(n, seed=0):
    """Returns dict with images [n,16,16], tokens [n,TEXT_LEN] i32,
    labels [n] i32 (option index 0..3), cats [n,3] i32 (subj, mod, grade),
    and the fact tables used (so train/test share them)."""
    rng = np.random.default_rng(seed)
    # fact tables: fact id -> class, per subject (sized per difficulty).
    # Fixed seed: the "world knowledge" is shared between train and test —
    # NO-context questions test recall of these memorized facts.
    frng = np.random.default_rng(20250711)
    fact_cls = {s: frng.integers(0, N_CLASSES, size=_SUBJ[s][4])
                for s in range(3)}
    images = np.zeros((n, 16, 16), dtype=np.float32)
    tokens = np.zeros((n, TEXT_LEN), dtype=np.int32)
    labels = np.zeros(n, dtype=np.int32)
    cats = np.zeros((n, 3), dtype=np.int32)
    for idx in range(n):
        subj = int(rng.integers(0, 3))
        p_txt, p_img, p_no, n_easy, n_hard = _SUBJ[subj]
        mod = int(rng.choice(3, p=[p_txt, p_img, p_no]))
        grade = int(rng.integers(0, 2))
        noise = 0.35 if grade == 0 else 0.8
        if mod == 2:  # NO-context: class comes from a memorized fact
            n_facts = n_easy if grade == 0 else n_hard
            fact = int(rng.integers(0, n_facts))
            cls = int(fact_cls[subj][fact])
        else:
            fact = int(rng.integers(0, n_easy))
            cls = int(rng.integers(0, N_CLASSES))
        # 4 answer options containing the true class (presentation; the
        # model answers with the class concept — see module docstring)
        others = rng.permutation([c for c in range(N_CLASSES) if c != cls])[:3]
        opts = np.concatenate([[cls], others])
        rng.shuffle(opts)
        label = int(cls)

        toks = [BOS, SUBJ_TOK + subj, GRADE_TOK + grade, FACT_TOK + fact]
        toks.append(CLS_TOK + cls if mod == 0 else NEUTRAL_TOK)
        toks += [CLS_TOK + int(c) for c in opts]
        toks += [2]  # [ANS]
        tokens[idx, :len(toks)] = toks
        if mod == 1:
            images[idx] = render_image(cls, noise, rng)
        labels[idx] = label
        cats[idx] = (subj, mod, grade)
    return {"images": images, "tokens": tokens, "labels": labels,
            "cats": cats}


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

def param_names(mm: configs.LlavaMiniConfig):
    names = ["vit.patch.w", "vit.patch.b", "vit.pos"]
    for i in range(mm.vision.n_layers):
        p = f"vit.layers.{i}."
        names += [p + "ln1.g", p + "ln1.b",
                  p + "attn.wq", p + "attn.bq", p + "attn.wk", p + "attn.bk",
                  p + "attn.wv", p + "attn.bv", p + "attn.wo", p + "attn.bo",
                  p + "ln2.g", p + "ln2.b",
                  p + "mlp.wu", p + "mlp.bu", p + "mlp.wd", p + "mlp.bd"]
    names += ["vit.lnf.g", "vit.lnf.b", "proj.w", "proj.b"]
    names += ["lm." + n for n in mm.lm.param_names()
              if n not in ("lm_head",)]
    names += ["ans.w", "ans.b"]
    return names


def init_params(mm: configs.LlavaMiniConfig, seed=0):
    from .model import init_params as lm_init
    rng = np.random.default_rng(seed + 777)
    v = mm.vision
    params = {}
    params["vit.patch.w"] = rng.normal(
        0, 1 / np.sqrt(v.patch_dim), (v.d, v.patch_dim)).astype(np.float32)
    params["vit.patch.b"] = np.zeros(v.d, dtype=np.float32)
    params["vit.pos"] = (0.02 * rng.normal(size=(v.n_patches, v.d))
                         ).astype(np.float32)
    vit_cfg = configs.MiniConfig(name="vit", vocab=1, d=v.d,
                                 n_layers=v.n_layers, n_heads=v.n_heads,
                                 d_i=v.d_i, max_len=v.n_patches)
    vit_p = lm_init(vit_cfg, seed=seed + 1)
    for i in range(v.n_layers):
        p = f"layers.{i}."
        for suffix in ("ln1.g", "ln1.b", "attn.wq", "attn.bq", "attn.wk",
                       "attn.bk", "attn.wv", "attn.bv", "attn.wo", "attn.bo",
                       "ln2.g", "ln2.b", "mlp.wu", "mlp.bu", "mlp.wd",
                       "mlp.bd"):
            params["vit." + p + suffix] = vit_p[p + suffix]
    params["vit.lnf.g"] = np.ones(v.d, dtype=np.float32)
    params["vit.lnf.b"] = np.zeros(v.d, dtype=np.float32)
    params["proj.w"] = rng.normal(
        0, 1 / np.sqrt(v.d), (mm.lm.d, v.d)).astype(np.float32)
    params["proj.b"] = np.zeros(mm.lm.d, dtype=np.float32)
    lm_p = lm_init(mm.lm, seed=seed + 2)
    for k, arr in lm_p.items():
        params["lm." + k] = arr
    params["ans.w"] = rng.normal(
        0, 1 / np.sqrt(mm.lm.d), (mm.n_answers, mm.lm.d)).astype(np.float32)
    params["ans.b"] = np.zeros(mm.n_answers, dtype=np.float32)
    return params


def _block(params, prefix, x, h, causal, collect=None):
    """One pre-LN transformer block over [t, d] tokens."""
    xa = _ln(x, params[prefix + "ln1.g"], params[prefix + "ln1.b"])
    q = xa @ params[prefix + "attn.wq"].T + params[prefix + "attn.bq"]
    k = xa @ params[prefix + "attn.wk"].T + params[prefix + "attn.bk"]
    v = xa @ params[prefix + "attn.wv"].T + params[prefix + "attn.bv"]
    from .kernels import ref
    ctx = _unheads(ref.mha(_heads(q, h), _heads(k, h), _heads(v, h),
                           causal=causal))
    x = x + ctx @ params[prefix + "attn.wo"].T + params[prefix + "attn.bo"]
    xm = _ln(x, params[prefix + "ln2.g"], params[prefix + "ln2.b"])
    z = jnp.maximum(xm @ params[prefix + "mlp.wu"].T
                    + params[prefix + "mlp.bu"], 0.0)
    x = x + z @ params[prefix + "mlp.wd"].T + params[prefix + "mlp.bd"]
    if collect is not None:
        collect.append({"attn_x": xa.T, "o_x": ctx.T, "mlp_x": xm.T})
    return x


def forward(mm, params, image, text_tokens, collect=False):
    """One sample: image [16,16], text_tokens [TEXT_LEN] → answer logits [4]."""
    v = mm.vision
    patches = image.reshape(v.img // v.patch, v.patch,
                            v.img // v.patch, v.patch)
    patches = patches.transpose(0, 2, 1, 3).reshape(v.n_patches, v.patch_dim)
    x = patches @ params["vit.patch.w"].T + params["vit.patch.b"] \
        + params["vit.pos"]
    cal_v, cal_l = [], []
    for i in range(v.n_layers):
        x = _block(params, f"vit.layers.{i}.", x, v.n_heads, causal=False,
                   collect=cal_v if collect else None)
    x = _ln(x, params["vit.lnf.g"], params["vit.lnf.b"])
    vis = x @ params["proj.w"].T + params["proj.b"]       # [n_patches, d_lm]

    emb = params["lm.tok_emb"][text_tokens]
    seq = jnp.concatenate([vis, emb], axis=0)
    seq = seq + params["lm.pos_emb"][:seq.shape[0]]
    for i in range(mm.lm.n_layers):
        seq = _block(params, f"lm.layers.{i}.", seq, mm.lm.n_heads,
                     causal=True, collect=cal_l if collect else None)
    seq = _ln(seq, params["lm.lnf.g"], params["lm.lnf.b"])
    logits = seq[-1] @ params["ans.w"].T + params["ans.b"]
    if collect:
        return logits, cal_v, cal_l
    return logits


def batch_logits(mm, params, images, tokens):
    return jax.vmap(lambda im, tk: forward(mm, params, im, tk))(
        images, tokens)


def train_mm(mm, ds, steps=800, batch=32, lr=2e-3, seed=0, log_every=100):
    import time
    params = init_params(mm, seed=seed)
    state = adam_init(params)
    rng = np.random.default_rng(seed + 5)

    def loss_fn(p, im, tk, lb):
        logits = jax.vmap(lambda a, b: forward(mm, p, a, b))(im, tk)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(lp, lb[:, None], axis=-1).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    n = ds["images"].shape[0]
    curve = []
    t0 = time.time()
    for it in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        jp = {k: jnp.asarray(v) for k, v in params.items()}
        loss, grads = grad_fn(jp, jnp.asarray(ds["images"][idx]),
                              jnp.asarray(ds["tokens"][idx]),
                              jnp.asarray(ds["labels"][idx]))
        params = adam_step(params, grads, state, it, lr)
        curve.append(float(loss))
        if it % log_every == 0 or it == 1:
            print(f"[llava-mini] step {it:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params, curve


def evaluate(mm, params, ds, batch=64):
    """Accuracy overall + by subject / context modality / grade
    (the Table 4 column structure)."""
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fn = jax.jit(lambda im, tk: batch_logits(mm, jp, im, tk))
    n = ds["images"].shape[0]
    preds = np.zeros(n, dtype=np.int64)
    for s in range(0, n, batch):
        e = min(s + batch, n)
        # pad to full batch for a single jit signature
        im = np.zeros((batch, 16, 16), np.float32)
        tk = np.zeros((batch, TEXT_LEN), np.int32)
        im[:e - s] = ds["images"][s:e]
        tk[:e - s] = ds["tokens"][s:e]
        out = np.asarray(fn(jnp.asarray(im), jnp.asarray(tk)))
        preds[s:e] = out[:e - s].argmax(axis=-1)
    correct = preds == ds["labels"]
    res = {"Avg": float(correct.mean())}
    for si, sname in enumerate(SUBJECTS):
        m = ds["cats"][:, 0] == si
        res[sname] = float(correct[m].mean()) if m.any() else 0.0
    for mi, mname in enumerate(MODALITIES):
        m = ds["cats"][:, 1] == mi
        res[mname] = float(correct[m].mean()) if m.any() else 0.0
    for gi, gname in enumerate(GRADES):
        m = ds["cats"][:, 2] == gi
        res[gname] = float(correct[m].mean()) if m.any() else 0.0
    return res


def collect_calibration(mm, params, ds, n_samples=64, max_cols=768, seed=3):
    """Per-layer activation matrices for both towers (vit./lm. prefixes)."""
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fwd = jax.jit(lambda im, tk: forward(mm, jp, im, tk, collect=True)[1:])
    acc_v = [{k: [] for k in ("attn_x", "o_x", "mlp_x")}
             for _ in range(mm.vision.n_layers)]
    acc_l = [{k: [] for k in ("attn_x", "o_x", "mlp_x")}
             for _ in range(mm.lm.n_layers)]
    for i in range(min(n_samples, ds["images"].shape[0])):
        cal_v, cal_l = fwd(jnp.asarray(ds["images"][i]),
                           jnp.asarray(ds["tokens"][i]))
        for j, layer in enumerate(cal_v):
            for k in acc_v[j]:
                acc_v[j][k].append(np.asarray(layer[k]))
        for j, layer in enumerate(cal_l):
            for k in acc_l[j]:
                acc_l[j][k].append(np.asarray(layer[k]))
    rng = np.random.default_rng(seed)
    out = {}
    for tower, acc in (("vit", acc_v), ("lm", acc_l)):
        for j, layer in enumerate(acc):
            d = {}
            for k, chunks in layer.items():
                x = np.concatenate(chunks, axis=1)
                if x.shape[1] > max_cols:
                    idx = rng.choice(x.shape[1], size=max_cols, replace=False)
                    x = x[:, np.sort(idx)]
                d[k] = x.astype(np.float32)
            out[f"{tower}.layers.{j}"] = d
    return out


def compress_mm(mm, params, calib, method, ratio):
    """Compress both towers with the LM pipeline (per-tower MiniConfig)."""
    from .latentllm import pipeline
    v = mm.vision
    vit_cfg = configs.MiniConfig(name="vit", vocab=1, d=v.d,
                                 n_layers=v.n_layers, n_heads=v.n_heads,
                                 d_i=v.d_i, max_len=v.n_patches)
    reports = {}
    new_params = dict(params)
    for tower, cfg in (("vit", vit_cfg), ("lm", mm.lm)):
        sub = {k[len(tower) + 1:]: np.asarray(val, np.float64)
               for k, val in params.items() if k.startswith(tower + ".")}
        cal = {f"layers.{i}": calib[f"{tower}.layers.{i}"]
               for i in range(cfg.n_layers)}
        new_sub, rep = pipeline.compress_model(cfg, sub, cal, method, ratio)
        for k, val in new_sub.items():
            new_params[f"{tower}.{k}"] = np.asarray(val, np.float32)
        reports[tower] = rep
    return new_params, reports
