"""Build-time training of the opt-mini family on the synthetic corpora.

Hand-rolled Adam over the jnp forward path (the Pallas path is reserved for
the AOT-lowered inference programs). Runs once inside `make artifacts`;
loss curves land in artifacts/training_log.json and EXPERIMENTS.md.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def adam_init(params):
    return {k: (np.zeros_like(v), np.zeros_like(v)) for k, v in params.items()}


def adam_step(params, grads, state, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    out = {}
    for k, v in params.items():
        g = np.asarray(grads[k])
        m, s = state[k]
        m = b1 * m + (1 - b1) * g
        s = b2 * s + (1 - b2) * g * g
        state[k] = (m, s)
        mh = m / (1 - b1 ** step)
        sh = s / (1 - b2 ** step)
        out[k] = v - lr * mh / (np.sqrt(sh) + eps)
    return out


def train_lm(cfg, train_tokens, steps=400, batch=16, seq_len=128,
             lr=1e-3, seed=0, log_every=50):
    """Train one opt-mini model; returns (params, loss_curve)."""
    params = model.init_params(cfg, seed=seed)
    state = adam_init(params)
    rng = np.random.default_rng(seed + 101)
    gen = data.batches(train_tokens, batch, seq_len, rng=rng)

    def loss_fn(p, toks):
        return model.batch_nll(cfg, p, toks).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    curve = []
    t0 = time.time()
    for it in range(1, steps + 1):
        toks = jnp.asarray(next(gen))
        jp = {k: jnp.asarray(v) for k, v in params.items()}
        loss, grads = grad_fn(jp, toks)
        params = adam_step(params, grads, state, it, lr)
        curve.append(float(loss))
        if it % log_every == 0 or it == 1:
            print(f"[{cfg.name}] step {it:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params, curve


def eval_ppl(cfg, params, test_tokens, batch=8, seq_len=128, max_batches=24):
    """Perplexity over sequential test windows (matches the rust evaluator)."""
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fn = jax.jit(lambda toks: model.batch_nll(cfg, jp, toks))
    tot, n = 0.0, 0
    for i, toks in enumerate(data.batches(test_tokens, batch, seq_len)):
        if i >= max_batches:
            break
        nll = np.asarray(fn(jnp.asarray(toks)))
        tot += float(nll.sum())
        n += nll.shape[0]
    return float(np.exp(tot / max(n, 1)))


def collect_calibration(cfg, params, calib_tokens, max_cols=1024, seed=7):
    """Run the model over the calibration samples and gather per-layer
    activation matrices (attn_x / o_x / mlp_x as [d, l]), subsampled to
    max_cols columns for the rust-side compression path."""
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fwd = jax.jit(lambda s: model.forward(cfg, jp, s, collect=True)[1])
    acc = [{"attn_x": [], "o_x": [], "mlp_x": []}
           for _ in range(cfg.n_layers)]
    for row in calib_tokens:
        cal = fwd(jnp.asarray(row))
        for i, layer in enumerate(cal):
            for k in acc[i]:
                acc[i][k].append(np.asarray(layer[k]))
    rng = np.random.default_rng(seed)
    out = {}
    for i, layer in enumerate(acc):
        out[f"layers.{i}"] = {}
        for k, chunks in layer.items():
            x = np.concatenate(chunks, axis=1)   # [d, n_samples*t]
            if x.shape[1] > max_cols:
                idx = rng.choice(x.shape[1], size=max_cols, replace=False)
                x = x[:, np.sort(idx)]
            out[f"layers.{i}"][k] = x.astype(np.float32)
    return out
