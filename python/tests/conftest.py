import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def wishart(rng, d, decay=0.9, dof=None):
    """Wishart-correlated covariance (the paper's synthetic setup)."""
    dof = dof or 2 * d
    idx = np.arange(d)
    sigma = decay ** np.abs(idx[:, None] - idx[None, :])
    l = np.linalg.cholesky(sigma + 1e-9 * np.eye(d))
    g = rng.normal(size=(d, dof))
    lg = l @ g
    return lg @ lg.T / dof


@pytest.fixture
def wishart_cov():
    return wishart
