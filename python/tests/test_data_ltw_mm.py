"""Data generator, LTW format, and multimodal dataset/model sanity."""

import os
import tempfile

import numpy as np
import pytest

from compile import configs, data, ltw, multimodal as mm


def test_corpora_deterministic_and_in_range():
    a = data.generate("synthwiki", 5000)
    b = data.generate("synthwiki", 5000)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < data.VOCAB
    # different corpora differ
    c = data.generate("synthptb", 5000)
    assert not np.array_equal(a, c)


def test_corpus_has_structure():
    toks = data.generate("synthwiki", 20_000)
    pairs = set(zip(toks[:-1], toks[1:]))
    # iid tokens over 512² pairs would give ~0.96·n distinct bigrams;
    # the topic-bigram generator concentrates far below that.
    assert len(pairs) < 0.5 * len(toks), "bigram structure expected"


def test_splits_disjoint_walks():
    tr, te = data.splits("synthptb", n_train=5000, n_test=5000)
    assert not np.array_equal(tr[:5000], te)


def test_calibration_protocol():
    toks = data.generate("synthc4", 50_000)
    cal = data.calibration(toks, n_samples=64, seq_len=128)
    assert cal.shape == (64, 128)
    cal2 = data.calibration(toks, n_samples=64, seq_len=128)
    np.testing.assert_array_equal(cal, cal2)


def test_ltw_roundtrip():
    tensors = {
        "w": np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
        "t": np.arange(7, dtype=np.int32),
        "scalar3d": np.ones((2, 2, 2), dtype=np.float32),
    }
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.ltw")
        ltw.write_ltw(p, tensors)
        back = ltw.read_ltw(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_mm_dataset_properties():
    ds = mm.make_dataset(600, seed=3)
    assert ds["images"].shape == (600, 16, 16)
    assert ds["tokens"].shape == (600, mm.TEXT_LEN)
    assert ((ds["labels"] >= 0) & (ds["labels"] < mm.N_CLASSES)).all()
    # categories cover all cells
    assert set(np.unique(ds["cats"][:, 0])) == {0, 1, 2}
    assert set(np.unique(ds["cats"][:, 1])) == {0, 1, 2}
    assert set(np.unique(ds["cats"][:, 2])) == {0, 1}
    # TXT questions carry the class token; IMG carry an image
    txt = ds["cats"][:, 1] == 0
    assert (ds["tokens"][txt, 4] >= mm.CLS_TOK).all()
    assert (ds["tokens"][txt, 4] < mm.CLS_TOK + mm.N_CLASSES).all()
    img = ds["cats"][:, 1] == 1
    assert (np.abs(ds["images"][img]).max(axis=(1, 2)) > 0.5).all()
    no_img = ds["cats"][:, 1] != 1
    assert (np.abs(ds["images"][no_img]).max() == 0.0)


def test_mm_fact_tables_shared_across_seeds():
    a = mm.make_dataset(400, seed=0)
    b = mm.make_dataset(400, seed=9)
    # NO-context answers derive from the same fact table: same fact token
    # must imply the same class in both datasets
    def fact_map(ds):
        m = {}
        for i in range(ds["tokens"].shape[0]):
            if ds["cats"][i, 1] == 2:
                subj = ds["cats"][i, 0]
                fact = ds["tokens"][i, 3]
                m[(subj, fact)] = ds["labels"][i]
        return m
    ma, mb = fact_map(a), fact_map(b)
    shared = set(ma) & set(mb)
    assert shared, "expect overlapping facts"
    assert all(ma[k] == mb[k] for k in shared)


def test_mm_forward_shapes():
    cfg = configs.LLAVA_MINI
    params = mm.init_params(cfg, seed=0)
    import jax.numpy as jnp
    logits = mm.forward(cfg,
                        {k: jnp.asarray(v) for k, v in params.items()},
                        jnp.zeros((16, 16), jnp.float32),
                        jnp.zeros((mm.TEXT_LEN,), jnp.int32))
    assert logits.shape == (cfg.n_answers,)


def test_render_image_classes_distinct():
    rng = np.random.default_rng(0)
    imgs = [mm.render_image(c, 0.0, rng) for c in range(mm.N_CLASSES)]
    for i in range(mm.N_CLASSES):
        for j in range(i + 1, mm.N_CLASSES):
            assert np.abs(imgs[i] - imgs[j]).max() > 0.5, (i, j)
