"""Joint QK / VO / UD compression properties (paper §4, Apps E/G/H)."""

import numpy as np
import pytest

from compile.latentllm import joint_qk, joint_ud, joint_vo, linalg


def test_joint_qk_losses_monotone(rng):
    d, h, dh = 20, 4, 5
    wq = rng.normal(size=(d, d))
    wk = rng.normal(size=(d, d))
    res = joint_qk.compress(wq, wk, n_kv_heads=h, d_h=dh, rq=8, rk=8,
                            n_iter=6, kind="identity")
    assert all(b <= a * (1 + 1e-9)
               for a, b in zip(res["losses"], res["losses"][1:]))


def test_joint_qk_exact_full_rank(rng):
    d, h, dh = 12, 4, 3
    wq = rng.normal(size=(d, d))
    wk = rng.normal(size=(d, d))
    res = joint_qk.compress(wq, wk, n_kv_heads=h, d_h=dh, rq=d, rk=d,
                            n_iter=3, kind="identity")
    np.testing.assert_allclose(res["wq_hat"], wq, atol=1e-7)
    np.testing.assert_allclose(res["wk_hat"], wk, atol=1e-7)


def test_joint_qk_beats_separate_on_attention_loss(rng, wishart_cov):
    """Fig 10: attention-aware joint ≥ activation-aware split."""
    from compile.latentllm import asvd
    d, h, dh, r = 20, 4, 5, 8
    c = wishart_cov(rng, d)
    p = linalg.sqrtm_psd(c)
    wq = rng.normal(size=(d, d)) @ p
    wk = rng.normal(size=(d, d)) @ p
    joint = joint_qk.compress(wq, wk, n_kv_heads=h, d_h=dh, rq=r, rk=r,
                              n_iter=8, kind="identity")
    rq = asvd.compress(wq, r, kind="identity", junction_kind="left")
    rk = asvd.compress(wk, r, kind="identity", junction_kind="left")
    base = 0.0
    for i in range(h):
        g = wq[i * dh:(i + 1) * dh].T @ wk[i * dh:(i + 1) * dh]
        gh = rq["w_hat"][i * dh:(i + 1) * dh].T \
            @ rk["w_hat"][i * dh:(i + 1) * dh]
        base += linalg.frob2(g - gh)
    assert joint["loss"] <= base * 1.01


def test_joint_qk_gqa(rng):
    d, dh, n_kv, gs = 16, 4, 2, 2
    wq = rng.normal(size=(gs * n_kv * dh, d))
    wk = rng.normal(size=(n_kv * dh, d))
    res = joint_qk.compress(wq, wk, n_kv_heads=n_kv, d_h=dh, rq=8, rk=8,
                            group_size=gs, kind="identity")
    assert len(res["Bq"]) == gs * n_kv
    assert len(res["Bk"]) == n_kv
    assert res["wq_hat"].shape == wq.shape


def test_joint_qk_bias_mean_preserved(rng):
    d, h, dh = 12, 4, 3
    wq = rng.normal(size=(d, d))
    wk = rng.normal(size=(d, d))
    x = rng.normal(size=(d, 128)) + 0.3
    bq = rng.normal(size=d) * 0.1
    bk = rng.normal(size=d) * 0.1
    res = joint_qk.compress(wq, wk, n_kv_heads=h, d_h=dh, rq=8, rk=8,
                            x=x, bq=bq, bk=bk,
                            mu=x.mean(axis=1))
    mu = x.mean(axis=1)
    np.testing.assert_allclose(wq @ mu + bq, res["wq_hat"] @ mu + res["bq"],
                               atol=1e-8)


def test_joint_vo_monotone_and_full_rank(rng):
    d, h, dh = 16, 4, 4
    wv = rng.normal(size=(d, d))
    wo = rng.normal(size=(d, d))
    res = joint_vo.compress(wv, wo, n_heads=h, d_h=dh, rv=8, ro=8,
                            n_iter=4, kind="identity")
    ls = res["losses"]
    assert all(b <= a * (1 + 1e-9) for a, b in zip(ls, ls[1:]))
    full = joint_vo.compress(wv, wo, n_heads=h, d_h=dh, rv=d, ro=d,
                             n_iter=2, kind="identity")
    for i in range(h):
        g = wo[:, i * dh:(i + 1) * dh] @ wv[i * dh:(i + 1) * dh]
        gh = full["wo_hat"][:, i * dh:(i + 1) * dh] \
            @ full["wv_hat"][i * dh:(i + 1) * dh]
        np.testing.assert_allclose(g, gh, atol=1e-7)


def test_vo_contraction_order_rule():
    """Eqs 17/18: the reduction formula and the h·ro<rv rule."""
    d, dh, h, l, rv, ro = 128, 32, 4, 128, 96, 16
    a, b, red = joint_vo.contraction_flops(d, dh, h, l, rv, ro)
    assert red == (d - rv) * l * l + (h - 1) * d * l * ro
    assert b < a


def test_joint_ud_best_never_worse_than_init(rng):
    d, di, l = 10, 24, 160
    wu = rng.normal(size=(di, d))
    wd = rng.normal(size=(d, di)) * 0.3
    bu = rng.normal(size=di) * 0.05
    bd = np.zeros(d)
    x = rng.normal(size=(d, l))
    res = joint_ud.compress(wu, bu, wd, bd, x, 5, 5, n_iter=3)
    assert res["loss"] <= res["losses"][0] * (1 + 1e-9)


def test_joint_ud_exact_full_rank(rng):
    d, di, l = 6, 12, 120
    wu = rng.normal(size=(di, d))
    wd = rng.normal(size=(d, di))
    bu = np.full(di, 0.1)
    bd = np.full(d, -0.2)
    x = rng.normal(size=(d, l))
    res = joint_ud.compress(wu, bu, wd, bd, x, d, d, n_iter=2)
    y = wd @ np.maximum(wu @ x + bu[:, None], 0) + bd[:, None]
    yh = res["wd_hat"] @ np.maximum(
        res["wu_hat"] @ x + res["bu"][:, None], 0) + res["bd"][:, None]
    assert linalg.frob2(yh - y) / linalg.frob2(y) < 1e-6
