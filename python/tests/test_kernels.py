"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes; allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis unavailable in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import attention, gram, lowrank, ref

FTOL = dict(rtol=2e-4, atol=2e-4)


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=12, deadline=None)
@given(t=st.integers(1, 70), d_in=st.integers(1, 40),
       r=st.integers(1, 24), d_out=st.integers(1, 40),
       bt=st.sampled_from([8, 16, 64]), use_bias=st.booleans())
def test_lowrank_matmul_matches_ref(t, d_in, r, d_out, bt, use_bias):
    rng = np.random.default_rng(t * 1000 + d_in * 10 + r)
    x, a, b = arr(rng, t, d_in), arr(rng, r, d_in), arr(rng, d_out, r)
    bias = arr(rng, d_out) if use_bias else None
    got = lowrank.lowrank_matmul(x, a, b, bias, bt=bt)
    want = ref.lowrank_matmul(x, a, b, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **FTOL)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 50), r=st.integers(1, 20),
       tail=st.integers(1, 30), d_out=st.integers(1, 30))
def test_lowrank_blockid_matches_ref(t, r, tail, d_out):
    rng = np.random.default_rng(t * 31 + r * 7 + tail)
    x = arr(rng, t, r + tail)
    a2 = arr(rng, r, tail)
    b = arr(rng, d_out, r)
    got = lowrank.lowrank_matmul_blockid(x, a2, b, bt=16)
    want = ref.lowrank_matmul_blockid(x, a2, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **FTOL)


def test_blockid_equals_dense_with_identity_block(rng):
    """A = [I A2] as dense vs the fast path (paper Eq 9)."""
    r, tail, t, d_out = 8, 12, 20, 16
    a2 = arr(rng, r, tail)
    a = jnp.concatenate([jnp.eye(r, dtype=jnp.float32), a2], axis=1)
    b = arr(rng, d_out, r)
    x = arr(rng, t, r + tail)
    y1 = lowrank.lowrank_matmul(x, a, b)
    y2 = lowrank.lowrank_matmul_blockid(x, a2, b)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), **FTOL)


@settings(max_examples=8, deadline=None)
@given(h=st.integers(1, 6), t=st.integers(2, 48),
       d_h=st.integers(2, 24))
def test_mha_matches_ref(h, t, d_h):
    rng = np.random.default_rng(h * 100 + t + d_h)
    q, k, v = (arr(rng, h, t, d_h) for _ in range(3))
    got = attention.mha(q, k, v)
    want = ref.mha(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **FTOL)


def test_mha_causality(rng):
    """Changing future tokens must not change past outputs."""
    h, t, d_h = 2, 16, 8
    q, k, v = (arr(rng, h, t, d_h) for _ in range(3))
    out1 = np.asarray(attention.mha(q, k, v))
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(-99.0)
    out2 = np.asarray(attention.mha(q, k2, v2))
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], **FTOL)
    assert not np.allclose(out1[:, -1], out2[:, -1])


@settings(max_examples=8, deadline=None)
@given(h=st.integers(1, 4), t=st.integers(2, 32), rq=st.integers(1, 12),
       rk=st.integers(1, 12), rv=st.integers(1, 12), d_h=st.integers(2, 12))
def test_latent_attention_matches_ref(h, t, rq, rk, rv, d_h):
    rng = np.random.default_rng(h + t * 3 + rq * 5 + rk * 7 + rv)
    q_lat, ck, cv = arr(rng, t, rq), arr(rng, t, rk), arr(rng, t, rv)
    hc, bv = arr(rng, h, rq, rk), arr(rng, h, d_h, rv)
    got = attention.latent_attention(q_lat, ck, cv, hc, bv)
    want = ref.latent_attention(q_lat, ck, cv, hc, bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **FTOL)


def test_latent_equals_dense_attention_when_exact(rng):
    """With factors that exactly reproduce q/k/v, MLA == MHA (the §4.1
    inference-path identity)."""
    h, t, d_h, d = 2, 12, 4, 16
    x = arr(rng, t, d)
    wq, wk, wv = (arr(rng, h * d_h, d) for _ in range(3))
    # exact factors: A = I_d (r = d), B_i = W_i
    eye = jnp.eye(d, dtype=jnp.float32)
    bq = jnp.stack([wq[i * d_h:(i + 1) * d_h] for i in range(h)])
    bk = jnp.stack([wk[i * d_h:(i + 1) * d_h] for i in range(h)])
    bv = jnp.stack([wv[i * d_h:(i + 1) * d_h] for i in range(h)])
    q = (x @ wq.T).reshape(t, h, d_h).transpose(1, 0, 2)
    k = (x @ wk.T).reshape(t, h, d_h).transpose(1, 0, 2)
    v = (x @ wv.T).reshape(t, h, d_h).transpose(1, 0, 2)
    dense = ref.mha(q, k, v)
    h_core = jnp.einsum("hdq,hdk->hqk", bq, bk)
    lat = ref.latent_attention(x @ eye, x @ eye, x @ eye, h_core, bv)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(lat),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(d=st.integers(1, 32), l=st.integers(1, 300),
       bl=st.sampled_from([32, 64, 256]))
def test_gram_matches_ref(d, l, bl):
    rng = np.random.default_rng(d * 1000 + l)
    x = arr(rng, d, l)
    got = gram.gram(x, bl=bl)
    want = ref.gram(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_vmem_estimate_sane():
    # the §Perf static VMEM model: well under a 16 MiB budget at repo scales
    assert lowrank.vmem_bytes(64, 192, 192, 96) < 16 << 20
