"""Whole-model pipeline + JAX model integration (trains a tiny model once
per session; verifies the Table 2 method ordering end-to-end and the
dense↔latent architectural identity)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, data, model, train
from compile.latentllm import pipeline, rank

TINY = configs.MiniConfig(name="tiny", vocab=256, d=48, n_layers=2,
                          n_heads=4, d_i=96, max_len=64)


@pytest.fixture(scope="module")
def trained():
    tr, te = data.splits("synthwiki", n_train=30_000, n_test=6_000)
    # remap tokens into the tiny vocab
    tr = (tr % TINY.vocab).astype(np.int32)
    te = (te % TINY.vocab).astype(np.int32)
    params, _ = train.train_lm(TINY, tr, steps=150, batch=8, seq_len=64,
                               lr=3e-3, log_every=1000)
    calib_tokens = data.calibration(tr, n_samples=8, seq_len=64)
    calib = train.collect_calibration(TINY, params, calib_tokens,
                                      max_cols=384)
    return params, calib, te


def eval_ppl(params, te):
    return train.eval_ppl(TINY, {k: np.asarray(v, np.float32)
                                 for k, v in params.items()},
                          te, batch=8, seq_len=64, max_batches=6)


def test_method_ordering(trained):
    """The paper's Table 2 story at tiny scale: latentllm ≤ rootcov ≤
    plain at matched ratio (allowing small noise margins)."""
    params, calib, te = trained
    base = eval_ppl(params, te)
    p64 = {k: np.asarray(v, np.float64) for k, v in params.items()}
    ppl = {}
    for m in ("plain", "asvd_rootcov", "latentllm"):
        nw, rep = pipeline.compress_model(TINY, p64, calib, m, 0.3,
                                          qk_iters=4, ud_iters=2)
        ppl[m] = eval_ppl(nw, te)
        assert abs(rep["achieved_ratio"] - 0.3) < 0.06, (m, rep)
    assert ppl["latentllm"] <= ppl["asvd_rootcov"] * 1.05
    assert ppl["asvd_rootcov"] <= ppl["plain"] * 1.05
    assert base <= ppl["latentllm"]


def test_latent_forward_equals_reconstructed(trained):
    """The deployed MLA architecture computes exactly the same function as
    the reconstructed dense Ŵ (§4.1 inference identity, incl. biases)."""
    from compile.aot import latent_params_from_report
    params, calib, te = trained
    p64 = {k: np.asarray(v, np.float64) for k, v in params.items()}
    nw, rep = pipeline.compress_model(TINY, p64, calib, "latentllm", 0.3,
                                      qk_iters=3, ud_iters=2)
    keep = 0.7
    r_qk = rank.joint_qk_rank(TINY.d, TINY.d_h, TINY.n_heads, TINY.n_heads,
                              keep, blockid=True)
    ranks = {"rq": r_qk, "rk": r_qk,
             "rv": rank.local_rank(TINY.d, TINY.d, keep, True),
             "ro": rank.local_rank(TINY.d, TINY.d, keep, True),
             "ru": rank.local_rank(TINY.d_i, TINY.d, keep, True),
             "rd": rank.local_rank(TINY.d, TINY.d_i, keep, True)}
    lat = latent_params_from_report(
        TINY, {k: np.asarray(v, np.float32) for k, v in params.items()},
        rep, ranks)
    toks = jnp.asarray(te[:64].astype(np.int32))
    dense_logits = model.forward(
        TINY, {k: jnp.asarray(np.asarray(v, np.float32))
               for k, v in nw.items()}, toks)
    lat_logits = model.latent_forward(
        TINY, {k: jnp.asarray(v) for k, v in lat.items()}, toks,
        use_pallas=False)
    np.testing.assert_allclose(np.asarray(dense_logits),
                               np.asarray(lat_logits), rtol=2e-3, atol=2e-3)


def test_pallas_forward_equals_jnp(trained):
    params, _, te = trained
    jp = {k: jnp.asarray(np.asarray(v, np.float32))
          for k, v in params.items()}
    toks = jnp.asarray(te[:64].astype(np.int32))
    l1 = model.forward(TINY, jp, toks, use_pallas=False)
    l2 = model.forward(TINY, jp, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_rank_solver_roundtrip():
    for keep in (0.5, 0.7, 0.9):
        for (do, di) in ((48, 48), (96, 48), (48, 96)):
            for blockid in (False, True):
                r = rank.local_rank(do, di, keep, blockid)
                p = rank.local_params(do, di, r, blockid)
                step = do + di
                if 1 < r < min(do, di):
                    assert abs(p - keep * do * di) <= step


def test_calibration_shapes(trained):
    _, calib, _ = trained
    for i in range(TINY.n_layers):
        layer = calib[f"layers.{i}"]
        for k in ("attn_x", "o_x", "mlp_x"):
            assert layer[k].shape[0] in (TINY.d,)
            assert layer[k].shape[1] <= 384
