"""Pre-conditioner (Table 1) and junction-matrix (§3.3) properties."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis unavailable in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.latentllm import asvd, junction, linalg, precond


def test_rootcov_is_optimal(rng, wishart_cov):
    """Paper §3.2: P = C^{1/2} minimizes the activation loss over Table 1."""
    d = 20
    c = wishart_cov(rng, d)
    w = rng.normal(size=(16, d))
    losses = {}
    for kind in precond.PRECONDITIONERS:
        res = asvd.compress(w, 8, kind=kind, junction_kind="left", c=c)
        losses[kind] = res["loss"]
    for kind, loss in losses.items():
        assert losses["rootcov"] <= loss * (1 + 1e-9), kind


def test_precond_inverse_pairs(rng, wishart_cov):
    c = wishart_cov(rng, 12)
    x = rng.normal(size=(12, 64))
    for kind in precond.PRECONDITIONERS:
        p, p_inv = precond.build(kind, x=x, c=c)
        if kind in ("identity", "diag_hessian", "diag_l1", "diag_l2",
                    "rootcov"):
            np.testing.assert_allclose(p @ p_inv, np.eye(12), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(d_out=st.integers(4, 24), d_in=st.integers(4, 24),
       r=st.integers(1, 12))
def test_junctions_loss_invariant(d_out, d_in, r):
    """Any J with SJJ⁺=S leaves Ŵ unchanged (§3.3)."""
    r = min(r, d_out, d_in)
    rng = np.random.default_rng(d_out * 100 + d_in + r)
    w = rng.normal(size=(d_out, d_in))
    u, s, vt = linalg.svd_truncated(w, r)
    p_inv = np.eye(d_in)
    ref_b, ref_a, _ = junction.apply(u, s, vt, p_inv, kind="left")
    ref_w = ref_b @ ref_a
    for kind in junction.JUNCTIONS:
        b, a, info = junction.apply(u, s, vt, p_inv, kind=kind)
        np.testing.assert_allclose(b @ a, ref_w, atol=1e-8)
        assert info["rank"] == r


def test_blockid_identity_exact(rng):
    w = rng.normal(size=(10, 14))
    u, s, vt = linalg.svd_truncated(w, 5)
    b, a, info = junction.apply(u, s, vt, np.eye(14), kind="blockid")
    idx = info["identity_cols"]
    np.testing.assert_array_equal(a[:, idx], np.eye(5))


def test_blockid_param_count():
    # §3.3 worked example: r = 0.75d keeps (15/16)d² params
    d = 64
    r = 48
    assert junction.factor_params(d, d, r, True) == 15 * d * d // 16
    assert junction.factor_params(d, d, r, False) == 3 * d * d // 2


def test_bias_update_preserves_mean(rng, wishart_cov):
    """App B.2: b̂ = b + (W−BA)μ keeps the mean output."""
    d = 12
    x = rng.normal(size=(d, 200)) + rng.normal(size=(d, 1))  # nonzero mean
    w = rng.normal(size=(8, d))
    bias = rng.normal(size=8)
    res = asvd.compress(w, 4, kind="rootcov", junction_kind="blockid",
                        x=x, bias=bias)
    mu = x.mean(axis=1)
    np.testing.assert_allclose(w @ mu + bias,
                               res["w_hat"] @ mu + res["bias"], atol=1e-8)


def test_loss_matches_eckart_young_for_identity(rng):
    """With P=I the ASVD loss equals the SVD tail energy."""
    w = rng.normal(size=(10, 10))
    s = np.linalg.svd(w, compute_uv=False)
    res = asvd.compress(w, 6, kind="identity", junction_kind="left")
    assert abs(res["loss"] - np.sum(s[6:] ** 2)) < 1e-8


def test_joint_qkv_beats_split(rng, wishart_cov):
    """App C / Fig 8: shared-A stacking wins at equal params."""
    d = 16
    c = wishart_cov(rng, d)
    ws = [rng.normal(size=(d, d)) for _ in range(3)]
    r = 4
    split = sum(asvd.compress(w, r, kind="rootcov", junction_kind="left",
                              c=c)["loss"] for w in ws)
    r_joint = 3 * r * 2 * d // (4 * d)
    jr = asvd.compress_stacked(ws, r_joint, kind="rootcov",
                               junction_kind="left", c=c)
    assert jr["loss"] <= split * 1.05


def test_split_head_worse(rng, wishart_cov):
    """App D / Fig 9."""
    d = 16
    c = wishart_cov(rng, d)
    w = rng.normal(size=(d, d))
    joint = asvd.compress(w, 8, kind="rootcov", junction_kind="left", c=c)
    split = asvd.split_head_compress(w, 4, 8, kind="rootcov", c=c)
    sl = linalg.act_loss(w, split["w_hat"], c)
    assert joint["loss"] <= sl * (1 + 1e-9)
