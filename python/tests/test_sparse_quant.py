"""Sparse / low-rank+sparse / quantization appendix algorithms (App I)."""

import numpy as np
import pytest

from compile.latentllm import asvd, linalg, quant, sparse


@pytest.fixture
def problem(rng, wishart_cov):
    d = 14
    return rng.normal(size=(d, d)), wishart_cov(rng, d)


def test_hard_topk_exact(rng):
    w = rng.normal(size=(8, 8))
    for k in [0, 3, 17, 64, 100]:
        d = sparse.hard_topk(w, k)
        assert (d != 0).sum() == min(k, 64)


def test_projected_gd_respects_sparsity_and_beats_wanda(problem):
    w, c = problem
    kappa = 60
    d, loss = sparse.projected_gd(w, c, kappa, n_iter=60)
    assert (d != 0).sum() <= kappa
    _, wloss = sparse.wanda_diag(w, c, kappa)
    assert loss <= wloss * (1 + 1e-9)


def test_fista_near_target(problem):
    w, c = problem
    d, _ = sparse.fista(w, c, 50, n_iter=40)
    assert 0 < (d != 0).sum() <= 75


def test_sparse_beats_lowrank_equal_budget(problem):
    """Fig 11 headline."""
    w, c = problem
    dsz = w.shape[0]
    r = 3
    budget = r * 2 * dsz
    lr = asvd.compress(w, r, kind="rootcov", junction_kind="left", c=c)
    _, sp = sparse.projected_gd(w, c, budget, n_iter=60)
    assert sp <= lr["loss"] * (1 + 1e-9)


def test_lowrank_plus_sparse_tracks(problem):
    w, c = problem
    ba, d, hist = sparse.lowrank_plus_sparse(w, c, rank=3, kappa=30,
                                             n_iter=4)
    assert hist[-1] <= hist[0] * (1 + 1e-9)
    got = linalg.act_loss(w, ba + d, c)
    assert abs(got - hist[-1]) < 1e-8


def test_sparsify_factors(problem):
    w, c = problem
    lr = asvd.compress(w, 8, kind="rootcov", junction_kind="left", c=c)
    b, a, hist = sparse.sparsify_factors(lr["B"], lr["A"], w, c, 0.5,
                                         n_iter=25)
    assert (b != 0).sum() <= int(0.5 * b.size) + 1
    assert (a != 0).sum() <= int(0.5 * a.size) + 1
    assert len(hist) == 25


def test_quantizer_levels_and_identity(rng):
    m = rng.normal(size=(6, 6))
    q2 = quant.quantize_uniform(m, 2, chunk=36)
    assert len(np.unique(np.round(q2, 9))) <= 4
    q16 = quant.quantize_uniform(m, 16, chunk=36)
    np.testing.assert_allclose(q16, m, atol=1e-3)


def test_quant_ste_improves(problem):
    w, c = problem
    lr = asvd.compress(w, 7, kind="rootcov", junction_kind="left", c=c)
    _, _, hist = quant.quantize_factors(lr["B"], lr["A"], w, c, bits=4,
                                        chunk=32, n_iter=20)
    assert min(hist) <= hist[0] * (1 + 1e-9)
    assert min(hist) < hist[0]
