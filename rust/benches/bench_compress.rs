//! Compression-algorithm benches: per-module costs and the whole-model
//! pipeline (the numbers behind EXPERIMENTS.md §Perf L3).

use latentllm::compress::asvd::{self, AsvdOpts};
use latentllm::compress::joint_qk::{self, JointQkOpts};
use latentllm::compress::joint_ud::{self, JointUdOpts};
use latentllm::compress::junction::Junction;
use latentllm::compress::pipeline::{compress_model, Method};
use latentllm::compress::precond::Precond;
use latentllm::data::CalibSet;
use latentllm::model::config::OPT_MINI_S;
use latentllm::util::bench::Bench;
use latentllm::util::rng::{decaying_covariance, wishart, Rng};

fn main() {
    let mut b = Bench::new(0.8);
    let mut rng = Rng::new(2);
    println!("== compression algorithms ==");

    for d in [96usize, 128] {
        let w = rng.normal_matrix(d, d);
        let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
        let r = d / 2;
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::BlockId,
                              ..Default::default() };
        b.run(&format!("asvd rootcov+blockid d={d} r={r}"),
              || asvd::compress_with_cov(&w, r, &c, &vec![0.0; d], &opts));
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        b.run(&format!("joint_qk alg1 d={d} h=4 iters=8"), || {
            joint_qk::compress(&wq, &wk, 4, d / 4, r, r,
                               &JointQkOpts { kind: Precond::Identity,
                                              n_iter: 8,
                                              ..Default::default() })
        });
    }

    // UD joint (the pipeline's dominant cost)
    let (d, di, l) = (96usize, 384usize, 512usize);
    let wu = rng.normal_matrix(di, d);
    let wd = rng.normal_matrix(d, di).scale(0.2);
    let x = rng.normal_matrix(d, l);
    b.run("joint_ud d=96 di=384 l=512 iters=2", || {
        joint_ud::compress(&wu, &vec![0.0; di], &wd, &vec![0.0; d], &x,
                           48, 48, &JointUdOpts { n_iter: 2,
                                                  ..Default::default() })
    });

    // whole-model pipeline (opt-mini-s, synthetic calibration)
    println!("== whole-model pipeline (opt-mini-s) ==");
    let cfg = OPT_MINI_S;
    let weights = latentllm::compress::pipeline::tests_support::
        random_weights(&cfg, 7);
    let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 512, 3);
    let mut bb = Bench::new(0.1); // pipeline is seconds; few iters
    bb.max_iters = 3;
    for method in [Method::AsvdRootCov, Method::LatentLlm] {
        bb.run(&format!("pipeline {} @30%", method.name()), || {
            compress_model(&cfg, &weights, &cal, method, 0.3, 4, 2).unwrap()
        });
    }
}
