//! Decode-path bench: incremental KV-cached sessions versus the
//! full-window recompute reference, the execution-layout sweep
//! (f64 / f32 / int8 weights through the same decode sessions), and the
//! paper's benefit (ii) — dense vs latent cache capacity at a matched
//! byte budget.
//!
//! The acceptance story: recompute re-executes the whole [1, T] window
//! per emitted token (O(T²·d²) total), so its per-token cost grows with
//! context length; a session reads prior K/V from the cache (O(T·d² +
//! T²·d) total), so its per-token cost stays ~flat until attention
//! itself dominates. The layout sweep then holds the session machinery
//! fixed and swaps the weight kernels: the blocked f32 panels and the
//! fused-dequant int8 path against the bit-exact f64 reference.
//!
//! A batched-step section then holds the shapes fixed and varies the
//! batch width: N ∈ {1, 4, 8, 16} prefilled sessions stepped through
//! one `BatchedDecodeState`, the fused shared-weight pass vs the
//! per-session fallback loop.
//!
//! Machine-readable results land in BENCH_DECODE.json (override the
//! path with BENCH_DECODE_JSON): ms/token + tok/s per layout × path ×
//! T ∈ {32, 64, 128}, int8-vs-f64 speedups, the batched-step
//! fused-vs-loop sweep, and the perplexity drift each layout costs on
//! the dense scoring program.
//!
//! Run: cargo bench --bench bench_decode

use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::data::synth::{latent_demo_ranks, write_test_artifacts};
use latentllm::data::Corpus;
use latentllm::eval::generate::{generate, GenerateOpts};
use latentllm::eval::perplexity;
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::runtime::decode::BatchedDecodeState;
use latentllm::runtime::Engine;
use latentllm::util::json::Value;
use latentllm::Layout;

// wide enough that per-token matmul work dominates session bookkeeping
// (the layout kernels target the matmul side; a toy d would measure
// overhead, not kernels)
const BENCH_CFG: MiniConfig = MiniConfig {
    name: "bench-decode", vocab: 256, d: 96, n_layers: 2, n_heads: 4,
    d_i: 192, max_len: 256,
};

const LAYOUTS: [Layout; 3] =
    [Layout::DenseF64, Layout::PackedF32, Layout::QuantI8];
const TS: [usize; 3] = [32, 64, 128];
const QUANT_CHUNK: usize = 64;

struct Run {
    path: &'static str,
    layout: Layout,
    max_new: usize,
    ms_per_tok: f64,
    tok_s: f64,
}

fn main() {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_decode_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let tag = write_test_artifacts(&dir, &BENCH_CFG, 3)
        .expect("synthesize artifacts");
    let engine = Engine::new(&dir).expect("engine");
    let dense_w = Weights::load(
        dir.join(format!("model_{}.ltw", BENCH_CFG.name))).unwrap();
    let latent_w = Weights::load(
        dir.join(format!("latent_model_{tag}.ltw"))).unwrap();

    println!("== decode scaling: incremental vs full-window recompute ==");
    println!("model {} (d={}, L={}); one lane, prompt 8, greedy",
             BENCH_CFG.name, BENCH_CFG.d, BENCH_CFG.n_layers);
    let prompt: Vec<Vec<i32>> = vec![(0..8)
        .map(|i| (i * 7) % BENCH_CFG.vocab as i32).collect()];
    for (label, program, weights) in
        [("dense ", format!("step_{}", BENCH_CFG.name), &dense_w),
         ("latent", format!("latent_step_{tag}"), &latent_w)] {
        for max_new in TS {
            // the recompute window is sized to the context it must hold,
            // so its cost reflects the actual O(T²) re-execution
            let window = 8 + max_new;
            let run = |use_cache: bool| {
                let opts = GenerateOpts {
                    max_new, temperature: 0.0, seed: 1, use_cache,
                };
                generate(&engine, &program, weights, &prompt, 1, window,
                         BENCH_CFG.vocab, &opts).expect("generate")
            };
            let inc = run(true);
            let rec = run(false);
            assert_eq!(inc.sequences, rec.sequences,
                       "bench paths must agree token-for-token");
            let per_tok = |s: f64| s * 1e3 / max_new as f64;
            println!("  {label} T={max_new:>3}: incremental \
                      {:>7.3} ms/tok  recompute {:>7.3} ms/tok  \
                      ({:.1}x, cache {} floats)",
                     per_tok(inc.seconds), per_tok(rec.seconds),
                     rec.seconds / inc.seconds.max(1e-12),
                     inc.peak_cache_elements);
        }
    }

    println!("== execution layouts: f64 / f32 / int8 decode kernels ==");
    let mut runs: Vec<Run> = Vec::new();
    for (path, program, base) in
        [("dense", format!("step_{}", BENCH_CFG.name), &dense_w),
         ("latent", format!("latent_step_{tag}"), &latent_w)] {
        for layout in LAYOUTS {
            let weights = if layout == Layout::DenseF64 {
                (*base).clone()
            } else {
                base.repack(layout, QUANT_CHUNK).expect("repack")
            };
            // warm up: builds + packs the model once so timing below
            // measures steady-state decode, not load-time packing
            let warm = GenerateOpts {
                max_new: 4, temperature: 0.0, seed: 1, use_cache: true,
            };
            generate(&engine, &program, &weights, &prompt, 1, 16,
                     BENCH_CFG.vocab, &warm).expect("warmup");
            for max_new in TS {
                let opts = GenerateOpts {
                    max_new, temperature: 0.0, seed: 1, use_cache: true,
                };
                let res = generate(&engine, &program, &weights, &prompt, 1,
                                   8 + max_new, BENCH_CFG.vocab, &opts)
                    .expect("generate");
                let ms = res.seconds * 1e3 / max_new as f64;
                println!("  {path:<6} {:<5} T={max_new:>3}: \
                          {ms:>7.3} ms/tok  {:>8.1} tok/s",
                         layout.name(), res.tokens_per_sec);
                runs.push(Run { path, layout, max_new,
                                ms_per_tok: ms,
                                tok_s: res.tokens_per_sec });
            }
        }
    }
    // speedup vs the f64 reference at the longest context
    let tok_s = |path: &str, layout: Layout| runs.iter()
        .find(|r| r.path == path && r.layout == layout
              && r.max_new == TS[TS.len() - 1])
        .map(|r| r.tok_s).unwrap_or(f64::NAN);
    let mut speedups: Vec<(&str, Value)> = Vec::new();
    for path in ["dense", "latent"] {
        let base = tok_s(path, Layout::DenseF64);
        for layout in [Layout::PackedF32, Layout::QuantI8] {
            let s = tok_s(path, layout) / base.max(1e-12);
            println!("  {path} {} speedup vs f64 @ T={}: {s:.2}x",
                     layout.name(), TS[TS.len() - 1]);
        }
        speedups.push((path, Value::obj(vec![
            ("f32", Value::Num(tok_s(path, Layout::PackedF32)
                               / base.max(1e-12))),
            ("int8", Value::Num(tok_s(path, Layout::QuantI8)
                                / base.max(1e-12))),
        ])));
    }

    // accuracy side of the tradeoff: perplexity through the dense
    // scoring program per layout
    let corpus = Corpus::load(dir.join("corpora.ltw"), "synthwiki", "test")
        .expect("corpus");
    let score = format!("score_{}", BENCH_CFG.name);
    let mut ppls: Vec<(&str, f64)> = Vec::new();
    for layout in LAYOUTS {
        let weights = if layout == Layout::DenseF64 {
            dense_w.clone()
        } else {
            dense_w.repack(layout, QUANT_CHUNK).expect("repack")
        };
        let r = perplexity(&engine, &score, &weights, &corpus, 4, 96, 3)
            .expect("perplexity");
        println!("  ppl({}) = {:.4}", layout.name(), r.ppl);
        ppls.push((layout.name(), r.ppl));
    }
    let ppl_f64 = ppls[0].1;
    for &(name, p) in &ppls[1..] {
        println!("  ppl drift {name} vs f64: {:+.5}", p - ppl_f64);
    }

    // batched-step kernel at matched shapes: N prefilled sessions
    // stepped together through one BatchedDecodeState, fused weight
    // pass vs the per-session fallback loop. Same model, same layout
    // sweep shapes as above — this isolates what the serving scheduler
    // gains per iteration before any queueing/cache effects.
    println!("== batched step: fused weight pass vs per-session loop ==");
    let step_prog = engine.program(&format!("step_{}", BENCH_CFG.name))
        .expect("step program");
    const BATCH_ROUNDS: usize = 64;
    let mut batched: Vec<(usize, &'static str, f64, f64)> = Vec::new();
    for n_live in [1usize, 4, 8, 16] {
        for fused_on in [true, false] {
            let mut batch = BatchedDecodeState::new();
            batch.set_fused(fused_on);
            let mut slots = Vec::with_capacity(n_live);
            for s in 0..n_live {
                let mut sess = step_prog.decode_session(&dense_w)
                    .expect("session");
                let p: Vec<i32> = (0..8)
                    .map(|j| ((s * 13 + j * 7) % BENCH_CFG.vocab) as i32)
                    .collect();
                sess.prefill(&p).expect("prefill");
                slots.push(batch.insert(s as u64, sess));
            }
            // warm round so timing excludes workspace growth
            let warm: Vec<(usize, i32)> =
                slots.iter().map(|&sl| (sl, 1)).collect();
            for r in batch.step_many(&warm) {
                r.expect("warm step");
            }
            let t0 = std::time::Instant::now();
            for round in 0..BATCH_ROUNDS {
                let steps: Vec<(usize, i32)> = slots.iter()
                    .map(|&sl| (sl, ((round * 5 + sl * 3)
                                     % BENCH_CFG.vocab) as i32))
                    .collect();
                for r in batch.step_many(&steps) {
                    r.expect("step");
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let rows_s = (n_live * BATCH_ROUNDS) as f64 / dt.max(1e-12);
            let ms_round = dt * 1e3 / BATCH_ROUNDS as f64;
            let mode = if fused_on { "fused" } else { "loop" };
            println!("  n={n_live:>2} {mode:<5}: {ms_round:>7.3} \
                      ms/round  {rows_s:>9.1} rows/s");
            batched.push((n_live, mode, ms_round, rows_s));
        }
    }
    let rows_s_at = |n: usize, mode: &str| batched.iter()
        .find(|r| r.0 == n && r.1 == mode)
        .map(|r| r.3).unwrap_or(f64::NAN);
    for n_live in [4usize, 8, 16] {
        println!("  fused speedup @ n={n_live}: {:.2}x",
                 rows_s_at(n_live, "fused")
                     / rows_s_at(n_live, "loop").max(1e-12));
    }

    // per-layer phase breakdown (what `serve --profile-layers` exposes
    // on /metrics): enable the global profiler with a bench-local sink,
    // decode through both programs at every layout, and report mean µs
    // per (layer kind, phase, weight layout) cell
    println!("== per-layer phase profile (attn_weight / attn_cache / \
              finish) ==");
    let sink = std::sync::Arc::new(
        latentllm::coordinator::metrics::Metrics::new());
    latentllm::runtime::profile::install(sink.clone());
    for (program, base) in
        [(format!("step_{}", BENCH_CFG.name), &dense_w),
         (format!("latent_step_{tag}"), &latent_w)] {
        for layout in LAYOUTS {
            let weights = if layout == Layout::DenseF64 {
                (*base).clone()
            } else {
                base.repack(layout, QUANT_CHUNK).expect("repack")
            };
            let opts = GenerateOpts {
                max_new: 32, temperature: 0.0, seed: 1, use_cache: true,
            };
            generate(&engine, &program, &weights, &prompt, 1, 40,
                     BENCH_CFG.vocab, &opts).expect("profiled decode");
        }
    }
    latentllm::runtime::profile::disable();
    let mut phase_rows: Vec<Value> = Vec::new();
    for kind in ["dense", "latent"] {
        for layout in LAYOUTS {
            for phase in ["attn_weight", "attn_cache", "finish"] {
                let labels = [("kind", kind), ("phase", phase),
                              ("layout", layout.name())];
                let Some((sum, n)) = sink.sum_count_with(
                    latentllm::runtime::profile::PHASE_METRIC, &labels)
                else {
                    continue;
                };
                let mean = sum / n as f64;
                println!("  {kind:<6} {:<5} {phase:<11}: {mean:>8.2} µs \
                          mean over {n} calls", layout.name());
                phase_rows.push(Value::obj(vec![
                    ("kind", Value::Str(kind.to_string())),
                    ("phase", Value::Str(phase.to_string())),
                    ("layout", Value::Str(layout.name().to_string())),
                    ("mean_us", Value::Num(mean)),
                    ("calls", Value::Num(n as f64)),
                ]));
            }
        }
    }
    assert!(!phase_rows.is_empty(),
            "the profiler must record phase timings when enabled");

    let json = Value::obj(vec![
        ("model", Value::obj(vec![
            ("name", Value::Str(BENCH_CFG.name.to_string())),
            ("d", Value::Num(BENCH_CFG.d as f64)),
            ("n_layers", Value::Num(BENCH_CFG.n_layers as f64)),
            ("vocab", Value::Num(BENCH_CFG.vocab as f64)),
        ])),
        ("quant_chunk", Value::Num(QUANT_CHUNK as f64)),
        ("results", Value::Arr(runs.iter().map(|r| Value::obj(vec![
            ("path", Value::Str(r.path.to_string())),
            ("layout", Value::Str(r.layout.name().to_string())),
            ("t", Value::Num(r.max_new as f64)),
            ("ms_per_tok", Value::Num(r.ms_per_tok)),
            ("tok_s", Value::Num(r.tok_s)),
        ])).collect())),
        ("speedup_vs_f64", Value::obj(speedups)),
        ("batched_step", Value::obj(vec![
            ("rounds", Value::Num(BATCH_ROUNDS as f64)),
            ("results", Value::Arr(batched.iter()
                .map(|&(n, mode, ms, rs)| Value::obj(vec![
                    ("live", Value::Num(n as f64)),
                    ("mode", Value::Str(mode.to_string())),
                    ("ms_per_round", Value::Num(ms)),
                    ("rows_per_s", Value::Num(rs)),
                ])).collect())),
            ("fused_speedup_at_8_live",
             Value::Num(rows_s_at(8, "fused")
                        / rows_s_at(8, "loop").max(1e-12))),
        ])),
        ("layer_phase_us", Value::Arr(phase_rows)),
        ("ppl", Value::Obj(ppls.iter()
            .map(|&(n, p)| (n.to_string(), Value::Num(p)))
            .collect())),
        ("ppl_drift", Value::Obj(ppls[1..].iter()
            .map(|&(n, p)| (n.to_string(), Value::Num(p - ppl_f64)))
            .collect())),
    ]);
    let out = std::env::var("BENCH_DECODE_JSON")
        .unwrap_or_else(|_| "BENCH_DECODE.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write json");
    println!("wrote {out}");

    println!("== cache capacity at a matched budget (benefit ii) ==");
    let budget = 1 << 20;
    let (rk, rv) = latent_demo_ranks(BENCH_CFG.d);
    let dense_c = KvCacheManager::new(CacheKind::Dense { d: BENCH_CFG.d },
                                      BENCH_CFG.n_layers, 2, budget);
    let latent_c = KvCacheManager::new(CacheKind::Latent { rk, rv },
                                       BENCH_CFG.n_layers, 2, budget);
    println!("  dense : {:>4} bytes/tok -> {:>6} token capacity",
             dense_c.bytes_per_token(), dense_c.capacity_tokens());
    println!("  latent: {:>4} bytes/tok -> {:>6} token capacity ({:.1}x)",
             latent_c.bytes_per_token(), latent_c.capacity_tokens(),
             latent_c.capacity_tokens() as f64
                 / dense_c.capacity_tokens().max(1) as f64);
    std::fs::remove_dir_all(&dir).ok();
}
