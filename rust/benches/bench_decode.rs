//! Decode-path bench: incremental KV-cached sessions versus the
//! full-window recompute reference, plus the paper's benefit (ii) —
//! dense vs latent cache capacity at a matched byte budget.
//!
//! The acceptance story: recompute re-executes the whole [1, T] window
//! per emitted token (O(T²·d²) total), so its per-token cost grows with
//! context length; a session reads prior K/V from the cache (O(T·d² +
//! T²·d) total), so its per-token cost stays ~flat until attention
//! itself dominates. Fully offline — artifacts are synthesized into a
//! tempdir.
//!
//! Run: cargo bench --bench bench_decode

use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::data::synth::{latent_demo_ranks, write_test_artifacts};
use latentllm::eval::generate::{generate, GenerateOpts};
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::runtime::Engine;

const BENCH_CFG: MiniConfig = MiniConfig {
    name: "bench-decode", vocab: 96, d: 48, n_layers: 2, n_heads: 4,
    d_i: 96, max_len: 256,
};

fn main() {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_decode_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let tag = write_test_artifacts(&dir, &BENCH_CFG, 3)
        .expect("synthesize artifacts");
    let engine = Engine::new(&dir).expect("engine");
    let dense_w = Weights::load(
        dir.join(format!("model_{}.ltw", BENCH_CFG.name))).unwrap();
    let latent_w = Weights::load(
        dir.join(format!("latent_model_{tag}.ltw"))).unwrap();

    println!("== decode scaling: incremental vs full-window recompute ==");
    println!("model {} (d={}, L={}); one lane, prompt 8, greedy",
             BENCH_CFG.name, BENCH_CFG.d, BENCH_CFG.n_layers);
    let prompt: Vec<Vec<i32>> = vec![(0..8)
        .map(|i| (i * 7) % BENCH_CFG.vocab as i32).collect()];
    for (label, program, weights) in
        [("dense ", format!("step_{}", BENCH_CFG.name), &dense_w),
         ("latent", format!("latent_step_{tag}"), &latent_w)] {
        for max_new in [32usize, 64, 128] {
            // the recompute window is sized to the context it must hold,
            // so its cost reflects the actual O(T²) re-execution
            let window = 8 + max_new;
            let run = |use_cache: bool| {
                let opts = GenerateOpts {
                    max_new, temperature: 0.0, seed: 1, use_cache,
                };
                generate(&engine, &program, weights, &prompt, 1, window,
                         BENCH_CFG.vocab, &opts).expect("generate")
            };
            let inc = run(true);
            let rec = run(false);
            assert_eq!(inc.sequences, rec.sequences,
                       "bench paths must agree token-for-token");
            let per_tok = |s: f64| s * 1e3 / max_new as f64;
            println!("  {label} T={max_new:>3}: incremental \
                      {:>7.3} ms/tok  recompute {:>7.3} ms/tok  \
                      ({:.1}x, cache {} floats)",
                     per_tok(inc.seconds), per_tok(rec.seconds),
                     rec.seconds / inc.seconds.max(1e-12),
                     inc.peak_cache_elements);
        }
    }

    println!("== cache capacity at a matched budget (benefit ii) ==");
    let budget = 1 << 20;
    let (rk, rv) = latent_demo_ranks(BENCH_CFG.d);
    let dense_c = KvCacheManager::new(CacheKind::Dense { d: BENCH_CFG.d },
                                      BENCH_CFG.n_layers, 2, budget);
    let latent_c = KvCacheManager::new(CacheKind::Latent { rk, rv },
                                       BENCH_CFG.n_layers, 2, budget);
    println!("  dense : {:>4} bytes/tok -> {:>6} token capacity",
             dense_c.bytes_per_token(), dense_c.capacity_tokens());
    println!("  latent: {:>4} bytes/tok -> {:>6} token capacity ({:.1}x)",
             latent_c.bytes_per_token(), latent_c.capacity_tokens(),
             latent_c.capacity_tokens() as f64
                 / dense_c.capacity_tokens().max(1) as f64);
    std::fs::remove_dir_all(&dir).ok();
}
