//! Decode-path bench: incremental KV-cached sessions versus the
//! full-window recompute reference, the execution-layout sweep
//! (f64 / f32 / int8 weights through the same decode sessions), and the
//! paper's benefit (ii) — dense vs latent cache capacity at a matched
//! byte budget.
//!
//! The acceptance story: recompute re-executes the whole [1, T] window
//! per emitted token (O(T²·d²) total), so its per-token cost grows with
//! context length; a session reads prior K/V from the cache (O(T·d² +
//! T²·d) total), so its per-token cost stays ~flat until attention
//! itself dominates. The layout sweep then holds the session machinery
//! fixed and swaps the weight kernels: the blocked f32 panels and the
//! fused-dequant int8 path against the bit-exact f64 reference.
//!
//! Machine-readable results land in BENCH_DECODE.json (override the
//! path with BENCH_DECODE_JSON): ms/token + tok/s per layout × path ×
//! T ∈ {32, 64, 128}, int8-vs-f64 speedups, and the perplexity drift
//! each layout costs on the dense scoring program.
//!
//! Run: cargo bench --bench bench_decode

use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::data::synth::{latent_demo_ranks, write_test_artifacts};
use latentllm::data::Corpus;
use latentllm::eval::generate::{generate, GenerateOpts};
use latentllm::eval::perplexity;
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::runtime::Engine;
use latentllm::util::json::Value;
use latentllm::Layout;

// wide enough that per-token matmul work dominates session bookkeeping
// (the layout kernels target the matmul side; a toy d would measure
// overhead, not kernels)
const BENCH_CFG: MiniConfig = MiniConfig {
    name: "bench-decode", vocab: 256, d: 96, n_layers: 2, n_heads: 4,
    d_i: 192, max_len: 256,
};

const LAYOUTS: [Layout; 3] =
    [Layout::DenseF64, Layout::PackedF32, Layout::QuantI8];
const TS: [usize; 3] = [32, 64, 128];
const QUANT_CHUNK: usize = 64;

struct Run {
    path: &'static str,
    layout: Layout,
    max_new: usize,
    ms_per_tok: f64,
    tok_s: f64,
}

fn main() {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_decode_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let tag = write_test_artifacts(&dir, &BENCH_CFG, 3)
        .expect("synthesize artifacts");
    let engine = Engine::new(&dir).expect("engine");
    let dense_w = Weights::load(
        dir.join(format!("model_{}.ltw", BENCH_CFG.name))).unwrap();
    let latent_w = Weights::load(
        dir.join(format!("latent_model_{tag}.ltw"))).unwrap();

    println!("== decode scaling: incremental vs full-window recompute ==");
    println!("model {} (d={}, L={}); one lane, prompt 8, greedy",
             BENCH_CFG.name, BENCH_CFG.d, BENCH_CFG.n_layers);
    let prompt: Vec<Vec<i32>> = vec![(0..8)
        .map(|i| (i * 7) % BENCH_CFG.vocab as i32).collect()];
    for (label, program, weights) in
        [("dense ", format!("step_{}", BENCH_CFG.name), &dense_w),
         ("latent", format!("latent_step_{tag}"), &latent_w)] {
        for max_new in TS {
            // the recompute window is sized to the context it must hold,
            // so its cost reflects the actual O(T²) re-execution
            let window = 8 + max_new;
            let run = |use_cache: bool| {
                let opts = GenerateOpts {
                    max_new, temperature: 0.0, seed: 1, use_cache,
                };
                generate(&engine, &program, weights, &prompt, 1, window,
                         BENCH_CFG.vocab, &opts).expect("generate")
            };
            let inc = run(true);
            let rec = run(false);
            assert_eq!(inc.sequences, rec.sequences,
                       "bench paths must agree token-for-token");
            let per_tok = |s: f64| s * 1e3 / max_new as f64;
            println!("  {label} T={max_new:>3}: incremental \
                      {:>7.3} ms/tok  recompute {:>7.3} ms/tok  \
                      ({:.1}x, cache {} floats)",
                     per_tok(inc.seconds), per_tok(rec.seconds),
                     rec.seconds / inc.seconds.max(1e-12),
                     inc.peak_cache_elements);
        }
    }

    println!("== execution layouts: f64 / f32 / int8 decode kernels ==");
    let mut runs: Vec<Run> = Vec::new();
    for (path, program, base) in
        [("dense", format!("step_{}", BENCH_CFG.name), &dense_w),
         ("latent", format!("latent_step_{tag}"), &latent_w)] {
        for layout in LAYOUTS {
            let weights = if layout == Layout::DenseF64 {
                (*base).clone()
            } else {
                base.repack(layout, QUANT_CHUNK).expect("repack")
            };
            // warm up: builds + packs the model once so timing below
            // measures steady-state decode, not load-time packing
            let warm = GenerateOpts {
                max_new: 4, temperature: 0.0, seed: 1, use_cache: true,
            };
            generate(&engine, &program, &weights, &prompt, 1, 16,
                     BENCH_CFG.vocab, &warm).expect("warmup");
            for max_new in TS {
                let opts = GenerateOpts {
                    max_new, temperature: 0.0, seed: 1, use_cache: true,
                };
                let res = generate(&engine, &program, &weights, &prompt, 1,
                                   8 + max_new, BENCH_CFG.vocab, &opts)
                    .expect("generate");
                let ms = res.seconds * 1e3 / max_new as f64;
                println!("  {path:<6} {:<5} T={max_new:>3}: \
                          {ms:>7.3} ms/tok  {:>8.1} tok/s",
                         layout.name(), res.tokens_per_sec);
                runs.push(Run { path, layout, max_new,
                                ms_per_tok: ms,
                                tok_s: res.tokens_per_sec });
            }
        }
    }
    // speedup vs the f64 reference at the longest context
    let tok_s = |path: &str, layout: Layout| runs.iter()
        .find(|r| r.path == path && r.layout == layout
              && r.max_new == TS[TS.len() - 1])
        .map(|r| r.tok_s).unwrap_or(f64::NAN);
    let mut speedups: Vec<(&str, Value)> = Vec::new();
    for path in ["dense", "latent"] {
        let base = tok_s(path, Layout::DenseF64);
        for layout in [Layout::PackedF32, Layout::QuantI8] {
            let s = tok_s(path, layout) / base.max(1e-12);
            println!("  {path} {} speedup vs f64 @ T={}: {s:.2}x",
                     layout.name(), TS[TS.len() - 1]);
        }
        speedups.push((path, Value::obj(vec![
            ("f32", Value::Num(tok_s(path, Layout::PackedF32)
                               / base.max(1e-12))),
            ("int8", Value::Num(tok_s(path, Layout::QuantI8)
                                / base.max(1e-12))),
        ])));
    }

    // accuracy side of the tradeoff: perplexity through the dense
    // scoring program per layout
    let corpus = Corpus::load(dir.join("corpora.ltw"), "synthwiki", "test")
        .expect("corpus");
    let score = format!("score_{}", BENCH_CFG.name);
    let mut ppls: Vec<(&str, f64)> = Vec::new();
    for layout in LAYOUTS {
        let weights = if layout == Layout::DenseF64 {
            dense_w.clone()
        } else {
            dense_w.repack(layout, QUANT_CHUNK).expect("repack")
        };
        let r = perplexity(&engine, &score, &weights, &corpus, 4, 96, 3)
            .expect("perplexity");
        println!("  ppl({}) = {:.4}", layout.name(), r.ppl);
        ppls.push((layout.name(), r.ppl));
    }
    let ppl_f64 = ppls[0].1;
    for &(name, p) in &ppls[1..] {
        println!("  ppl drift {name} vs f64: {:+.5}", p - ppl_f64);
    }

    let json = Value::obj(vec![
        ("model", Value::obj(vec![
            ("name", Value::Str(BENCH_CFG.name.to_string())),
            ("d", Value::Num(BENCH_CFG.d as f64)),
            ("n_layers", Value::Num(BENCH_CFG.n_layers as f64)),
            ("vocab", Value::Num(BENCH_CFG.vocab as f64)),
        ])),
        ("quant_chunk", Value::Num(QUANT_CHUNK as f64)),
        ("results", Value::Arr(runs.iter().map(|r| Value::obj(vec![
            ("path", Value::Str(r.path.to_string())),
            ("layout", Value::Str(r.layout.name().to_string())),
            ("t", Value::Num(r.max_new as f64)),
            ("ms_per_tok", Value::Num(r.ms_per_tok)),
            ("tok_s", Value::Num(r.tok_s)),
        ])).collect())),
        ("speedup_vs_f64", Value::obj(speedups)),
        ("ppl", Value::Obj(ppls.iter()
            .map(|&(n, p)| (n.to_string(), Value::Num(p)))
            .collect())),
        ("ppl_drift", Value::Obj(ppls[1..].iter()
            .map(|&(n, p)| (n.to_string(), Value::Num(p - ppl_f64)))
            .collect())),
    ]);
    let out = std::env::var("BENCH_DECODE_JSON")
        .unwrap_or_else(|_| "BENCH_DECODE.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write json");
    println!("wrote {out}");

    println!("== cache capacity at a matched budget (benefit ii) ==");
    let budget = 1 << 20;
    let (rk, rv) = latent_demo_ranks(BENCH_CFG.d);
    let dense_c = KvCacheManager::new(CacheKind::Dense { d: BENCH_CFG.d },
                                      BENCH_CFG.n_layers, 2, budget);
    let latent_c = KvCacheManager::new(CacheKind::Latent { rk, rv },
                                       BENCH_CFG.n_layers, 2, budget);
    println!("  dense : {:>4} bytes/tok -> {:>6} token capacity",
             dense_c.bytes_per_token(), dense_c.capacity_tokens());
    println!("  latent: {:>4} bytes/tok -> {:>6} token capacity ({:.1}x)",
             latent_c.bytes_per_token(), latent_c.capacity_tokens(),
             latent_c.capacity_tokens() as f64
                 / dense_c.capacity_tokens().max(1) as f64);
    std::fs::remove_dir_all(&dir).ok();
}
