//! Linear-algebra substrate benches — the §Perf L3 hot-path baseline:
//! matmul, symmetric eig (Algorithm 1's inner op), SVD, sqrtm.
//!
//! Run: cargo bench --offline (custom harness, see util::bench)

use latentllm::tensor::{eigh, sqrtm_psd, svd_truncated, topk_eigvecs};
use latentllm::util::bench::Bench;
use latentllm::util::rng::Rng;

fn main() {
    let mut b = Bench::new(0.6);
    let mut rng = Rng::new(1);
    println!("== linalg substrate ==");
    for d in [64usize, 128, 256] {
        let a = rng.normal_matrix(d, d);
        let bm = rng.normal_matrix(d, d);
        b.run(&format!("matmul {d}x{d}"), || a.matmul(&bm));
        b.run(&format!("matmul_bt {d}x{d}"), || a.matmul_bt(&bm));
        let psd = a.matmul_bt(&a);
        b.run(&format!("eigh {d}x{d}"), || eigh(&psd));
        b.run(&format!("topk_eigvecs {d}->k32"),
              || topk_eigvecs(&psd, 32.min(d)));
        b.run(&format!("sqrtm {d}x{d}"), || sqrtm_psd(&psd));
        b.run(&format!("svd_r32 {d}x{d}"),
              || svd_truncated(&a, 32.min(d)));
    }
    // the UD-path shape: tall covariance
    let tall = rng.normal_matrix(384, 96);
    b.run("svd_r48 384x96 (UD shape)", || svd_truncated(&tall, 48));
}
