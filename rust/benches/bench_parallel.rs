//! Parallel-subsystem bench: speedup of the pool-backed paths over their
//! serial baselines — row-parallel matmul, layer-parallel compress_model,
//! and the Table-2-sized method sweep (the acceptance target is >1.5×
//! at 4 threads on the sweep). Thread counts are pinned in-process via
//! `pool::set_global_threads`, so the numbers are comparable regardless
//! of `LATENTLLM_THREADS`.
//!
//! Run: cargo bench --bench bench_parallel

use latentllm::compress::pipeline::{self, tests_support::random_weights,
                                    Method, TABLE2_METHODS};
use latentllm::data::CalibSet;
use latentllm::model::config::OPT_MINI_M;
use latentllm::util::bench::Bench;
use latentllm::util::pool::{self, Pool};
use latentllm::util::rng::Rng;

const THREADS: usize = 4;

fn main() {
    println!("== parallel subsystem (1 vs {THREADS} threads) ==");

    // --- row-parallel matmul
    let mut rng = Rng::new(5);
    let n = 384;
    let a = rng.normal_matrix(n, n);
    let b = rng.normal_matrix(n, n);
    let mut bench = Bench::new(0.4);
    pool::set_global_threads(1);
    let m1 = bench.run(&format!("matmul {n}x{n} threads=1"),
                       || a.matmul(&b)).mean_ns;
    pool::set_global_threads(THREADS);
    let mt = bench.run(&format!("matmul {n}x{n} threads={THREADS}"),
                       || a.matmul(&b)).mean_ns;
    println!("  -> matmul speedup {:.2}x", m1 / mt);

    // --- layer-parallel whole-model pipeline (opt-mini-m, 4 layers)
    let cfg = OPT_MINI_M;
    let weights = random_weights(&cfg, 7);
    let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 256, 3);
    let mut bp = Bench::new(0.1);
    bp.max_iters = 3;
    pool::set_global_threads(1);
    let p1 = bp.run("pipeline latentllm@30% threads=1", || {
        pipeline::compress_model(&cfg, &weights, &cal, Method::LatentLlm,
                                 0.3, 4, 2).unwrap()
    }).mean_ns;
    pool::set_global_threads(THREADS);
    let pt = bp.run(&format!("pipeline latentllm@30% threads={THREADS}"),
                    || {
        pipeline::compress_model(&cfg, &weights, &cal, Method::LatentLlm,
                                 0.3, 4, 2).unwrap()
    }).mean_ns;
    println!("  -> pipeline speedup {:.2}x", p1 / pt);

    // --- Table-2-sized sweep: all six methods at 30%, compressed
    // concurrently the way reports::table2 does
    let sweep = || {
        Pool::global().run(TABLE2_METHODS.len(), |i| {
            pipeline::compress_model(&cfg, &weights, &cal,
                                     TABLE2_METHODS[i], 0.3, 2, 1)
                .unwrap().1.achieved_ratio()
        })
    };
    let mut bs = Bench::new(0.1);
    bs.max_iters = 3;
    pool::set_global_threads(1);
    let s1 = bs.run("table2 sweep (6 methods) threads=1", || sweep())
        .mean_ns;
    pool::set_global_threads(THREADS);
    let st = bs.run(&format!("table2 sweep (6 methods) threads={THREADS}"),
                    || sweep()).mean_ns;
    let speedup = s1 / st;
    println!("  -> sweep speedup {speedup:.2}x (target >1.5x)");
    pool::set_global_threads(pool::configured_threads());
}
