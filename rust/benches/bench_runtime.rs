//! Runtime benches: program compile/load time and scoring-program
//! execution throughput (tokens/s), dense vs latent-architecture programs,
//! on the engine's configured backend (RefBackend by default, PJRT via
//! `--features pjrt` + `LATENTLLM_BACKEND=pjrt`).
//! Requires artifacts (`make artifacts`); skips gracefully otherwise.

use latentllm::data::Corpus;
use latentllm::model::Weights;
use latentllm::runtime::{Engine, ParamValue};
use latentllm::util::bench::Bench;

fn main() {
    let artifacts = std::env::var("LATENTLLM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("bench_runtime: no artifacts at {artifacts} — skipping \
                  (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&artifacts).expect("engine");
    let model = "opt-mini-m";
    let weights = Weights::load(format!("{artifacts}/model_{model}.ltw"))
        .expect("weights");
    let corpus = Corpus::load(format!("{artifacts}/corpora.ltw"),
                              "synthwiki", "test").expect("corpus");
    let (b, t) = (8usize, 128usize);
    let batch = corpus.batches(b, t).into_iter().next().unwrap();

    let mut bench = Bench::new(1.0);
    println!("== runtime (backend: {}) ==", engine.backend_name());
    bench.run("compile score program (cold-ish)", || {
        // compile cache makes repeats cheap; measure the cached fetch too
        engine.program(&format!("score_{model}")).unwrap()
    });
    let prog = engine.program(&format!("score_{model}")).unwrap();
    let stats = bench.run("score exec 8x128 (dense)", || {
        let tokens = ParamValue::I32 { shape: vec![b, t],
                                       data: batch.clone() };
        prog.run_f32(&[tokens], &weights).unwrap()
    });
    let toks_per_s = (b * t) as f64 / (stats.mean_ns / 1e9);
    println!("  -> {toks_per_s:.0} tokens/s (dense scoring)");

    // latent-architecture program (true MLA execution path)
    let tag_entry = engine.manifest().path(&["latent_demo", "tag"])
        .and_then(|v| v.as_str()).map(String::from);
    if let Some(tag) = tag_entry {
        let lat_w = Weights::load(
            format!("{artifacts}/latent_model_{tag}.ltw")).unwrap();
        let lprog = engine.program(&format!("latent_score_{tag}")).unwrap();
        let stats = bench.run("score exec 8x128 (latent/MLA)", || {
            let tokens = ParamValue::I32 { shape: vec![b, t],
                                           data: batch.clone() };
            lprog.run_f32(&[tokens], &lat_w).unwrap()
        });
        let l_toks = (b * t) as f64 / (stats.mean_ns / 1e9);
        println!("  -> {l_toks:.0} tokens/s (latent scoring, \
                  {:.2}x dense)", l_toks / toks_per_s);
    }
}
