//! End-to-end serving bench: throughput and latency quantiles of the
//! coordinator (batcher + router + PJRT worker) under a closed-loop load,
//! across batcher configurations — the L3 target of EXPERIMENTS.md §Perf.

use std::time::Duration;

use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::server::{ScoreRequest, Server, ServerConfig};
use latentllm::data::Corpus;
use latentllm::model::config::mini_by_name;
use latentllm::model::Weights;

fn main() {
    let artifacts = std::env::var("LATENTLLM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("bench_serving: no artifacts — skipping");
        return;
    }
    let model = "opt-mini-m";
    let cfg = mini_by_name(model).unwrap();
    let weights = Weights::load(format!("{artifacts}/model_{model}.ltw"))
        .unwrap();
    let corpus = Corpus::load(format!("{artifacts}/corpora.ltw"),
                              "synthwiki", "test").unwrap();
    let n_requests = 64usize;

    println!("== serving e2e (batcher × worker sweep) ==");
    let weights = std::sync::Arc::new(weights);
    for (workers, max_batch, wait_ms) in
        [(1usize, 1usize, 0u64), (1, 4, 2), (1, 8, 5), (1, 8, 20),
         (2, 8, 5), (4, 8, 5)] {
        let variants = vec![ModelVariant {
            name: "dense".into(),
            score_program: format!("score_{model}"),
            step_program: format!("step_{model}"),
            weights: weights.clone(),
            cache: KvCacheManager::new(CacheKind::Dense { d: cfg.d },
                                       cfg.n_layers, 2, 64 << 20),
        }];
        let server = Server::start(
            artifacts.clone().into(),
            Router::new(variants, Policy::RoundRobin),
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                policy: Policy::RoundRobin,
                program_batch: 8,
                seq_len: 128,
                workers,
            })
            .expect("server start");
        let reqs = corpus.calibration(n_requests, 128, 42);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = reqs.into_iter().enumerate()
            .map(|(i, tokens)| server.submit(ScoreRequest {
                id: i as u64, tokens }).expect("submit"))
            .collect();
        for rx in rxs {
            let _ = rx.recv();
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let (p50, p95, p99) = m.quantiles("request_us")
            .unwrap_or((0.0, 0.0, 0.0));
        println!("workers={workers} max_batch={max_batch:<2} \
                  wait={wait_ms:>2}ms: \
                  {:>6.1} req/s  p50={:>7.0}µs p95={:>7.0}µs p99={:>7.0}µs \
                  batches={}",
                 n_requests as f64 / dt, p50, p95, p99,
                 m.counter("batches"));
    }
}
