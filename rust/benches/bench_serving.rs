//! End-to-end serving bench: throughput and latency quantiles of the
//! coordinator under closed-loop load — the L3 target of EXPERIMENTS.md
//! §Perf.
//!
//! Three sections:
//!
//! * **Mixed score+generate** (always runs; artifacts synthesized into a
//!   tempdir): the same concurrent workload driven once through the
//!   continuous-batching scheduler and once through sequential
//!   one-session-per-worker decode, at a page budget tight enough that
//!   sessions contend. Reports completed requests, successful decode
//!   tokens/sec, and p50/p95 queue wait — the scheduler's preemption
//!   (requeue + resume) versus the sequential path's evictions (failed
//!   requests) is the headline number. Plus the capacity probe: live
//!   sessions a matched page budget admits, dense vs latent.
//! * **Shared-prefix prefill** (always runs): a prefill-dominated
//!   generate workload at 0% and 90% prompt sharing, scheduler vs
//!   sequential, with a warm second wave that re-submits against the
//!   cold wave's donated blocks. Reports prefill ms/request and goodput
//!   tok/s per (sharing, mode, phase) cell and writes the machine-
//!   readable summary to `BENCH_SERVING.json` (path overridable via
//!   `BENCH_SERVING_JSON`), headline field
//!   `prefill_ms_reduction_at_90_shared`.
//! * **Score-only batcher×worker sweep** (needs real `artifacts/`,
//!   skipped otherwise) — the original closed-loop scoring bench.

use std::time::Duration;

use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::scheduler::SchedulerConfig;
use latentllm::coordinator::server::{Drain, GenerateParams, ScoreParams,
                                     Server, ServerConfig};
use latentllm::data::synth::{latent_demo_ranks, write_test_artifacts};
use latentllm::data::Corpus;
use latentllm::model::config::{mini_by_name, MiniConfig};
use latentllm::model::Weights;
use latentllm::util::json::Value;

const MIX_CFG: MiniConfig = MiniConfig {
    name: "bench-serve", vocab: 96, d: 32, n_layers: 2, n_heads: 4,
    d_i: 64, max_len: 64,
};
const PROMPT_LEN: usize = 8;
const MAX_NEW: usize = 24;
const N_GEN: usize = 6;
const N_SCORE: usize = 12;
const BLOCK_TOKENS: usize = 4;

// shared-prefix section: long prompts, short decodes, so prefill
// dominates and prefix reuse moves the wall clock
const SP_PROMPT: usize = 40;
const SP_NEW: usize = 4;
const SP_REQS: usize = 12;

fn main() {
    mixed_workload();
    let live_scaling = live_scaling_workload();
    let trace_overhead = trace_overhead_workload();
    shared_prefix_workload(live_scaling, trace_overhead);
    score_sweep();
}

/// Build the tight-budget single-variant server for the mixed bench.
fn mix_server(art: &std::path::Path, weights: &std::sync::Arc<Weights>,
              budget: usize, sched: Option<SchedulerConfig>) -> Server {
    let variants = vec![ModelVariant {
        name: "dense".into(),
        score_program: format!("score_{}", MIX_CFG.name),
        step_program: format!("step_{}", MIX_CFG.name),
        weights: weights.clone(),
        cache: KvCacheManager::with_block_tokens(
            CacheKind::Dense { d: MIX_CFG.d }, MIX_CFG.n_layers, 2,
            budget, BLOCK_TOKENS),
    }];
    Server::start(
        art.to_path_buf(),
        Router::new(variants, Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            policy: Policy::RoundRobin,
            program_batch: 8,
            seq_len: MIX_CFG.max_len,
            workers: 2,
            sched,
            trace: true,
        })
        .expect("server start")
}

fn mixed_workload() {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_serving_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_test_artifacts(&dir, &MIX_CFG, 11).expect("synth artifacts");
    let weights = std::sync::Arc::new(Weights::load(
        dir.join(format!("model_{}.ltw", MIX_CFG.name))).unwrap());

    // page pool for ~1.5 full decodes: each request needs
    // ceil((PROMPT_LEN + MAX_NEW - 1) · bpt / block) = 8 blocks, so
    // concurrent sessions contend and the two modes diverge: sequential
    // decode EVICTS the loser (failed request, tokens wasted) while the
    // scheduler preempts + requeues it (all requests finish)
    let bpt = 2 * MIX_CFG.d * 2 * MIX_CFG.n_layers;
    let budget = 12 * BLOCK_TOKENS * bpt;

    println!("== mixed score+generate: continuous batching vs sequential \
              sessions ==");
    println!("model {} (d={}, L={}), 2 workers, {N_GEN} generate \
              (prompt {PROMPT_LEN}, max_new {MAX_NEW}) + {N_SCORE} score, \
              {}-block pool of {} tokens",
             MIX_CFG.name, MIX_CFG.d, MIX_CFG.n_layers,
             budget / (BLOCK_TOKENS * bpt), BLOCK_TOKENS);
    for (label, sched) in [
        ("sequential", None),
        ("scheduler ",
         Some(SchedulerConfig { max_live: 4, block_tokens: BLOCK_TOKENS,
                                prefill_chunk: 8, fused: true })),
    ] {
        let server = mix_server(&dir, &weights, budget, sched);
        let t0 = std::time::Instant::now();
        let gen_rxs: Vec<_> = (0..N_GEN)
            .map(|i| server.submit_generate(GenerateParams {
                prompt: (0..PROMPT_LEN)
                    .map(|j| ((i * 13 + j * 5) % MIX_CFG.vocab) as i32)
                    .collect(),
                max_new: MAX_NEW,
                temperature: 0.0,
                seed: i as u64,
            }).expect("submit_generate"))
            .collect();
        let score_rxs: Vec<_> = (0..N_SCORE)
            .map(|i| server.submit_score(ScoreParams {
                tokens: (0..16)
                    .map(|j| ((i * 7 + j) % MIX_CFG.vocab) as i32)
                    .collect(),
            }).expect("submit"))
            .collect();
        let mut gen_ok = 0usize;
        let mut gen_failed = 0usize;
        for rx in gen_rxs {
            match rx.recv() {
                Ok(r) if r.error().is_none() => gen_ok += 1,
                _ => gen_failed += 1,
            }
        }
        let mut score_ok = 0usize;
        for rx in score_rxs {
            if let Ok(r) = rx.recv() {
                if r.error().is_none() {
                    score_ok += 1;
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.shutdown(Drain::Graceful);
        let tokens = m.counter("gen_tokens");
        let (p50, p95, _) = m.quantiles("gen_queue_us")
            .unwrap_or((0.0, 0.0, 0.0));
        println!("  {label}: gen {gen_ok}/{N_GEN} ok ({gen_failed} \
                  failed), score {score_ok}/{N_SCORE}, \
                  {tokens} tokens in {dt:.2}s = {:>6.1} tok/s | \
                  queue wait p50={:.0}µs p95={:.0}µs | \
                  preempt={} evict={} occupancy={}",
                 tokens as f64 / dt.max(1e-9), p50, p95,
                 m.counter("gen_preemptions"),
                 m.counter("gen_evictions"),
                 m.ratio_pct("sched_steps", "sched_slots"));
    }

    // capacity probe (paper benefit (ii), paged): live sessions a
    // matched pool admits at the full per-request footprint
    let (rk, rv) = latent_demo_ranks(MIX_CFG.d);
    let need = PROMPT_LEN + MAX_NEW - 1;
    let mut line = String::new();
    for (name, kind) in [("dense ", CacheKind::Dense { d: MIX_CFG.d }),
                         ("latent", CacheKind::Latent { rk, rv })] {
        let mut c = KvCacheManager::with_block_tokens(
            kind, MIX_CFG.n_layers, 2, budget, BLOCK_TOKENS);
        let mut n = 0u64;
        while c.admit(n, need) {
            n += 1;
        }
        line.push_str(&format!("  {name}: {n} live sessions \
                                ({} blocks of {} B)\n",
                               c.total_blocks(), c.block_bytes()));
    }
    println!("capacity at a matched {budget}-byte page budget, \
              {need}-token sessions:\n{line}");
    std::fs::remove_dir_all(&dir).ok();
}

// live-session scaling: decode-dominated traffic, wide enough that the
// per-token GEMMs are real work (a toy d would measure dispatch
// overhead, not the fused weight pass)
const LIVE_CFG: MiniConfig = MiniConfig {
    name: "bench-live", vocab: 128, d: 96, n_layers: 2, n_heads: 4,
    d_i: 192, max_len: 64,
};
const LIVE_PROMPT: usize = 6;
const LIVE_NEW: usize = 40;
const LIVE_COUNTS: [usize; 4] = [1, 4, 8, 16];

/// Fused vs per-sequence stepping at live ∈ {1, 4, 8, 16} concurrent
/// decodes on ONE worker: the step batch is exactly `live` wide, so the
/// fused weight pass amortizes (and row-parallelizes) each layer's
/// GEMMs across the whole live set while the fallback loop streams the
/// weights once per sequence. Token streams are asserted bit-equal
/// between the two modes. Returns the JSON section (with the headline
/// `fused_speedup_at_8_live`) for BENCH_SERVING.json.
fn live_scaling_workload() -> Value {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_live_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_test_artifacts(&dir, &LIVE_CFG, 23).expect("synth artifacts");
    let weights = std::sync::Arc::new(Weights::load(
        dir.join(format!("model_{}.ltw", LIVE_CFG.name))).unwrap());
    // roomy pool — this section measures stepping, not contention
    let bpt = 2 * LIVE_CFG.d * 2 * LIVE_CFG.n_layers;
    let budget = 16 * ((LIVE_PROMPT + LIVE_NEW) / BLOCK_TOKENS + 2)
        * BLOCK_TOKENS * bpt;

    println!("== live-session scaling: fused vs per-sequence stepping ==");
    println!("model {} (d={}, L={}), 1 worker, prompt {LIVE_PROMPT}, \
              max_new {LIVE_NEW}, greedy",
             LIVE_CFG.name, LIVE_CFG.d, LIVE_CFG.n_layers);
    let mut rows: Vec<(usize, &'static str, f64, f64)> = Vec::new();
    for live in LIVE_COUNTS {
        let mut streams: Vec<Vec<Vec<i32>>> = Vec::new();
        for fused in [true, false] {
            let variants = vec![ModelVariant {
                name: "dense".into(),
                score_program: format!("score_{}", LIVE_CFG.name),
                step_program: format!("step_{}", LIVE_CFG.name),
                weights: weights.clone(),
                cache: KvCacheManager::with_block_tokens(
                    CacheKind::Dense { d: LIVE_CFG.d }, LIVE_CFG.n_layers,
                    2, budget, BLOCK_TOKENS),
            }];
            let server = Server::start(
                dir.to_path_buf(),
                Router::new(variants, Policy::RoundRobin),
                ServerConfig {
                    batcher: BatcherConfig {
                        max_batch: 4,
                        max_wait: Duration::from_millis(2),
                    },
                    policy: Policy::RoundRobin,
                    program_batch: 8,
                    seq_len: LIVE_CFG.max_len,
                    workers: 1,
                    sched: Some(SchedulerConfig {
                        max_live: live, block_tokens: BLOCK_TOKENS,
                        prefill_chunk: 8, fused,
                    }),
                    trace: true,
                })
                .expect("server start");
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = (0..live)
                .map(|i| server.submit_generate(GenerateParams {
                    prompt: (0..LIVE_PROMPT)
                        .map(|j| ((i * 17 + j * 5) % LIVE_CFG.vocab) as i32)
                        .collect(),
                    max_new: LIVE_NEW,
                    temperature: 0.0,
                    seed: i as u64,
                }).expect("submit_generate"))
                .collect();
            let tokens: Vec<Vec<i32>> = rxs.into_iter()
                .map(|rx| {
                    let r = rx.recv().expect("gen response");
                    assert!(r.error().is_none(), "decode failed");
                    r.tokens().to_vec()
                })
                .collect();
            let dt = t0.elapsed().as_secs_f64();
            let m = server.shutdown(Drain::Graceful);
            streams.push(tokens);
            let decoded = m.counter("gen_tokens");
            let (p50, _, _) = m.quantiles("step_us")
                .unwrap_or((0.0, 0.0, 0.0));
            let mode = if fused { "fused" } else { "per-seq" };
            if fused {
                assert!(m.counter("fused_batches") >= 1 || live == 1,
                        "live={live}: wide batches must fuse");
            } else {
                assert_eq!(m.counter("fused_batches"), 0,
                           "kill switch must hold");
            }
            println!("  live={live:>2} {mode:<7}: {decoded} tokens in \
                      {dt:.2}s = {:>7.1} tok/s | step p50={p50:.0}µs",
                     decoded as f64 / dt.max(1e-9));
            rows.push((live, mode, decoded as f64 / dt.max(1e-9), p50));
        }
        assert_eq!(streams[0], streams[1],
                   "live={live}: fused and per-sequence streams differ");
    }
    let tok_s_at = |live: usize, mode: &str| rows.iter()
        .find(|r| r.0 == live && r.1 == mode)
        .map(|r| r.2)
        .unwrap_or(f64::NAN);
    let speedup8 = tok_s_at(8, "fused") / tok_s_at(8, "per-seq").max(1e-9);
    println!("  fused speedup at 8 live sessions: {speedup8:.2}x");
    std::fs::remove_dir_all(&dir).ok();
    Value::obj(vec![
        ("model", Value::obj(vec![
            ("name", Value::Str(LIVE_CFG.name.to_string())),
            ("d", Value::Num(LIVE_CFG.d as f64)),
            ("n_layers", Value::Num(LIVE_CFG.n_layers as f64)),
        ])),
        ("prompt_len", Value::Num(LIVE_PROMPT as f64)),
        ("max_new", Value::Num(LIVE_NEW as f64)),
        ("results", Value::Arr(rows.iter().map(|&(live, mode, ts, p50)|
            Value::obj(vec![
                ("live", Value::Num(live as f64)),
                ("mode", Value::Str(mode.to_string())),
                ("tok_s", Value::Num(ts)),
                ("step_p50_us", Value::Num(p50)),
            ])).collect())),
        ("fused_speedup_at_8_live", Value::Num(speedup8)),
    ])
}

/// Tracing is on by default in production, so it must be effectively
/// free. The same decode-dominated workload runs traced and untraced,
/// interleaved, best-of-3 each: the streams must be bit-identical and
/// the traced goodput must stay within 2% of untraced (best-of compares
/// peak capability, which filters scheduler/allocator noise on shared
/// runners). Returns the JSON section for BENCH_SERVING.json.
fn trace_overhead_workload() -> Value {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_trace_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_test_artifacts(&dir, &LIVE_CFG, 29).expect("synth artifacts");
    let weights = std::sync::Arc::new(Weights::load(
        dir.join(format!("model_{}.ltw", LIVE_CFG.name))).unwrap());
    let bpt = 2 * LIVE_CFG.d * 2 * LIVE_CFG.n_layers;
    let budget = 16 * ((LIVE_PROMPT + LIVE_NEW) / BLOCK_TOKENS + 2)
        * BLOCK_TOKENS * bpt;
    let live = 8usize;

    println!("== request-trace overhead: traced vs untraced ==");
    println!("model {} (d={}, L={}), 1 worker, {live} concurrent \
              decodes of {LIVE_NEW} tokens, best of 3 runs per mode",
             LIVE_CFG.name, LIVE_CFG.d, LIVE_CFG.n_layers);
    // [untraced, traced]
    let mut best = [0.0f64; 2];
    let mut streams: [Option<Vec<Vec<i32>>>; 2] = [None, None];
    for _run in 0..3 {
        for (slot, trace) in [(0usize, false), (1usize, true)] {
            let variants = vec![ModelVariant {
                name: "dense".into(),
                score_program: format!("score_{}", LIVE_CFG.name),
                step_program: format!("step_{}", LIVE_CFG.name),
                weights: weights.clone(),
                cache: KvCacheManager::with_block_tokens(
                    CacheKind::Dense { d: LIVE_CFG.d }, LIVE_CFG.n_layers,
                    2, budget, BLOCK_TOKENS),
            }];
            let server = Server::start(
                dir.to_path_buf(),
                Router::new(variants, Policy::RoundRobin),
                ServerConfig {
                    batcher: BatcherConfig {
                        max_batch: 4,
                        max_wait: Duration::from_millis(2),
                    },
                    policy: Policy::RoundRobin,
                    program_batch: 8,
                    seq_len: LIVE_CFG.max_len,
                    workers: 1,
                    sched: Some(SchedulerConfig {
                        max_live: live, block_tokens: BLOCK_TOKENS,
                        prefill_chunk: 8, fused: true,
                    }),
                    trace,
                })
                .expect("server start");
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = (0..live)
                .map(|i| server.submit_generate(GenerateParams {
                    prompt: (0..LIVE_PROMPT)
                        .map(|j| ((i * 17 + j * 5) % LIVE_CFG.vocab)
                             as i32)
                        .collect(),
                    max_new: LIVE_NEW,
                    temperature: 0.0,
                    seed: i as u64,
                }).expect("submit_generate"))
                .collect();
            let tokens: Vec<Vec<i32>> = rxs.into_iter()
                .map(|rx| {
                    let r = rx.recv().expect("gen response");
                    assert!(r.error().is_none(), "decode failed");
                    r.tokens().to_vec()
                })
                .collect();
            let dt = t0.elapsed().as_secs_f64();
            let m = server.shutdown(Drain::Graceful);
            best[slot] = best[slot]
                .max(m.counter("gen_tokens") as f64 / dt.max(1e-9));
            match &streams[slot] {
                None => streams[slot] = Some(tokens),
                Some(prev) => assert_eq!(
                    prev, &tokens,
                    "trace={trace}: token streams changed across runs"),
            }
        }
    }
    assert_eq!(streams[0], streams[1],
               "tracing changed the token streams — it must be a pure \
                observer");
    let overhead = 1.0 - best[1] / best[0].max(1e-9);
    println!("  untraced best {:.1} tok/s, traced best {:.1} tok/s \
              ({:+.2}% overhead)",
             best[0], best[1], overhead * 100.0);
    assert!(overhead < 0.02,
            "tracing costs {:.2}% goodput — over the 2% budget",
            overhead * 100.0);
    std::fs::remove_dir_all(&dir).ok();
    Value::obj(vec![
        ("untraced_tok_s", Value::Num(best[0])),
        ("traced_tok_s", Value::Num(best[1])),
        ("overhead_pct", Value::Num(overhead * 100.0)),
    ])
}

struct SpRun {
    sharing_pct: usize,
    mode: &'static str,
    phase: &'static str,
    seconds: f64,
    ms_per_request: f64,
    tok_s: f64,
}

/// Submit one wave of generate requests and block until all answer.
fn sp_wave(server: &Server, prompts: &[Vec<i32>]) -> (f64, usize) {
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts.iter().enumerate()
        .map(|(i, p)| server.submit_generate(GenerateParams {
            prompt: p.clone(),
            max_new: SP_NEW,
            temperature: 0.0,
            seed: i as u64,
        }).expect("submit_generate"))
        .collect();
    let mut ok = 0usize;
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            if r.error().is_none() {
                ok += 1;
            }
        }
    }
    (t0.elapsed().as_secs_f64(), ok)
}

fn shared_prefix_workload(live_scaling: Value, trace_overhead: Value) {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_bench_prefix_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_test_artifacts(&dir, &MIX_CFG, 17).expect("synth artifacts");
    let weights = std::sync::Arc::new(Weights::load(
        dir.join(format!("model_{}.ltw", MIX_CFG.name))).unwrap());
    // roomy pool — this section measures prefix reuse, not contention
    let bpt = 2 * MIX_CFG.d * 2 * MIX_CFG.n_layers;
    let budget = 48 * BLOCK_TOKENS * bpt;
    let sched_cfg = SchedulerConfig {
        max_live: 4, block_tokens: BLOCK_TOKENS, prefill_chunk: 8,
        fused: true,
    };

    println!("== shared-prefix prefill: content-addressed reuse ==");
    println!("{SP_REQS} generate requests, prompt {SP_PROMPT} tokens, \
              max_new {SP_NEW} (prefill-dominated); the warm wave \
              re-submits the same prompts against the cold wave's \
              donated blocks");
    let mut runs: Vec<SpRun> = Vec::new();
    let mut prefix_stats: Vec<(usize, u64, u64)> = Vec::new();
    for sharing_pct in [0usize, 90] {
        let shared = SP_PROMPT * sharing_pct / 100;
        let prompts: Vec<Vec<i32>> = (0..SP_REQS)
            .map(|i| (0..SP_PROMPT).map(|j| if j < shared {
                ((j * 11 + 5) % MIX_CFG.vocab) as i32
            } else {
                ((i * 31 + j * 11 + 5) % MIX_CFG.vocab) as i32
            }).collect())
            .collect();

        // sequential baseline: per-session caches, no prefix admission
        let seq = mix_server(&dir, &weights, budget, None);
        let (dt, ok) = sp_wave(&seq, &prompts);
        seq.shutdown(Drain::Graceful);
        runs.push(SpRun { sharing_pct, mode: "sequential", phase: "cold",
                          seconds: dt,
                          ms_per_request: dt * 1e3 / SP_REQS as f64,
                          tok_s: (ok * SP_NEW) as f64 / dt.max(1e-9) });

        // scheduler: the cold wave prefills and donates its prompt
        // blocks; the warm wave admits against them
        let server = mix_server(&dir, &weights, budget, Some(sched_cfg));
        for phase in ["cold", "warm"] {
            let (dt, ok) = sp_wave(&server, &prompts);
            runs.push(SpRun { sharing_pct, mode: "scheduler", phase,
                              seconds: dt,
                              ms_per_request: dt * 1e3 / SP_REQS as f64,
                              tok_s: (ok * SP_NEW) as f64
                                  / dt.max(1e-9) });
        }
        let m = server.shutdown(Drain::Graceful);
        prefix_stats.push((sharing_pct, m.counter("prefix_hits"),
                           m.counter("prefix_saved_tokens")));
    }
    for r in &runs {
        println!("  {:>2}% shared, {} {:<4}: {:>7.2} ms/request, \
                  {:>7.1} tok/s goodput",
                 r.sharing_pct, r.mode, r.phase, r.ms_per_request,
                 r.tok_s);
    }
    for &(pct, hits, saved) in &prefix_stats {
        println!("  {pct:>2}% shared: prefix hits={hits} \
                  saved_tokens={saved}");
    }
    let ms_of = |pct: usize, phase: &str| runs.iter()
        .find(|r| r.sharing_pct == pct && r.mode == "scheduler"
              && r.phase == phase)
        .map(|r| r.ms_per_request)
        .unwrap_or(0.0);
    let (cold90, warm90) = (ms_of(90, "cold"), ms_of(90, "warm"));
    let reduction = 1.0 - warm90 / cold90.max(1e-9);
    println!("  prefill at 90% shared: cold {cold90:.2} -> warm \
              {warm90:.2} ms/request ({:.1}% less time)",
             reduction * 100.0);

    let json = Value::obj(vec![
        ("model", Value::obj(vec![
            ("name", Value::Str(MIX_CFG.name.to_string())),
            ("d", Value::Num(MIX_CFG.d as f64)),
            ("n_layers", Value::Num(MIX_CFG.n_layers as f64)),
        ])),
        ("prompt_len", Value::Num(SP_PROMPT as f64)),
        ("max_new", Value::Num(SP_NEW as f64)),
        ("n_requests", Value::Num(SP_REQS as f64)),
        ("block_tokens", Value::Num(BLOCK_TOKENS as f64)),
        ("scenarios", Value::Arr(runs.iter().map(|r| Value::obj(vec![
            ("sharing_pct", Value::Num(r.sharing_pct as f64)),
            ("mode", Value::Str(r.mode.to_string())),
            ("phase", Value::Str(r.phase.to_string())),
            ("seconds", Value::Num(r.seconds)),
            ("ms_per_request", Value::Num(r.ms_per_request)),
            ("tok_s", Value::Num(r.tok_s)),
        ])).collect())),
        ("prefix", Value::Arr(prefix_stats.iter().map(|&(pct, h, s)|
            Value::obj(vec![
                ("sharing_pct", Value::Num(pct as f64)),
                ("hits", Value::Num(h as f64)),
                ("saved_tokens", Value::Num(s as f64)),
            ])).collect())),
        ("prefill_ms_reduction_at_90_shared", Value::Num(reduction)),
        ("live_scaling", live_scaling),
        ("trace_overhead", trace_overhead),
    ]);
    let out = std::env::var("BENCH_SERVING_JSON")
        .unwrap_or_else(|_| "BENCH_SERVING.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write json");
    println!("wrote {out}");
    std::fs::remove_dir_all(&dir).ok();
}

fn score_sweep() {
    let artifacts = std::env::var("LATENTLLM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("score sweep: no artifacts — skipping");
        return;
    }
    let model = "opt-mini-m";
    let cfg = mini_by_name(model).unwrap();
    let weights = Weights::load(format!("{artifacts}/model_{model}.ltw"))
        .unwrap();
    let corpus = Corpus::load(format!("{artifacts}/corpora.ltw"),
                              "synthwiki", "test").unwrap();
    let n_requests = 64usize;

    println!("== serving e2e (batcher × worker sweep) ==");
    let weights = std::sync::Arc::new(weights);
    for (workers, max_batch, wait_ms) in
        [(1usize, 1usize, 0u64), (1, 4, 2), (1, 8, 5), (1, 8, 20),
         (2, 8, 5), (4, 8, 5)] {
        let variants = vec![ModelVariant {
            name: "dense".into(),
            score_program: format!("score_{model}"),
            step_program: format!("step_{model}"),
            weights: weights.clone(),
            cache: KvCacheManager::new(CacheKind::Dense { d: cfg.d },
                                       cfg.n_layers, 2, 64 << 20),
        }];
        let server = Server::start(
            artifacts.clone().into(),
            Router::new(variants, Policy::RoundRobin),
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                policy: Policy::RoundRobin,
                program_batch: 8,
                seq_len: 128,
                workers,
                sched: None,
                trace: true,
            })
            .expect("server start");
        let reqs = corpus.calibration(n_requests, 128, 42);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = reqs.into_iter()
            .map(|tokens| server.submit_score(ScoreParams { tokens })
                .expect("submit"))
            .collect();
        for rx in rxs {
            let _ = rx.recv();
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.shutdown(Drain::Graceful);
        let (p50, p95, p99) = m.quantiles("request_us")
            .unwrap_or((0.0, 0.0, 0.0));
        println!("workers={workers} max_batch={max_batch:<2} \
                  wait={wait_ms:>2}ms: \
                  {:>6.1} req/s  p50={:>7.0}µs p95={:>7.0}µs p99={:>7.0}µs \
                  batches={}",
                 n_requests as f64 / dt, p50, p95, p99,
                 m.counter("batches"));
    }
}
