//! One bench per paper table/figure (DESIGN.md §4): regenerates each
//! artifact-free experiment and times it; artifact-dependent tables run in
//! reduced form when artifacts exist. `cargo bench` therefore exercises
//! every reproduction path end to end.

use latentllm::compress::pipeline::Method;
use latentllm::reports::{figs, tables};
use latentllm::runtime::Engine;
use latentllm::util::bench::Bench;

fn main() {
    let mut b = Bench::new(0.3);
    b.max_iters = 3;
    println!("== paper tables & figures ==");
    b.run("table3 (analytic, exact)", tables::table3);
    b.run("fig7  (precond sweep)", || figs::fig7(32, 1));
    b.run("fig8  (joint vs split qkv)", || figs::fig8(32, 2));
    b.run("fig9  (split-head)", || figs::fig9(32, 4, 3));
    b.run("fig10 (attention-aware)", || figs::fig10(32, 4, 4));
    b.run("fig11+16 (sparse vs lowrank)", || figs::fig11_16(28, 5));
    b.run("fig12 (rope window)", || figs::fig12(48, 8, 6));
    b.run("fig13 (shrink variants)", || figs::fig13(28, 7));
    b.run("fig14 (lowrank+sparse)", || figs::fig14(24, 8));
    b.run("fig15 (sparse factors)", || figs::fig15(24, 9));

    let artifacts = std::env::var("LATENTLLM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&artifacts).join("manifest.json").exists() {
        let engine = Engine::new(&artifacts).unwrap();
        let ctx = tables::TableCtx {
            engine: &engine,
            artifacts: artifacts.clone().into(),
            max_batches: 4,
            qk_iters: 3,
            ud_iters: 2,
        };
        let mut b2 = Bench::new(0.1);
        b2.max_iters = 1;
        b2.run("table2 (1 size, 1 ratio, 2 methods)", || {
            tables::table2(&ctx, &["opt-mini-s"], &[0.3],
                           &[Method::AsvdRootCov.plan(),
                             Method::LatentLlm.plan()])
                .unwrap()
        });
        b2.run("table4 (1 ratio, 1 method)", || {
            tables::table4(&ctx, &[0.3], &[Method::LatentLlm.plan()])
                .unwrap()
        });
    } else {
        println!("(artifacts missing: table2/table4 skipped — run `make \
                  artifacts`)");
    }
}
