//! Local activation-aware SVD compression of one linear layer
//! (paper §3.2 + App A/B):  B A P = svd_r[W P]  with bias update
//! b̂ = b + (W − BA)μ against the centered covariance (App B.2).

use super::junction::{self, Factors, Junction};
use super::precond::Precond;
use crate::tensor::linalg::act_loss;
use crate::tensor::svd_truncated;
use crate::Matrix;

#[derive(Clone, Debug)]
pub struct AsvdResult {
    pub factors: Factors,
    pub w_hat: Matrix,
    pub bias: Option<Vec<f64>>,
    pub rank: usize,
    /// tr[(W−Ŵ) C (W−Ŵ)ᵀ]
    pub loss: f64,
    /// loss / tr[W C Wᵀ]
    pub rel_loss: f64,
    pub params: usize,
}

pub struct AsvdOpts<'a> {
    pub kind: Precond,
    pub junction: Junction,
    /// raw activations [d×l] (for the ℓ1 pre-conditioner / centering)
    pub x: Option<&'a Matrix>,
    pub bias: Option<&'a [f64]>,
    pub lam_rel: f64,
}

impl Default for AsvdOpts<'_> {
    fn default() -> Self {
        AsvdOpts {
            kind: Precond::RootCov,
            junction: Junction::BlockId,
            x: None,
            bias: None,
            lam_rel: 1e-6,
        }
    }
}

/// Covariance + mean from opts (centered iff a bias is being updated —
/// App B.2 Remark 2).
fn stats(d_in: usize, opts: &AsvdOpts) -> (Matrix, Vec<f64>) {
    match opts.x {
        Some(x) => {
            if opts.bias.is_some() {
                let mu = x.col_mean();
                (x.center_cols(&mu).covariance(opts.lam_rel), mu)
            } else {
                (x.covariance(opts.lam_rel), vec![0.0; d_in])
            }
        }
        None => (Matrix::eye(d_in), vec![0.0; d_in]),
    }
}

pub fn compress(w: &Matrix, rank: usize, opts: &AsvdOpts) -> AsvdResult {
    let (c, mu) = stats(w.cols(), opts);
    compress_with_cov(w, rank, &c, &mu, opts)
}

pub fn compress_with_cov(w: &Matrix, rank: usize, c: &Matrix, mu: &[f64],
                         opts: &AsvdOpts) -> AsvdResult {
    let (p, p_inv) = opts.kind.build(c, opts.x);
    compress_prewhitened(w, rank, &p, &p_inv, c, mu, opts)
}

/// As [`compress_with_cov`] but with a prebuilt pre-conditioner pair —
/// §Perf: callers that already hold an eigendecomposition of C (the UD
/// refit loop) avoid recomputing it.
pub fn compress_prewhitened(w: &Matrix, rank: usize, p: &Matrix,
                            p_inv: &Matrix, c: &Matrix, mu: &[f64],
                            opts: &AsvdOpts) -> AsvdResult {
    let rank = rank.min(w.rows()).min(w.cols()).max(1);
    let f = svd_truncated(&w.matmul(p), rank);
    let factors = junction::apply(&f, p_inv, opts.junction);
    let w_hat = factors.w_hat();

    let bias = opts.bias.map(|b| {
        let delta = w.sub(&w_hat).matvec(mu);
        b.iter().zip(&delta).map(|(b, d)| b + d).collect()
    });

    let loss = act_loss(w, &w_hat, c);
    let denom = w.matmul(c).matmul_bt(w).trace().max(1e-30);
    let params = factors.params();
    AsvdResult {
        factors, w_hat, bias, rank, loss,
        rel_loss: loss / denom, params,
    }
}

/// Joint-QKV style (App C): stack weights sharing the same input; shared A,
/// stacked B. Returns the full result plus per-block row offsets.
pub fn compress_stacked(ws: &[&Matrix], rank: usize, opts: &AsvdOpts)
                        -> (AsvdResult, Vec<usize>) {
    let refs: Vec<&Matrix> = ws.to_vec();
    let stacked = Matrix::vstack(&refs);
    let mut offs = vec![0usize];
    for w in ws {
        offs.push(offs.last().unwrap() + w.rows());
    }
    (compress(&stacked, rank, opts), offs)
}

/// Split-head ablation (App D): each head compressed independently with
/// rank_total/h; block-diagonal B.
pub fn split_head_compress(w: &Matrix, n_heads: usize, rank_total: usize,
                           opts: &AsvdOpts) -> (Matrix, f64) {
    let dh = w.rows() / n_heads;
    let rh = (rank_total / n_heads).max(1);
    let mut blocks = Vec::new();
    let mut loss = 0.0;
    for i in 0..n_heads {
        let wi = w.slice_rows(i * dh, (i + 1) * dh);
        let r = compress(&wi, rh, opts);
        loss += r.loss;
        blocks.push(r.w_hat);
    }
    let refs: Vec<&Matrix> = blocks.iter().collect();
    (Matrix::vstack(&refs), loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{decaying_covariance, wishart, Rng};

    fn problem(seed: u64, d_out: usize, d_in: usize, l: usize)
               -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_matrix(d_out, d_in);
        let sigma = decaying_covariance(d_in, 0.9);
        let chol = crate::tensor::cholesky(&sigma).unwrap();
        let x = chol.matmul(&rng.normal_matrix(d_in, l));
        (w, x)
    }

    #[test]
    fn rootcov_is_optimal_among_preconditioners() {
        // Paper §3.2: P = C^{1/2} minimizes the activation loss — every
        // other Table 1 variant must be ≥ (Fig 7 / Fig 16 premise).
        let (w, x) = problem(40, 12, 16, 200);
        let c = x.covariance(1e-6);
        let mut losses = std::collections::BTreeMap::new();
        for kind in super::super::precond::ALL {
            let opts = AsvdOpts { kind, x: Some(&x), junction: Junction::Left,
                                  ..Default::default() };
            let r = compress_with_cov(&w, 6, &c, &vec![0.0; 16], &opts);
            losses.insert(kind.name(), r.loss);
        }
        let best = losses["rootcov"];
        for (name, &loss) in &losses {
            assert!(best <= loss * (1.0 + 1e-9),
                    "rootcov {best} should beat {name} {loss}");
        }
    }

    #[test]
    fn loss_decreases_with_rank() {
        let (w, x) = problem(41, 10, 14, 150);
        let opts = AsvdOpts { x: Some(&x), ..Default::default() };
        let mut prev = f64::INFINITY;
        for r in [2usize, 4, 6, 8, 10] {
            let res = compress(&w, r, &opts);
            assert!(res.loss <= prev + 1e-9, "rank {r}");
            prev = res.loss;
        }
        // full rank = exact
        let res = compress(&w, 10, &opts);
        assert!(res.rel_loss < 1e-12);
    }

    #[test]
    fn bias_update_preserves_mean_output() {
        // App B.2: with b̂ = b + (W−Ŵ)μ the mean output is unchanged.
        let (w, x) = problem(42, 8, 12, 300);
        let bias: Vec<f64> = (0..8).map(|i| i as f64 * 0.1).collect();
        let opts = AsvdOpts { x: Some(&x), bias: Some(&bias),
                              ..Default::default() };
        let res = compress(&w, 4, &opts);
        let mu = x.col_mean();
        let y_mean = w.matvec(&mu).iter().zip(&bias)
            .map(|(a, b)| a + b).collect::<Vec<_>>();
        let y_hat_mean = res.w_hat.matvec(&mu).iter()
            .zip(res.bias.as_ref().unwrap())
            .map(|(a, b)| a + b).collect::<Vec<_>>();
        for (a, b) in y_mean.iter().zip(&y_hat_mean) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn joint_qkv_beats_split_qkv_at_equal_params(// Fig 8
    ) {
        let mut rng = Rng::new(43);
        let d = 18;
        let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 3 * d);
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        let wv = rng.normal_matrix(d, d);
        // split: rank r each => params 3r(2d); joint: rank 3r-ish shared.
        let r = 4;
        let opts = AsvdOpts { junction: Junction::Left, ..Default::default() };
        let mut split_loss = 0.0;
        for w in [&wq, &wk, &wv] {
            split_loss +=
                compress_with_cov(w, r, &c, &vec![0.0; d], &opts).loss;
        }
        // joint rank giving the same params: 3r(2d) = r_j(3d + d)
        let r_j = 3 * r * 2 * d / (4 * d);
        let (joint, _) = {
            let stacked = Matrix::vstack(&[&wq, &wk, &wv]);
            (compress_with_cov(&stacked, r_j, &c, &vec![0.0; d], &opts), 0)
        };
        assert!(joint.loss <= split_loss * 1.05,
                "joint {} vs split {}", joint.loss, split_loss);
    }

    #[test]
    fn split_head_is_worse(// Fig 9: block-diagonal B wastes capacity
    ) {
        let (w, x) = problem(44, 16, 16, 200);
        let opts = AsvdOpts { x: Some(&x), junction: Junction::Left,
                              ..Default::default() };
        let joint = compress(&w, 8, &opts);
        let (_, split_loss) = split_head_compress(&w, 4, 8, &opts);
        assert!(joint.loss <= split_loss * (1.0 + 1e-9),
                "joint {} vs split-head {}", joint.loss, split_loss);
    }
}
