//! Joint QK compression → multi-head latent attention
//! (paper §4.1, Algorithm 1, App E).
//!
//! Tucker/HOSVD over the 3-mode tensor with slices G̃ᵢ = (Wq,i P)ᵀ(Wk,i P),
//! alternating `RightSingular` (= top-k eigenvector) updates:
//!
//! ```text
//! Ak ← eigvecs_rk[Σᵢ G̃ᵢᵀ Aqᵀ Aq G̃ᵢ]
//! Aq ← eigvecs_rq[Σᵢ G̃ᵢ Akᵀ Ak G̃ᵢᵀ]
//! ```
//!
//! with per-head cores Hᵢ = Aq G̃ᵢ Akᵀ and outputs Bq,i = Wq,i P Aqᵀ,
//! Bk,i = Wk,i P Akᵀ, Aq ← Aq P⁺, Ak ← Ak P⁺ (Jᵢ = I). GQA is supported
//! through `group_size` (App E.3); bias-aware mode adds the rank-1 terms of
//! Eq 140/142 and the first-order bias correction b̂ = b + (W−Ŵ)μ.

use super::precond::Precond;
use crate::tensor::topk_eigvecs;
use crate::Matrix;

pub struct JointQkOpts<'a> {
    pub kind: Precond,
    pub n_iter: usize,
    /// query heads per kv head (GQA group size; 1 = MHA)
    pub group_size: usize,
    pub x: Option<&'a Matrix>,
    pub bq: Option<&'a [f64]>,
    pub bk: Option<&'a [f64]>,
    pub lam_rel: f64,
}

impl Default for JointQkOpts<'_> {
    fn default() -> Self {
        JointQkOpts {
            kind: Precond::RootCov,
            n_iter: 8,
            group_size: 1,
            x: None,
            bq: None,
            bk: None,
            lam_rel: 1e-6,
        }
    }
}

#[derive(Clone, Debug)]
pub struct JointQkResult {
    pub aq: Matrix,          // rq×d (already un-whitened: Aq P⁺)
    pub ak: Matrix,          // rk×d
    pub bq: Vec<Matrix>,     // per q-head d_h×rq
    pub bk: Vec<Matrix>,     // per kv-head d_h×rk
    pub bq_bias: Option<Vec<f64>>,
    pub bk_bias: Option<Vec<f64>>,
    pub wq_hat: Matrix,
    pub wk_hat: Matrix,
    /// attention-map loss after each alternating iteration (Eq 68)
    pub losses: Vec<f64>,
    pub rq: usize,
    pub rk: usize,
    pub params: usize,
}

fn split_heads(w: &Matrix, n: usize, dh: usize) -> Vec<Matrix> {
    assert_eq!(w.rows(), n * dh, "head split {}x{} into {n}x{dh}",
               w.rows(), w.cols());
    (0..n).map(|i| w.slice_rows(i * dh, (i + 1) * dh)).collect()
}

/// L = Σᵢ ‖Gᵢ‖² − ‖Aq Gᵢ Akᵀ‖² for orthonormal Aq/Ak rows (Eq 68).
pub fn attention_map_loss(g: &[Matrix], aq: &Matrix, ak: &Matrix) -> f64 {
    g.iter()
        .map(|gi| gi.frob2() - aq.matmul(gi).matmul_bt(ak).frob2())
        .sum()
}

pub fn compress(wq: &Matrix, wk: &Matrix, n_kv_heads: usize, d_h: usize,
                rq: usize, rk: usize, opts: &JointQkOpts) -> JointQkResult {
    let d = wq.cols();
    let rq = rq.min(d).max(1);
    let rk = rk.min(d).max(1);
    let gs = opts.group_size.max(1);
    let n_q = gs * n_kv_heads;
    let bias_aware = opts.bq.is_some() && opts.bk.is_some() && opts.x.is_some();

    let (c, mu) = match opts.x {
        Some(x) if bias_aware => {
            let mu = x.col_mean();
            (x.center_cols(&mu).covariance(opts.lam_rel), mu)
        }
        Some(x) => (x.covariance(opts.lam_rel), vec![0.0; d]),
        None => (Matrix::eye(d), vec![0.0; d]),
    };
    let (p, p_inv) = opts.kind.build(&c, opts.x);

    let q_heads = split_heads(wq, n_q, d_h);
    let k_heads = split_heads(wk, n_kv_heads, d_h);
    let qp: Vec<Matrix> = q_heads.iter().map(|h| h.matmul(&p)).collect();
    let kp: Vec<Matrix> = k_heads.iter().map(|h| h.matmul(&p)).collect();

    // whitened kernels G̃_{i,j} = (Wq,ij P)ᵀ (Wk,i P), one per (q, kv) pair
    let mut g = Vec::with_capacity(n_q);
    let mut pair_kv = Vec::with_capacity(n_q);
    for i in 0..n_kv_heads {
        for j in 0..gs {
            let qi = i * gs + j;
            g.push(qp[qi].matmul_at(&kp[i]));
            pair_kv.push(i);
        }
    }

    // bias rank-1 augmentation (Eq 140/142)
    let mut uq = Matrix::zeros(d, d);
    let mut uk = Matrix::zeros(d, d);
    if bias_aware {
        let bq = opts.bq.unwrap();
        let bk = opts.bk.unwrap();
        for (qi, &ki) in pair_kv.iter().enumerate() {
            let bk_i = &bk[ki * d_h..(ki + 1) * d_h];
            let bq_i = &bq[qi * d_h..(qi + 1) * d_h];
            let vk: Vec<f64> = k_heads[ki].matvec(&mu).iter().zip(bk_i)
                .map(|(a, b)| a + b).collect();
            let vq: Vec<f64> = q_heads[qi].matvec(&mu).iter().zip(bq_i)
                .map(|(a, b)| a + b).collect();
            let a_vec = p.matvec(&q_heads[qi].transpose().matvec(&vk));
            let b_vec = p.matvec(&k_heads[ki].transpose().matvec(&vq));
            rank1_add(&mut uq, &a_vec);
            rank1_add(&mut uk, &b_vec);
        }
    }

    // init Aq from Σ G Gᵀ (Algorithm 1 init line)
    let mut acc = Matrix::zeros(d, d);
    for gi in &g {
        acc.add_inplace(&gi.matmul_bt(gi));
    }
    acc.add_inplace(&uq);
    let mut aq = topk_eigvecs(&acc, rq);

    let mut ak = {
        let mut acc = Matrix::zeros(d, d);
        for gi in &g {
            acc.add_inplace(&gi.matmul_at(gi));
        }
        acc.add_inplace(&uk);
        topk_eigvecs(&acc, rk)
    };
    let mut losses = vec![attention_map_loss(&g, &aq, &ak)];

    for _ in 0..opts.n_iter.max(1) {
        // Ak ← eigvecs[Σ Gᵀ Aqᵀ Aq G]
        let mut acc_k = Matrix::zeros(d, d);
        for gi in &g {
            let ag = aq.matmul(gi); // rq×d
            acc_k.add_inplace(&ag.matmul_at(&ag));
        }
        acc_k.add_inplace(&uk);
        ak = topk_eigvecs(&acc_k, rk);
        // Aq ← eigvecs[Σ G Akᵀ Ak Gᵀ]
        let mut acc_q = Matrix::zeros(d, d);
        for gi in &g {
            let ga = ak.matmul(&gi.transpose()); // rk×d
            acc_q.add_inplace(&ga.matmul_at(&ga));
        }
        acc_q.add_inplace(&uq);
        aq = topk_eigvecs(&acc_q, rq);
        losses.push(attention_map_loss(&g, &aq, &ak));
    }

    // outputs (Alg 1, Jᵢ = I)
    let bq_f: Vec<Matrix> = qp.iter().map(|h| h.matmul_bt(&aq)).collect();
    let bk_f: Vec<Matrix> = kp.iter().map(|h| h.matmul_bt(&ak)).collect();
    let aq_f = aq.matmul(&p_inv);
    let ak_f = ak.matmul(&p_inv);

    let wq_hat = {
        let blocks: Vec<Matrix> =
            bq_f.iter().map(|b| b.matmul(&aq_f)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Matrix::vstack(&refs)
    };
    let wk_hat = {
        let blocks: Vec<Matrix> =
            bk_f.iter().map(|b| b.matmul(&ak_f)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Matrix::vstack(&refs)
    };

    let (bq_bias, bk_bias) = if bias_aware {
        // first-order correction: b̂ = b + (W − Ŵ)μ (Eq 121/122, Jᵢ=I)
        let fix = |b: &[f64], w: &Matrix, wh: &Matrix| {
            let delta = w.sub(wh).matvec(&mu);
            b.iter().zip(&delta).map(|(a, d)| a + d).collect::<Vec<f64>>()
        };
        (Some(fix(opts.bq.unwrap(), wq, &wq_hat)),
         Some(fix(opts.bk.unwrap(), wk, &wk_hat)))
    } else {
        (None, None)
    };

    let params =
        super::rank::joint_qk_params(d, d_h, n_q, n_kv_heads, rq, rk, true);
    JointQkResult {
        aq: aq_f, ak: ak_f, bq: bq_f, bk: bk_f, bq_bias, bk_bias,
        wq_hat, wk_hat, losses, rq, rk, params,
    }
}

fn rank1_add(m: &mut Matrix, v: &[f64]) {
    let d = v.len();
    for i in 0..d {
        if v[i] == 0.0 {
            continue;
        }
        for j in 0..d {
            m[(i, j)] += v[i] * v[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::asvd::{self, AsvdOpts};
    use crate::compress::junction::Junction;
    use crate::util::rng::{decaying_covariance, wishart, Rng};

    fn heads(seed: u64, d: usize, h: usize) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
        let _ = h;
        (wq, wk, c)
    }

    #[test]
    fn losses_monotone_nonincreasing() {
        let (wq, wk, _) = heads(50, 24, 4);
        let opts = JointQkOpts { kind: Precond::Identity, n_iter: 6,
                                 ..Default::default() };
        let res = compress(&wq, &wk, 4, 6, 10, 10, &opts);
        for w in res.losses.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "{:?}", res.losses);
        }
        assert!(res.losses[0] >= 0.0);
    }

    #[test]
    fn exact_at_full_rank() {
        let (wq, wk, _) = heads(51, 16, 4);
        let opts = JointQkOpts { kind: Precond::Identity,
                                 ..Default::default() };
        let res = compress(&wq, &wk, 4, 4, 16, 16, &opts);
        assert!(res.wq_hat.max_abs_diff(&wq) < 1e-7);
        assert!(res.wk_hat.max_abs_diff(&wk) < 1e-7);
        assert!(res.losses.last().unwrap().abs() < 1e-7);
    }

    #[test]
    fn attention_aware_beats_activation_aware(// Fig 10
    ) {
        // Attention-map loss of the joint HOSVD vs per-matrix ASVD at the
        // same ranks, both whitened by the same covariance.
        let (wq, wk, c) = heads(52, 20, 4);
        let d = 20;
        let dh = 5;
        let (rq, rk) = (8, 8);
        let opts = JointQkOpts { kind: Precond::RootCov, n_iter: 8,
                                 ..Default::default() };
        // inject covariance by pretending x: use c via compress_with_cov
        // path: build P outside and pass identity + pre-whitened weights.
        let p = crate::tensor::sqrtm_psd(&c);
        let wq_w = wq.matmul(&p);
        let wk_w = wk.matmul(&p);
        let joint = compress(&wq_w, &wk_w, 4, dh, rq, rk,
                             &JointQkOpts { kind: Precond::Identity,
                                            ..opts });
        // activation-aware baseline: ASVD each of Wq, Wk at same ranks
        let aopts = AsvdOpts { kind: Precond::Identity,
                               junction: Junction::Left,
                               ..Default::default() };
        let rq_res = asvd::compress(&wq_w, rq, &aopts);
        let rk_res = asvd::compress(&wk_w, rk, &aopts);
        // attention-map loss of the baseline
        let mut base_loss = 0.0;
        for i in 0..4 {
            let gi = wq_w.slice_rows(i * dh, (i + 1) * dh).matmul_at(
                &wk_w.slice_rows(i * dh, (i + 1) * dh));
            let gh = rq_res.w_hat.slice_rows(i * dh, (i + 1) * dh).matmul_at(
                &rk_res.w_hat.slice_rows(i * dh, (i + 1) * dh));
            base_loss += gi.sub(&gh).frob2();
        }
        let joint_loss = *joint.losses.last().unwrap();
        assert!(joint_loss <= base_loss * 1.01,
                "attention-aware {joint_loss} vs activation-aware {base_loss}");
    }

    #[test]
    fn gqa_group_size() {
        let mut rng = Rng::new(53);
        let (d, dh, n_kv, gs) = (16usize, 4usize, 2usize, 2usize);
        let wq = rng.normal_matrix(gs * n_kv * dh, d);
        let wk = rng.normal_matrix(n_kv * dh, d);
        let opts = JointQkOpts { kind: Precond::Identity, group_size: gs,
                                 ..Default::default() };
        let res = compress(&wq, &wk, n_kv, dh, 8, 8, &opts);
        assert_eq!(res.bq.len(), gs * n_kv);
        assert_eq!(res.bk.len(), n_kv);
        assert_eq!(res.wq_hat.rows(), wq.rows());
        assert_eq!(res.wk_hat.rows(), wk.rows());
    }

    #[test]
    fn bias_aware_keeps_mean_logits() {
        let mut rng = Rng::new(54);
        let (d, dh, h) = (12usize, 3usize, 4usize);
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        let x = rng.normal_matrix(d, 100);
        let bq: Vec<f64> = (0..d).map(|i| 0.05 * i as f64).collect();
        let bk: Vec<f64> = (0..d).map(|i| -0.03 * i as f64).collect();
        let opts = JointQkOpts { x: Some(&x), bq: Some(&bq), bk: Some(&bk),
                                 ..Default::default() };
        let res = compress(&wq, &wk, h, dh, 8, 8, &opts);
        let mu = x.col_mean();
        // mean q per head preserved
        let q_mean: Vec<f64> = wq.matvec(&mu).iter().zip(&bq)
            .map(|(a, b)| a + b).collect();
        let q_hat_mean: Vec<f64> = res.wq_hat.matvec(&mu).iter()
            .zip(res.bq_bias.as_ref().unwrap())
            .map(|(a, b)| a + b).collect();
        for (a, b) in q_mean.iter().zip(&q_hat_mean) {
            assert!((a - b).abs() < 1e-8);
        }
    }
}
