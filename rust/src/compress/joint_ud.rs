//! Joint Up/Down (MLP) compression via SparseLLM-style decoupling
//! (paper §4.3, App H). Alternates the closed-form auxiliary updates
//! (Z′ ridge solve Eq 21, Z ReLU branch choice Eq 22) with effective-weight
//! refits compressed by root-covariance ASVD.

use super::asvd::{self, AsvdOpts, AsvdResult};
use super::junction::Junction;
use super::precond::Precond;
use crate::tensor::solve;
use crate::Matrix;

pub struct JointUdOpts {
    pub n_iter: usize,
    pub junction: Junction,
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub lam_rel: f64,
}

impl Default for JointUdOpts {
    fn default() -> Self {
        JointUdOpts { n_iter: 4, junction: Junction::BlockId,
                      alpha: 1.0, beta: 1.0, gamma: 1.0, lam_rel: 1e-6 }
    }
}

#[derive(Clone, Debug)]
pub struct JointUdResult {
    pub wu_hat: Matrix,
    pub bu: Vec<f64>,
    pub wd_hat: Matrix,
    pub bd: Vec<f64>,
    pub res_u: AsvdResult,
    pub res_d: AsvdResult,
    /// end-to-end MLP output loss after init and each iteration
    pub losses: Vec<f64>,
    pub params: usize,
}

fn relu(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for v in out.data_mut() {
        *v = v.max(0.0);
    }
    out
}

fn add_bias(m: &Matrix, b: &[f64]) -> Matrix {
    let mut out = m.clone();
    for i in 0..m.rows() {
        for v in out.row_mut(i) {
            *v += b[i];
        }
    }
    out
}

fn mlp_loss(wu: &Matrix, bu: &[f64], wd: &Matrix, bd: &[f64],
            x: &Matrix, y: &Matrix) -> f64 {
    let z = relu(&add_bias(&wu.matmul(x), bu));
    add_bias(&wd.matmul(&z), bd).sub(y).frob2()
}

/// Ridge-fit target ≈ W_eff·x + b, then root-cov ASVD at the given rank.
/// §Perf: one eigendecomposition of C serves the ridge pseudo-inverse AND
/// the root-covariance pre-conditioner pair.
fn fit_effective(target: &Matrix, x: &Matrix, rank: usize,
                 junction: Junction, lam_rel: f64)
                 -> (Matrix, Vec<f64>, AsvdResult) {
    use crate::tensor::eig::eigh;
    let mu_x = x.col_mean();
    let mu_t = target.col_mean();
    let xc = x.center_cols(&mu_x);
    let tc = target.center_cols(&mu_t);
    let c = xc.covariance(lam_rel.max(1e-8));
    let l = x.cols().max(1) as f64;

    // single eigh → C⁺, C^{1/2}, C^{-1/2}
    let (w_eig, v_eig) = eigh(&c);
    let wmax = w_eig.last().copied().unwrap_or(0.0).max(0.0);
    let scaled = |f: &dyn Fn(f64) -> f64| -> Matrix {
        let n = v_eig.rows();
        let mut vs = v_eig.clone();
        for j in 0..n {
            let s = f(w_eig[j]);
            for i in 0..n {
                vs[(i, j)] *= s;
            }
        }
        vs.matmul_bt(&v_eig)
    };
    let thresh = 1e-12 * wmax.max(1.0);
    let c_pinv = scaled(&|x| if x > thresh { 1.0 / x } else { 0.0 });
    let p = scaled(&|x| x.max(0.0).sqrt());
    let p_inv = scaled(&|x| if x > 1e-10 * wmax.max(1.0) {
        1.0 / x.max(0.0).sqrt()
    } else {
        0.0
    });

    let w_eff = tc.matmul_bt(&xc).scale(1.0 / l).matmul(&c_pinv);
    let b_eff: Vec<f64> = mu_t.iter()
        .zip(w_eff.matvec(&mu_x))
        .map(|(t, wx)| t - wx)
        .collect();
    let opts = AsvdOpts { kind: Precond::RootCov, junction,
                          bias: Some(&b_eff), lam_rel, x: None,
                          };
    let res = asvd::compress_prewhitened(&w_eff, rank, &p, &p_inv, &c,
                                         &vec![0.0; x.rows()], &opts);
    let bias = res.bias.clone().unwrap_or(b_eff);
    (res.w_hat.clone(), bias, res)
}

pub fn compress(wu: &Matrix, bu: &[f64], wd: &Matrix, bd: &[f64],
                x: &Matrix, ru: usize, rd: usize, opts: &JointUdOpts)
                -> JointUdResult {
    let z_teacher = add_bias(&wu.matmul(x), bu);
    let zp_teacher = relu(&z_teacher);
    let y = add_bias(&wd.matmul(&zp_teacher), bd);

    // init: local root-cov ASVD of both layers (the non-joint baseline)
    let up_opts = AsvdOpts { kind: Precond::RootCov, junction: opts.junction,
                             x: Some(x), bias: Some(bu),
                             lam_rel: opts.lam_rel };
    let res_u0 = asvd::compress(wu, ru, &up_opts);
    let dn_opts = AsvdOpts { kind: Precond::RootCov, junction: opts.junction,
                             x: Some(&zp_teacher), bias: Some(bd),
                             lam_rel: opts.lam_rel };
    let res_d0 = asvd::compress(wd, rd, &dn_opts);
    let mut wu_hat = res_u0.w_hat.clone();
    let mut bu_hat = res_u0.bias.clone().unwrap();
    let mut wd_hat = res_d0.w_hat.clone();
    let mut bd_hat = res_d0.bias.clone().unwrap();

    let mut losses = vec![mlp_loss(&wu_hat, &bu_hat, &wd_hat, &bd_hat,
                                   x, &y)];
    let mut z = add_bias(&wu_hat.matmul(x), &bu_hat);
    let mut best = (losses[0], wu_hat.clone(), bu_hat.clone(),
                    wd_hat.clone(), bd_hat.clone(),
                    res_u0.clone(), res_d0.clone());

    let (al, be, ga) = (opts.alpha, opts.beta, opts.gamma);
    for _ in 0..opts.n_iter {
        // Z′ ridge solve (Eq 21): (γ ŴdᵀŴd + βI) Z′ = βσ(Z) + γŴdᵀ(Y−b̂d)
        let di = wd_hat.cols();
        let mut m = wd_hat.matmul_at(&wd_hat).scale(ga);
        for i in 0..di {
            m[(i, i)] += be;
        }
        let neg_bd: Vec<f64> = bd_hat.iter().map(|v| -v).collect();
        let rhs = relu(&z).scale(be)
            .add(&wd_hat.transpose()
                .matmul(&add_bias(&y, &neg_bd))
                .scale(ga));
        let zp = solve(&m, &rhs);

        // Z branch choice (Eq 22)
        let z_lin = add_bias(&wu_hat.matmul(x), &bu_hat);
        let mut z_new = z_lin.clone();
        for idx in 0..z_new.data().len() {
            let zl = z_lin.data()[idx];
            let zpv = zp.data()[idx];
            let z_pos = ((al * zl + be * zpv) / (al + be)).max(0.0);
            let z_neg = zl.min(0.0);
            let loss_pos = al * (z_pos - zl).powi(2)
                + be * (zpv - z_pos).powi(2);
            let loss_neg = al * (z_neg - zl).powi(2) + be * zpv * zpv;
            z_new.data_mut()[idx] = if loss_pos <= loss_neg { z_pos }
                                    else { z_neg };
        }
        z = z_new;

        // refit effective weights (App H)
        let (wu2, bu2, ru_res) =
            fit_effective(&z, x, ru, opts.junction, opts.lam_rel);
        let (wd2, bd2, rd_res) =
            fit_effective(&y, &zp, rd, opts.junction, opts.lam_rel);
        wu_hat = wu2;
        bu_hat = bu2;
        wd_hat = wd2;
        bd_hat = bd2;
        let cur = mlp_loss(&wu_hat, &bu_hat, &wd_hat, &bd_hat, x, &y);
        losses.push(cur);
        if cur < best.0 {
            best = (cur, wu_hat.clone(), bu_hat.clone(), wd_hat.clone(),
                    bd_hat.clone(), ru_res, rd_res);
        }
    }

    let params = best.5.params + best.6.params;
    JointUdResult {
        wu_hat: best.1, bu: best.2, wd_hat: best.3, bd: best.4,
        res_u: best.5, res_d: best.6, losses, params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn joint_not_worse_than_local_init() {
        let mut rng = Rng::new(70);
        let (d, di, l) = (12usize, 32usize, 160usize);
        let wu = rng.normal_matrix(di, d);
        let wd = rng.normal_matrix(d, di).scale(0.3);
        let bu: Vec<f64> = (0..di).map(|i| 0.01 * i as f64 - 0.1).collect();
        let bd = vec![0.0; d];
        let x = rng.normal_matrix(d, l);
        let res = compress(&wu, &bu, &wd, &bd, &x, 6, 6,
                           &JointUdOpts::default());
        // the returned best is never worse than the local-ASVD init
        // (on iid random weights the decoupled iterations may not improve —
        // the best-tracking guarantees we keep the init in that case; the
        // structured-model improvement is covered by the goldens
        // integration test and the python pipeline validation)
        let final_loss = *res.losses.iter()
            .fold(&f64::INFINITY, |m, v| if v < m { v } else { m });
        assert!(final_loss <= res.losses[0] * (1.0 + 1e-9),
                "{:?}", res.losses);
        // and the reported factors reproduce that best loss
        let y = add_bias(&wd.matmul(&relu(&add_bias(&wu.matmul(&x), &bu))),
                         &bd);
        let got = mlp_loss(&res.wu_hat, &res.bu, &res.wd_hat, &res.bd,
                           &x, &y);
        assert!((got - final_loss).abs() < 1e-6 * (1.0 + final_loss));
    }

    #[test]
    fn exact_at_full_rank() {
        let mut rng = Rng::new(71);
        let (d, di, l) = (6usize, 12usize, 100usize);
        let wu = rng.normal_matrix(di, d);
        let wd = rng.normal_matrix(d, di);
        let bu = vec![0.1; di];
        let bd = vec![-0.2; d];
        let x = rng.normal_matrix(d, l);
        let res = compress(&wu, &bu, &wd, &bd, &x, d.min(di), d.min(di),
                           &JointUdOpts { n_iter: 2, ..Default::default() });
        let y = add_bias(&wd.matmul(&relu(&add_bias(&wu.matmul(&x), &bu))),
                         &bd);
        let yh = add_bias(
            &res.wd_hat.matmul(&relu(&add_bias(&res.wu_hat.matmul(&x),
                                               &res.bu))),
            &res.bd);
        let rel = yh.sub(&y).frob2() / y.frob2();
        assert!(rel < 1e-6, "rel {rel}");
    }
}
