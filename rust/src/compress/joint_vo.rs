//! Joint value/output compression (paper §4.2, App G): alternating HOSVD
//! over Gᵢ = Wo,i (Wv,i P), plus the single-SVD combined variant (Eq 183)
//! and the contraction-order FLOP analysis (Eqs 17/18).

use super::precond::Precond;
use crate::tensor::topk_eigvecs;
use crate::Matrix;

pub struct JointVoOpts<'a> {
    pub kind: Precond,
    pub n_iter: usize,
    pub x: Option<&'a Matrix>,
    pub bv: Option<&'a [f64]>,
    pub bo: Option<&'a [f64]>,
    pub lam_rel: f64,
}

impl Default for JointVoOpts<'_> {
    fn default() -> Self {
        JointVoOpts { kind: Precond::RootCov, n_iter: 4, x: None,
                      bv: None, bo: None, lam_rel: 1e-6 }
    }
}

#[derive(Clone, Debug)]
pub struct JointVoResult {
    pub av: Matrix,        // rv×d
    pub bv: Vec<Matrix>,   // per head d_h×rv
    pub ao: Vec<Matrix>,   // per head ro×d_h
    pub bo: Matrix,        // d'×ro
    pub bo_bias: Option<Vec<f64>>,
    pub wv_hat: Matrix,
    pub wo_hat: Matrix,
    pub losses: Vec<f64>,
    pub rv: usize,
    pub ro: usize,
    pub params: usize,
}

/// wv: [h·d_h × d], wo: [d' × h·d_h].
pub fn compress(wv: &Matrix, wo: &Matrix, n_heads: usize, d_h: usize,
                rv: usize, ro: usize, opts: &JointVoOpts) -> JointVoResult {
    let d = wv.cols();
    let d_out = wo.rows();
    let rv = rv.min(d).max(1);
    let ro = ro.min(d_out).max(1);
    let bias_aware = opts.bv.is_some() && opts.bo.is_some() && opts.x.is_some();

    let (c, mu) = match opts.x {
        Some(x) if bias_aware => {
            let mu = x.col_mean();
            (x.center_cols(&mu).covariance(opts.lam_rel), mu)
        }
        Some(x) => (x.covariance(opts.lam_rel), vec![0.0; d]),
        None => (Matrix::eye(d), vec![0.0; d]),
    };
    let (p, p_inv) = opts.kind.build(&c, opts.x);

    let v_heads: Vec<Matrix> =
        (0..n_heads).map(|i| wv.slice_rows(i * d_h, (i + 1) * d_h)).collect();
    let o_heads: Vec<Matrix> =
        (0..n_heads).map(|i| wo.slice_cols(i * d_h, (i + 1) * d_h)).collect();
    let vp: Vec<Matrix> = v_heads.iter().map(|h| h.matmul(&p)).collect();
    // Gᵢ = Wo,i (Wv,i P)  (d'×d)
    let g: Vec<Matrix> =
        (0..n_heads).map(|i| o_heads[i].matmul(&vp[i])).collect();

    // init Av from Σ Gᵀ G
    let mut acc = Matrix::zeros(d, d);
    for gi in &g {
        acc.add_inplace(&gi.matmul_at(gi));
    }
    let mut av = topk_eigvecs(&acc, rv);
    let mut bo_m = Matrix::zeros(d_out, ro);
    let mut losses = Vec::new();

    for _ in 0..opts.n_iter.max(1) {
        // Bo = eigvecs_ro[Σ G Avᵀ Av Gᵀ] (columns)
        let mut acc_o = Matrix::zeros(d_out, d_out);
        for gi in &g {
            let ga = av.matmul(&gi.transpose()); // rv×d'
            acc_o.add_inplace(&ga.matmul_at(&ga));
        }
        bo_m = topk_eigvecs(&acc_o, ro).transpose(); // d'×ro
        // Av = eigvecs_rv[Σ Gᵀ Bo Boᵀ G] (rows)
        let mut acc_v = Matrix::zeros(d, d);
        for gi in &g {
            let bg = bo_m.matmul_at(gi); // ro×d
            acc_v.add_inplace(&bg.matmul_at(&bg));
        }
        av = topk_eigvecs(&acc_v, rv);
        let loss: f64 = g.iter()
            .map(|gi| gi.frob2()
                - bo_m.matmul_at(gi).matmul_bt(&av).frob2())
            .sum();
        losses.push(loss);
    }

    let ao: Vec<Matrix> =
        o_heads.iter().map(|oh| bo_m.matmul_at(oh)).collect(); // ro×d_h
    let bv_f: Vec<Matrix> = vp.iter().map(|vh| vh.matmul_bt(&av)).collect();
    let av_f = av.matmul(&p_inv);

    let wv_hat = {
        let blocks: Vec<Matrix> =
            bv_f.iter().map(|b| b.matmul(&av_f)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Matrix::vstack(&refs)
    };
    let wo_hat = {
        let blocks: Vec<Matrix> = ao.iter().map(|a| bo_m.matmul(a)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        Matrix::hstack(&refs)
    };

    let bo_bias = if bias_aware {
        // App G.1 Eq 193: b̂o = bo + Σᵢ[Wo,i(Wv,iμ+bv,i) − Ŵo,i(Ŵv,iμ+bv,i)]
        let bv_b = opts.bv.unwrap();
        let mut out = opts.bo.unwrap().to_vec();
        for i in 0..n_heads {
            let bv_i = &bv_b[i * d_h..(i + 1) * d_h];
            let t: Vec<f64> = v_heads[i].matvec(&mu).iter().zip(bv_i)
                .map(|(a, b)| a + b).collect();
            let y = o_heads[i].matvec(&t);
            let th: Vec<f64> = wv_hat.slice_rows(i * d_h, (i + 1) * d_h)
                .matvec(&mu).iter().zip(bv_i).map(|(a, b)| a + b).collect();
            let yh = wo_hat.slice_cols(i * d_h, (i + 1) * d_h).matvec(&th);
            for j in 0..d_out {
                out[j] += y[j] - yh[j];
            }
        }
        Some(out)
    } else {
        None
    };

    let params = super::rank::joint_vo_params(d, d_out, n_heads, d_h,
                                              rv, ro);
    JointVoResult {
        av: av_f, bv: bv_f, ao, bo: bo_m, bo_bias,
        wv_hat, wo_hat, losses, rv, ro, params,
    }
}

/// Combined single-SVD variant (Eq 183): factor Wo Wv P at rank r.
pub fn combined(wv: &Matrix, wo: &Matrix, rank: usize, kind: Precond,
                c: &Matrix) -> (Matrix, f64) {
    let (p, p_inv) = kind.build(c, None);
    let m = wo.matmul(wv).matmul(&p);
    let f = crate::tensor::svd_truncated(&m, rank);
    let w_hat = f.reconstruct().matmul(&p_inv);
    let loss = m.frob2() - f.s.iter().map(|s| s * s).sum::<f64>();
    (w_hat, loss)
}

/// MLA contraction-order MAC counts (Eqs 17/18). Returns (order_a, order_b):
/// order_a decompresses values per head before attention weighting, order_b
/// weights on the shared latent and defers Bo. Rule: if h·ro < rv, weight on
/// the output-compression side.
pub fn contraction_flops(d: usize, d_h: usize, h: usize, l: usize,
                         rv: usize, ro: usize) -> (usize, usize) {
    let order_a = l * d * rv + h * d_h * l * rv + h * d_h * l * l
        + h * d_h * l * ro + h * d * l * ro;
    let order_b = l * d * rv + rv * l * l + h * d_h * l * rv
        + h * d_h * l * ro + d * l * ro;
    (order_a, order_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn losses_monotone_and_exact_at_full_rank() {
        let mut rng = Rng::new(60);
        let (d, dh, h) = (16usize, 4usize, 4usize);
        let wv = rng.normal_matrix(d, d);
        let wo = rng.normal_matrix(d, d);
        let opts = JointVoOpts { kind: Precond::Identity, n_iter: 5,
                                 ..Default::default() };
        let res = compress(&wv, &wo, h, dh, 8, 8, &opts);
        for w in res.losses.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9));
        }
        let full = compress(&wv, &wo, h, dh, d, d, &opts);
        // at full rank the per-head PRODUCTS are preserved
        for i in 0..h {
            let gi = wo.slice_cols(i * dh, (i + 1) * dh)
                .matmul(&wv.slice_rows(i * dh, (i + 1) * dh));
            let gh = full.wo_hat.slice_cols(i * dh, (i + 1) * dh)
                .matmul(&full.wv_hat.slice_rows(i * dh, (i + 1) * dh));
            assert!(gi.max_abs_diff(&gh) < 1e-7);
        }
    }

    #[test]
    fn contraction_order_rule(// Eq 17/18 + the "if h·ro < rv" remark
    ) {
        let (d, dh, h, l) = (128, 32, 4, 128);
        // h·ro < rv → order_b strictly cheaper
        let (a, b) = contraction_flops(d, dh, h, l, 96, 16);
        assert!(b < a);
        // reduction formula: (d−rv)l² + (h−1)·d·l·ro
        let (rv, ro) = (96usize, 16usize);
        assert_eq!(a - b, (d - rv) * l * l + (h - 1) * d * l * ro);
    }

    #[test]
    fn combined_loss_matches_tail() {
        let mut rng = Rng::new(61);
        let wv = rng.normal_matrix(12, 12);
        let wo = rng.normal_matrix(12, 12);
        let c = Matrix::eye(12);
        let m = wo.matmul(&wv);
        let f = crate::tensor::svd(&m);
        let (_, loss) = combined(&wv, &wo, 5, Precond::Identity, &c);
        let tail: f64 = f.s[5..].iter().map(|s| s * s).sum();
        assert!((loss - tail).abs() < 1e-7);
    }

    #[test]
    fn bias_update_preserves_mean_output() {
        let mut rng = Rng::new(62);
        let (d, dh, h) = (12usize, 3usize, 4usize);
        let wv = rng.normal_matrix(d, d);
        let wo = rng.normal_matrix(d, d);
        let x = rng.normal_matrix(d, 80);
        let bv: Vec<f64> = (0..d).map(|i| 0.02 * i as f64).collect();
        let bo: Vec<f64> = (0..d).map(|i| 0.01 * i as f64 - 0.05).collect();
        let opts = JointVoOpts { x: Some(&x), bv: Some(&bv), bo: Some(&bo),
                                 ..Default::default() };
        let res = compress(&wv, &wo, h, dh, 6, 6, &opts);
        let mu = x.col_mean();
        // per-head mean output sums preserved
        let mut y = bo.clone();
        let mut yh = res.bo_bias.clone().unwrap();
        for i in 0..h {
            let t: Vec<f64> = wv.slice_rows(i * dh, (i + 1) * dh)
                .matvec(&mu).iter().zip(&bv[i * dh..(i + 1) * dh])
                .map(|(a, b)| a + b).collect();
            let o = wo.slice_cols(i * dh, (i + 1) * dh).matvec(&t);
            let th: Vec<f64> = res.wv_hat.slice_rows(i * dh, (i + 1) * dh)
                .matvec(&mu).iter().zip(&bv[i * dh..(i + 1) * dh])
                .map(|(a, b)| a + b).collect();
            let oh = res.wo_hat.slice_cols(i * dh, (i + 1) * dh).matvec(&th);
            for j in 0..d {
                y[j] += o[j];
                yh[j] += oh[j];
            }
        }
        for (a, b) in y.iter().zip(&yh) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }
}
