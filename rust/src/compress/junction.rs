//! Junction matrices J (paper §3.3, App A.2).
//!
//! `B = U S J`, `A = J⁺ V P⁺` is loss-invariant in J; the block-identity
//! choice J = V₁ gives A = [I  V₁⁺V₂] (Eq 9), saving r² parameters and
//! MACs — with greedy column pivoting for ill-conditioned V₁ (Remark 4).

use crate::tensor::svd::Svd;
use crate::tensor::{pinv, Matrix};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Junction {
    /// J = I: singular values live in B.
    Left,
    /// J = S⁺: singular values live in A.
    Right,
    /// J = [S^{1/2}]⁺: split equally.
    Sym,
    /// J = V₁: A gets an exact identity block (saves r² params).
    BlockId,
}

pub const ALL: [Junction; 4] =
    [Junction::Left, Junction::Right, Junction::Sym, Junction::BlockId];

impl Junction {
    /// Stable name used by the plan TOML schema.
    pub fn name(&self) -> &'static str {
        match self {
            Junction::Left => "left",
            Junction::Right => "right",
            Junction::Sym => "sym",
            Junction::BlockId => "blockid",
        }
    }

    pub fn from_name(s: &str) -> Option<Junction> {
        ALL.iter().copied().find(|j| j.name() == s)
    }
}

#[derive(Clone, Debug)]
pub struct Factors {
    pub b: Matrix,
    pub a: Matrix,
    /// columns of A carrying the identity block (BlockId only).
    pub identity_cols: Option<Vec<usize>>,
}

impl Factors {
    pub fn w_hat(&self) -> Matrix {
        self.b.matmul(&self.a)
    }

    /// Parameter count with the identity-block credit (paper §3.3).
    pub fn params(&self) -> usize {
        let r = self.a.rows();
        let n = self.b.rows() * r + r * self.a.cols();
        if self.identity_cols.is_some() {
            n - r * r
        } else {
            n
        }
    }
}

/// Greedy rank-revealing column selection (modified Gram-Schmidt):
/// picks r columns of the r×d matrix m that span it well.
pub fn greedy_pivot(m: &Matrix, r: usize) -> Vec<usize> {
    let d = m.cols();
    let rows = m.rows();
    let mut chosen: Vec<usize> = Vec::with_capacity(r);
    let mut q: Vec<Vec<f64>> = Vec::new(); // orthonormal basis so far
    let mut resid2: Vec<f64> =
        (0..d).map(|j| (0..rows).map(|i| m[(i, j)].powi(2)).sum()).collect();
    for _ in 0..r {
        let mut best = usize::MAX;
        let mut best_v = -1.0;
        for j in 0..d {
            if !chosen.contains(&j) && resid2[j] > best_v {
                best_v = resid2[j];
                best = j;
            }
        }
        if best == usize::MAX {
            break;
        }
        chosen.push(best);
        // orthonormalize the chosen column, update residuals
        let mut v: Vec<f64> = (0..rows).map(|i| m[(i, best)]).collect();
        for b in &q {
            let dot: f64 = v.iter().zip(b).map(|(a, b)| a * b).sum();
            for (vi, bi) in v.iter_mut().zip(b) {
                *vi -= dot * bi;
            }
        }
        let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if n < 1e-12 {
            continue;
        }
        for vi in &mut v {
            *vi /= n;
        }
        for j in 0..d {
            let dot: f64 = (0..rows).map(|i| m[(i, j)] * v[i]).sum();
            resid2[j] = (resid2[j] - dot * dot).max(0.0);
        }
        q.push(v);
    }
    while chosen.len() < r {
        for j in 0..d {
            if !chosen.contains(&j) {
                chosen.push(j);
                break;
            }
        }
    }
    chosen
}

/// Build (B, A) from a truncated whitened SVD of W·P and P⁺.
pub fn apply(f: &Svd, p_inv: &Matrix, kind: Junction) -> Factors {
    let r = f.s.len();
    let m = f.vt.matmul(p_inv); // V P⁺ (r×d)
    match kind {
        Junction::Left => Factors {
            b: scale_cols(&f.u, &f.s),
            a: m,
            identity_cols: None,
        },
        Junction::Right => Factors {
            b: f.u.clone(),
            a: scale_rows(&m, &f.s),
            identity_cols: None,
        },
        Junction::Sym => {
            let rs: Vec<f64> = f.s.iter().map(|v| v.sqrt()).collect();
            Factors {
                b: scale_cols(&f.u, &rs),
                a: scale_rows(&m, &rs),
                identity_cols: None,
            }
        }
        Junction::BlockId => {
            let idx = greedy_pivot(&m, r);
            let v1 = m.select_cols(&idx);
            let v1_inv = pinv(&v1);
            let mut a = v1_inv.matmul(&m);
            // exact identity at the pivot columns (kill fp residue)
            for (k, &j) in idx.iter().enumerate() {
                for i in 0..r {
                    a[(i, j)] = if i == k { 1.0 } else { 0.0 };
                }
            }
            let b = scale_cols(&f.u, &f.s).matmul(&v1);
            Factors { b, a, identity_cols: Some(idx) }
        }
    }
}

fn scale_cols(m: &Matrix, s: &[f64]) -> Matrix {
    let mut out = m.clone();
    for j in 0..s.len() {
        for i in 0..m.rows() {
            out[(i, j)] *= s[j];
        }
    }
    out
}

fn scale_rows(m: &Matrix, s: &[f64]) -> Matrix {
    let mut out = m.clone();
    for i in 0..s.len() {
        for j in 0..m.cols() {
            out[(i, j)] *= s[i];
        }
    }
    out
}

/// Factor-pair parameter count (paper §3.3).
pub fn factor_params(d_out: usize, d_in: usize, r: usize, blockid: bool)
                     -> usize {
    let n = r * (d_out + d_in);
    if blockid {
        n - r * r
    } else {
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::svd_truncated;
    use crate::util::rng::Rng;

    #[test]
    fn all_junctions_same_w_hat() {
        let mut rng = Rng::new(30);
        let w = rng.normal_matrix(8, 12);
        let f = svd_truncated(&w, 5);
        let p_inv = Matrix::eye(12);
        let reference = apply(&f, &p_inv, Junction::Left).w_hat();
        for kind in [Junction::Right, Junction::Sym, Junction::BlockId] {
            let fac = apply(&f, &p_inv, kind);
            assert!(fac.w_hat().max_abs_diff(&reference) < 1e-8,
                    "{kind:?}");
        }
    }

    #[test]
    fn blockid_has_exact_identity_block() {
        let mut rng = Rng::new(31);
        let w = rng.normal_matrix(10, 10);
        let f = svd_truncated(&w, 4);
        let fac = apply(&f, &Matrix::eye(10), Junction::BlockId);
        let idx = fac.identity_cols.clone().unwrap();
        assert_eq!(idx.len(), 4);
        for (k, &j) in idx.iter().enumerate() {
            for i in 0..4 {
                let expect = if i == k { 1.0 } else { 0.0 };
                assert_eq!(fac.a[(i, j)], expect);
            }
        }
        // params credit
        assert_eq!(fac.params(), 4 * (10 + 10) - 16);
    }

    #[test]
    fn names_roundtrip() {
        for j in ALL {
            assert_eq!(Junction::from_name(j.name()), Some(j));
        }
        assert_eq!(Junction::from_name("nope"), None);
    }

    #[test]
    fn greedy_pivot_prefers_strong_columns() {
        // m has two huge columns and the rest tiny: pivots must take them.
        let mut m = Matrix::zeros(2, 6);
        m[(0, 3)] = 10.0;
        m[(1, 5)] = 8.0;
        for j in 0..6 {
            m[(0, j)] += 0.01;
        }
        let idx = greedy_pivot(&m, 2);
        assert!(idx.contains(&3) && idx.contains(&5), "{idx:?}");
    }
}
