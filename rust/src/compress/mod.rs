//! The paper's compression suite — production rust implementation.
//!
//! Mirrors `python/compile/latentllm/` (the build-time reference) exactly;
//! integration tests cross-check both against artifacts/goldens.json.
//!
//! * [`precond`] — Table 1 pre-conditioners (§3.2, App B.1)
//! * [`junction`] — junction matrices incl. block identity (§3.3, App A.2)
//! * [`asvd`] — local activation-aware SVD (§3.2, App B)
//! * [`joint_qk`] — Algorithm 1: MHA→MLA Tucker/HOSVD (§4.1, App E)
//! * [`joint_vo`] — joint value/output HOSVD (§4.2, App G)
//! * [`joint_ud`] — SparseLLM-style decoupled MLP compression (§4.3, App H)
//! * [`sparse`] — sparse / low-rank+sparse approximation (App I)
//! * [`quant`] — quantization-aware factor distillation (App I.1)
//! * [`rope`] — RoPE-aware attention-map loss (App F.3, Fig 12)
//! * [`rank`] — compression-ratio → rank solvers (§3.3 accounting)
//! * [`plan`] — composable whole-model plans: `Compressor` stages +
//!   registry, `CompressionPlan` (TOML serde, per-layer ratios, rank
//!   overrides, sparse/quant post-stages), `compress_plan`
//! * [`pipeline`] — the §5 protocol presets (`Method` shim over [`plan`])

pub mod asvd;
pub mod joint_qk;
pub mod joint_ud;
pub mod joint_vo;
pub mod junction;
pub mod pipeline;
pub mod plan;
pub mod precond;
pub mod quant;
pub mod rank;
pub mod rope;
pub mod sparse;

pub use pipeline::{compress_model, Method};
pub use plan::{compress_plan, CompressionPlan, Compressor, Registry};
pub use precond::Precond;
