//! Whole-model compression presets (paper §5 protocol — the Table 2 rows).
//! Mirrors python/compile/latentllm/pipeline.py.
//!
//! Since the plan refactor this module is a thin compatibility shim: the
//! eight historical [`Method`]s are presets over [`super::plan`]
//! ([`Method::plan`]), and [`compress_model`] / [`compress_model_on`]
//! wrap [`plan::compress_plan_on`]. New scenarios (per-layer ratio
//! schedules, sparse/quant hybrids, custom stages) are expressed as
//! [`CompressionPlan`]s directly — no new enum arms.

use anyhow::Result;

use super::junction::Junction;
use super::plan::{self, CompressionPlan, Registry};
use super::precond::Precond;
use crate::data::CalibSet;
use crate::model::{MiniConfig, Weights};
use crate::util::pool::Pool;

pub use super::plan::{LayerReport, Report};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Plain,
    AsvdHessian,
    AsvdL1,
    AsvdL2,
    AsvdCov,
    AsvdRootCov,
    LatentLlm,
    /// ablation: joint VO instead of split V/O (Remark 11)
    LatentLlmJointVo,
}

pub const TABLE2_METHODS: [Method; 6] = [
    Method::Plain, Method::AsvdHessian, Method::AsvdL2,
    Method::AsvdCov, Method::AsvdRootCov, Method::LatentLlm,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Plain => "plain",
            Method::AsvdHessian => "asvd_hessian",
            Method::AsvdL1 => "asvd_l1",
            Method::AsvdL2 => "asvd_l2",
            Method::AsvdCov => "asvd_cov",
            Method::AsvdRootCov => "asvd_rootcov",
            Method::LatentLlm => "latentllm",
            Method::LatentLlmJointVo => "latentllm_jointvo",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        [Method::Plain, Method::AsvdHessian, Method::AsvdL1, Method::AsvdL2,
         Method::AsvdCov, Method::AsvdRootCov, Method::LatentLlm,
         Method::LatentLlmJointVo]
            .into_iter()
            .find(|m| m.name() == s)
    }

    pub fn precond(&self) -> Precond {
        match self {
            Method::Plain => Precond::Identity,
            Method::AsvdHessian => Precond::DiagHessian,
            Method::AsvdL1 => Precond::DiagL1,
            Method::AsvdL2 => Precond::DiagL2,
            Method::AsvdCov => Precond::Cov,
            Method::AsvdRootCov | Method::LatentLlm
            | Method::LatentLlmJointVo => Precond::RootCov,
        }
    }

    pub fn is_latent(&self) -> bool {
        matches!(self, Method::LatentLlm | Method::LatentLlmJointVo)
    }

    /// Paper's display label (Table 2 row names).
    pub fn label(&self) -> &'static str {
        match self {
            Method::Plain => "Plain SVD (Identity)",
            Method::AsvdHessian => "ASVD (Hessian)",
            Method::AsvdL1 => "ASVD (L1-norm)",
            Method::AsvdL2 => "ASVD (L2-norm)",
            Method::AsvdCov => "ASVD (Cov)",
            Method::AsvdRootCov => "ASVD (RootCov)",
            Method::LatentLlm => "LatentLLM (RootCov)",
            Method::LatentLlmJointVo => "LatentLLM (JointVO)",
        }
    }

    /// The preset expressed as a [`CompressionPlan`] — bit-identical to
    /// the historical enum pipeline (pinned by `tests/plan.rs`).
    pub fn plan(&self) -> CompressionPlan {
        let latent = self.is_latent();
        CompressionPlan {
            name: self.name().into(),
            label: Some(self.label().into()),
            attn: if *self == Method::LatentLlmJointVo {
                plan::ATTN_LATENT_JOINTVO.into()
            } else if latent {
                plan::ATTN_LATENT.into()
            } else {
                plan::ATTN_LOCAL.into()
            },
            mlp: if latent {
                plan::MLP_JOINT_UD.into()
            } else {
                plan::MLP_LOCAL.into()
            },
            precond: self.precond(),
            junction: if latent { Junction::BlockId } else { Junction::Left },
            ..CompressionPlan::default()
        }
    }
}

/// The Table 2 method set as plans (report sweeps, benches).
pub fn table2_plans() -> Vec<CompressionPlan> {
    TABLE2_METHODS.iter().map(|m| m.plan()).collect()
}

/// Compress every MHA/MLP linear of `weights` to the target ratio with a
/// [`Method`] preset. Returns the effective (reconstructed Ŵ + updated
/// biases) weight set — evaluated through the dense scoring program —
/// plus the report. Thin wrapper over [`plan::compress_plan`].
///
/// Layers run in parallel on the global [`Pool`] (`LATENTLLM_THREADS`);
/// results merge in layer order, so the output is bit-identical to the
/// serial path (pinned by the `layer_parallel_matches_serial_bitwise`
/// test).
pub fn compress_model(cfg: &MiniConfig, weights: &Weights, calib: &CalibSet,
                      method: Method, ratio: f64, qk_iters: usize,
                      ud_iters: usize) -> Result<(Weights, Report)> {
    compress_model_on(&Pool::global(), cfg, weights, calib, method, ratio,
                      qk_iters, ud_iters)
}

/// [`compress_model`] on an explicit pool (tests/benches pin the width).
pub fn compress_model_on(pool: &Pool, cfg: &MiniConfig, weights: &Weights,
                         calib: &CalibSet, method: Method, ratio: f64,
                         qk_iters: usize, ud_iters: usize)
                         -> Result<(Weights, Report)> {
    let p = method.plan().with_ratio(ratio).with_iters(qk_iters, ud_iters);
    plan::compress_plan_on(pool, &Registry::builtin(), cfg, weights, calib,
                           &p, None)
}

/// Support for tests and benches: random weight sets in the exact
/// MiniConfig layout (not behind cfg(test) so `cargo bench` can use it).
pub mod tests_support {
    use super::*;
    use crate::model::io::{Tensor, TensorMap};
    use crate::util::rng::Rng;

    /// Random weights in the exact MiniConfig layout.
    pub fn random_weights(cfg: &MiniConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let mut map = TensorMap::new();
        let put_m = |map: &mut TensorMap, name: String, r: usize,
                         c: usize, rng: &mut Rng| {
            let m = rng.normal_matrix(r, c).scale(1.0 / (c as f64).sqrt());
            map.insert(name, Tensor::F32 { shape: vec![r, c],
                                           data: m.to_f32() });
        };
        let put_v = |map: &mut TensorMap, name: String, n: usize, v: f32| {
            map.insert(name, Tensor::F32 { shape: vec![n],
                                           data: vec![v; n] });
        };
        put_m(&mut map, "tok_emb".into(), cfg.vocab, cfg.d, &mut rng);
        put_m(&mut map, "pos_emb".into(), cfg.max_len, cfg.d, &mut rng);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            put_v(&mut map, format!("{p}ln1.g"), cfg.d, 1.0);
            put_v(&mut map, format!("{p}ln1.b"), cfg.d, 0.0);
            for m in ["wq", "wk", "wv", "wo"] {
                put_m(&mut map, format!("{p}attn.{m}"), cfg.d, cfg.d,
                      &mut rng);
            }
            for b in ["bq", "bk", "bv", "bo"] {
                put_v(&mut map, format!("{p}attn.{b}"), cfg.d, 0.01);
            }
            put_v(&mut map, format!("{p}ln2.g"), cfg.d, 1.0);
            put_v(&mut map, format!("{p}ln2.b"), cfg.d, 0.0);
            put_m(&mut map, format!("{p}mlp.wu"), cfg.d_i, cfg.d, &mut rng);
            put_v(&mut map, format!("{p}mlp.bu"), cfg.d_i, 0.01);
            put_m(&mut map, format!("{p}mlp.wd"), cfg.d, cfg.d_i, &mut rng);
            put_v(&mut map, format!("{p}mlp.bd"), cfg.d, 0.0);
        }
        put_v(&mut map, "lnf.g".into(), cfg.d, 1.0);
        put_v(&mut map, "lnf.b".into(), cfg.d, 0.0);
        Weights::new(map)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::random_weights;
    use super::*;
    use crate::compress::rank;
    use crate::model::config::OPT_MINI_S;

    #[test]
    fn pipeline_hits_target_ratio() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 100);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 256, 7);
        for method in [Method::AsvdRootCov, Method::LatentLlm] {
            for ratio in [0.2f64, 0.4] {
                let (_, rep) = compress_model(&cfg, &w, &cal, method, ratio,
                                              3, 2).unwrap();
                let got = rep.achieved_ratio();
                assert!((got - ratio).abs() < 0.05,
                        "{method:?}@{ratio}: achieved {got}");
            }
        }
    }

    #[test]
    fn latentllm_blockid_credit_gives_higher_ranks() {
        // at equal ratio, latentllm's −r² credit buys strictly larger ranks
        let cfg = OPT_MINI_S;
        let keep = 0.7;
        let r_dense = rank::local_rank(cfg.d, cfg.d, keep, false);
        let r_block = rank::local_rank(cfg.d, cfg.d, keep, true);
        assert!(r_block > r_dense, "{r_block} vs {r_dense}");
    }

    #[test]
    fn method_plans_pick_the_right_stages() {
        for m in [Method::Plain, Method::AsvdHessian, Method::AsvdL1,
                  Method::AsvdL2, Method::AsvdCov, Method::AsvdRootCov] {
            let p = m.plan();
            assert_eq!(p.attn, plan::ATTN_LOCAL);
            assert_eq!(p.mlp, plan::MLP_LOCAL);
            assert_eq!(p.junction, Junction::Left);
            assert_eq!(p.precond, m.precond());
            assert_eq!(p.name, m.name());
        }
        let p = Method::LatentLlm.plan();
        assert_eq!(p.attn, plan::ATTN_LATENT);
        assert_eq!(p.mlp, plan::MLP_JOINT_UD);
        assert_eq!(p.junction, Junction::BlockId);
        let p = Method::LatentLlmJointVo.plan();
        assert_eq!(p.attn, plan::ATTN_LATENT_JOINTVO);
        assert_eq!(p.mlp, plan::MLP_JOINT_UD);
        assert_eq!(table2_plans().len(), TABLE2_METHODS.len());
    }

    #[test]
    fn layer_parallel_matches_serial_bitwise() {
        // the acceptance bar for the parallel pipeline: byte-for-byte
        // identical tensors at every pool width
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 55);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 192, 5);
        for method in [Method::LatentLlm, Method::AsvdRootCov] {
            let (w1, r1) = compress_model_on(&Pool::new(1), &cfg, &w, &cal,
                                             method, 0.3, 2, 1).unwrap();
            let (w4, r4) = compress_model_on(&Pool::new(4), &cfg, &w, &cal,
                                             method, 0.3, 2, 1).unwrap();
            assert_eq!(w1.names().count(), w4.names().count());
            for name in w1.names() {
                let a = w1.tensor(name).unwrap().as_f32().unwrap();
                let b = w4.tensor(name).unwrap().as_f32().unwrap();
                assert!(a.iter().zip(b.iter())
                            .all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{method:?}: {name} diverged between serial and \
                         parallel compression");
            }
            assert_eq!(r1.new_linear_params, r4.new_linear_params);
            assert_eq!(r1.layers.len(), r4.layers.len());
            for (l1, l4) in r1.layers.iter().zip(&r4.layers) {
                assert_eq!(l1.layer, l4.layer);
                assert_eq!(l1.params, l4.params);
                assert_eq!(l1.qk_loss.to_bits(), l4.qk_loss.to_bits());
            }
        }
    }

    #[test]
    fn all_methods_produce_finite_weights() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 101);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 200, 8);
        for method in TABLE2_METHODS {
            let (nw, _) = compress_model(&cfg, &w, &cal, method, 0.3, 2, 1)
                .unwrap();
            for name in nw.names() {
                let t = nw.tensor(name).unwrap();
                if let Ok(data) = t.as_f32() {
                    assert!(data.iter().all(|v| v.is_finite()),
                            "{method:?}: {name} has non-finite values");
                }
            }
        }
    }
}
