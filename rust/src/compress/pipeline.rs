//! Whole-model compression pipeline (paper §5 protocol — the Table 2 rows).
//! Mirrors python/compile/latentllm/pipeline.py.

use anyhow::{Context, Result};

use super::asvd::{self, AsvdOpts};
use super::joint_qk::{self, JointQkOpts};
use super::joint_ud::{self, JointUdOpts};
use super::joint_vo::{self, JointVoOpts};
use super::junction::Junction;
use super::precond::Precond;
use super::rank;
use crate::data::CalibSet;
use crate::model::{MiniConfig, Weights};
use crate::util::pool::Pool;
use crate::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Plain,
    AsvdHessian,
    AsvdL1,
    AsvdL2,
    AsvdCov,
    AsvdRootCov,
    LatentLlm,
    /// ablation: joint VO instead of split V/O (Remark 11)
    LatentLlmJointVo,
}

pub const TABLE2_METHODS: [Method; 6] = [
    Method::Plain, Method::AsvdHessian, Method::AsvdL2,
    Method::AsvdCov, Method::AsvdRootCov, Method::LatentLlm,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Plain => "plain",
            Method::AsvdHessian => "asvd_hessian",
            Method::AsvdL1 => "asvd_l1",
            Method::AsvdL2 => "asvd_l2",
            Method::AsvdCov => "asvd_cov",
            Method::AsvdRootCov => "asvd_rootcov",
            Method::LatentLlm => "latentllm",
            Method::LatentLlmJointVo => "latentllm_jointvo",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        [Method::Plain, Method::AsvdHessian, Method::AsvdL1, Method::AsvdL2,
         Method::AsvdCov, Method::AsvdRootCov, Method::LatentLlm,
         Method::LatentLlmJointVo]
            .into_iter()
            .find(|m| m.name() == s)
    }

    pub fn precond(&self) -> Precond {
        match self {
            Method::Plain => Precond::Identity,
            Method::AsvdHessian => Precond::DiagHessian,
            Method::AsvdL1 => Precond::DiagL1,
            Method::AsvdL2 => Precond::DiagL2,
            Method::AsvdCov => Precond::Cov,
            Method::AsvdRootCov | Method::LatentLlm
            | Method::LatentLlmJointVo => Precond::RootCov,
        }
    }

    pub fn is_latent(&self) -> bool {
        matches!(self, Method::LatentLlm | Method::LatentLlmJointVo)
    }

    /// Paper's display label (Table 2 row names).
    pub fn label(&self) -> &'static str {
        match self {
            Method::Plain => "Plain SVD (Identity)",
            Method::AsvdHessian => "ASVD (Hessian)",
            Method::AsvdL1 => "ASVD (L1-norm)",
            Method::AsvdL2 => "ASVD (L2-norm)",
            Method::AsvdCov => "ASVD (Cov)",
            Method::AsvdRootCov => "ASVD (RootCov)",
            Method::LatentLlm => "LatentLLM (RootCov)",
            Method::LatentLlmJointVo => "LatentLLM (JointVO)",
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct LayerReport {
    pub layer: usize,
    pub qk_rank: usize,
    pub qk_loss: f64,
    pub ud_loss: f64,
    pub params: usize,
}

#[derive(Clone, Debug)]
pub struct Report {
    pub method: Method,
    pub ratio: f64,
    pub layers: Vec<LayerReport>,
    pub orig_linear_params: usize,
    pub new_linear_params: usize,
}

impl Report {
    pub fn achieved_ratio(&self) -> f64 {
        1.0 - self.new_linear_params as f64
            / self.orig_linear_params.max(1) as f64
    }
}

/// One layer's compression output, staged for the deterministic merge:
/// tensors are *named*, not written, so layers can run on any thread.
struct LayerOut {
    rep: LayerReport,
    mats: Vec<(String, Matrix)>,
    biases: Vec<(String, Vec<f64>)>,
}

/// Compress layer `i` of the model — pure w.r.t. `weights`/`calib` (reads
/// only the source weight set), so every layer is independent and the
/// pipeline parallelizes across layers without changing any arithmetic.
fn compress_layer(cfg: &MiniConfig, weights: &Weights, calib: &CalibSet,
                  method: Method, ratio: f64, qk_iters: usize,
                  ud_iters: usize, i: usize) -> Result<LayerOut> {
    let keep = 1.0 - ratio;
    let pk = method.precond();
    let latent = method.is_latent();
    let junction = if latent { Junction::BlockId } else { Junction::Left };
    let (d, dh, h, di) = (cfg.d, cfg.d_h(), cfg.n_heads, cfg.d_i);

    let p = format!("layers.{i}.");
    let x_attn = calib.x(i, "attn_x");
    let x_o = calib.x(i, "o_x");
    let x_mlp = calib.x(i, "mlp_x");
    let mut lrep = LayerReport { layer: i, ..Default::default() };
    let mut mats: Vec<(String, Matrix)> = Vec::new();
    let mut biases: Vec<(String, Vec<f64>)> = Vec::new();

    let wq = weights.matrix(&format!("{p}attn.wq"))?;
    let wk = weights.matrix(&format!("{p}attn.wk"))?;
    let wv = weights.matrix(&format!("{p}attn.wv"))?;
    let wo = weights.matrix(&format!("{p}attn.wo"))?;
    let bq = weights.bias(&format!("{p}attn.bq"))?;
    let bk = weights.bias(&format!("{p}attn.bk"))?;
    let bv = weights.bias(&format!("{p}attn.bv"))?;
    let bo = weights.bias(&format!("{p}attn.bo"))?;
    let wu = weights.matrix(&format!("{p}mlp.wu"))?;
    let wd = weights.matrix(&format!("{p}mlp.wd"))?;
    let bu = weights.bias(&format!("{p}mlp.bu"))?;
    let bd = weights.bias(&format!("{p}mlp.bd"))?;

    if latent {
        // ---- joint QK (§4.1, Alg 1)
        let r_qk = rank::joint_qk_rank(d, dh, h, h, keep, true);
        let jq = joint_qk::compress(&wq, &wk, h, dh, r_qk, r_qk,
                                    &JointQkOpts {
                                        kind: pk, n_iter: qk_iters,
                                        x: Some(x_attn),
                                        bq: Some(&bq), bk: Some(&bk),
                                        ..Default::default()
                                    });
        mats.push((format!("{p}attn.wq"), jq.wq_hat));
        mats.push((format!("{p}attn.wk"), jq.wk_hat));
        biases.push((format!("{p}attn.bq"), jq.bq_bias.unwrap()));
        biases.push((format!("{p}attn.bk"), jq.bk_bias.unwrap()));
        lrep.qk_rank = r_qk;
        lrep.qk_loss = *jq.losses.last().unwrap();
        let mut layer_params = jq.params;

        // ---- V / O
        if method == Method::LatentLlmJointVo {
            let r_vo = rank::local_rank(d, d, keep, true);
            let jv = joint_vo::compress(&wv, &wo, h, dh, r_vo, r_vo,
                                        &JointVoOpts {
                                            kind: pk, n_iter: ud_iters,
                                            x: Some(x_attn),
                                            bv: Some(&bv), bo: Some(&bo),
                                            ..Default::default()
                                        });
            mats.push((format!("{p}attn.wv"), jv.wv_hat));
            mats.push((format!("{p}attn.wo"), jv.wo_hat));
            biases.push((format!("{p}attn.bo"), jv.bo_bias.unwrap()));
            layer_params += jv.params;
        } else {
            // paper default: split V/O, root-cov + block identity
            let r_v = rank::local_rank(d, d, keep, true);
            let rv = asvd::compress(&wv, r_v, &AsvdOpts {
                kind: pk, junction, x: Some(x_attn), bias: Some(&bv),
                ..Default::default()
            });
            let r_o = rank::local_rank(d, d, keep, true);
            let ro = asvd::compress(&wo, r_o, &AsvdOpts {
                kind: pk, junction, x: Some(x_o), bias: Some(&bo),
                ..Default::default()
            });
            mats.push((format!("{p}attn.wv"), rv.w_hat));
            biases.push((format!("{p}attn.bv"), rv.bias.unwrap()));
            mats.push((format!("{p}attn.wo"), ro.w_hat));
            biases.push((format!("{p}attn.bo"), ro.bias.unwrap()));
            layer_params += rv.params + ro.params;
        }

        // ---- joint UD (§4.3)
        let r_u = rank::local_rank(di, d, keep, true);
        let r_d = rank::local_rank(d, di, keep, true);
        let ud = joint_ud::compress(&wu, &bu, &wd, &bd, x_mlp, r_u, r_d,
                                    &JointUdOpts {
                                        n_iter: ud_iters,
                                        junction,
                                        ..Default::default()
                                    });
        mats.push((format!("{p}mlp.wu"), ud.wu_hat));
        biases.push((format!("{p}mlp.bu"), ud.bu));
        mats.push((format!("{p}mlp.wd"), ud.wd_hat));
        biases.push((format!("{p}mlp.bd"), ud.bd));
        lrep.ud_loss = *ud.losses.iter()
            .fold(&f64::INFINITY, |m, v| if v < m { v } else { m });
        layer_params += ud.params;
        lrep.params = layer_params;
    } else {
        // local compression of each of the six linears
        let mut layer_params = 0usize;
        let jobs: [(&str, &Matrix, &[f64], &Matrix); 5] = [
            ("attn.wq", &wq, &bq, x_attn),
            ("attn.wk", &wk, &bk, x_attn),
            ("attn.wv", &wv, &bv, x_attn),
            ("attn.wo", &wo, &bo, x_o),
            ("mlp.wu", &wu, &bu, x_mlp),
        ];
        for (name, w, b, x) in jobs {
            let r = rank::local_rank(w.rows(), w.cols(), keep, false);
            let res = asvd::compress(w, r, &AsvdOpts {
                kind: pk, junction, x: Some(x), bias: Some(b),
                ..Default::default()
            });
            mats.push((format!("{p}{name}"), res.w_hat));
            let bname = format!("{p}{}", name.replace('w', "b"));
            biases.push((bname, res.bias.unwrap()));
            layer_params += res.params;
        }
        // wd sees σ(Wu_orig x + bu)
        let mut z = wu.matmul(x_mlp);
        for r in 0..z.rows() {
            let bi = bu[r];
            for v in z.row_mut(r) {
                *v = (*v + bi).max(0.0);
            }
        }
        let r = rank::local_rank(d, di, keep, false);
        let res = asvd::compress(&wd, r, &AsvdOpts {
            kind: pk, junction, x: Some(&z), bias: Some(&bd),
            ..Default::default()
        });
        mats.push((format!("{p}mlp.wd"), res.w_hat));
        biases.push((format!("{p}mlp.bd"), res.bias.unwrap()));
        layer_params += res.params;
        lrep.params = layer_params;
    }
    Ok(LayerOut { rep: lrep, mats, biases })
}

/// Compress every MHA/MLP linear of `weights` to the target ratio.
/// Returns the effective (reconstructed Ŵ + updated biases) weight set —
/// evaluated through the dense scoring program — plus the report.
///
/// Layers run in parallel on the global [`Pool`] (`LATENTLLM_THREADS`);
/// results merge in layer order, so the output is bit-identical to the
/// serial path (pinned by the `layer_parallel_matches_serial_bitwise`
/// test).
pub fn compress_model(cfg: &MiniConfig, weights: &Weights, calib: &CalibSet,
                      method: Method, ratio: f64, qk_iters: usize,
                      ud_iters: usize) -> Result<(Weights, Report)> {
    compress_model_on(&Pool::global(), cfg, weights, calib, method, ratio,
                      qk_iters, ud_iters)
}

/// [`compress_model`] on an explicit pool (tests/benches pin the width).
pub fn compress_model_on(pool: &Pool, cfg: &MiniConfig, weights: &Weights,
                         calib: &CalibSet, method: Method, ratio: f64,
                         qk_iters: usize, ud_iters: usize)
                         -> Result<(Weights, Report)> {
    let mut report = Report {
        method, ratio, layers: Vec::new(),
        orig_linear_params: cfg.linear_params(),
        new_linear_params: 0,
    };
    let layer_outs = pool.run(cfg.n_layers, |i| {
        compress_layer(cfg, weights, calib, method, ratio, qk_iters,
                       ud_iters, i)
    });
    let mut out = weights.clone();
    for (i, res) in layer_outs.into_iter().enumerate() {
        let lo = res.with_context(|| format!("compress layer {i}"))?;
        for (name, m) in &lo.mats {
            out.set_matrix(name, m);
        }
        for (name, b) in &lo.biases {
            out.set_bias(name, b);
        }
        report.new_linear_params += lo.rep.params;
        report.layers.push(lo.rep);
    }
    Ok((out, report))
}

/// Support for tests and benches: random weight sets in the exact
/// MiniConfig layout (not behind cfg(test) so `cargo bench` can use it).
pub mod tests_support {
    use super::*;
    use crate::model::io::{Tensor, TensorMap};
    use crate::util::rng::Rng;

    /// Random weights in the exact MiniConfig layout.
    pub fn random_weights(cfg: &MiniConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let mut map = TensorMap::new();
        let put_m = |map: &mut TensorMap, name: String, r: usize,
                         c: usize, rng: &mut Rng| {
            let m = rng.normal_matrix(r, c).scale(1.0 / (c as f64).sqrt());
            map.insert(name, Tensor::F32 { shape: vec![r, c],
                                           data: m.to_f32() });
        };
        let put_v = |map: &mut TensorMap, name: String, n: usize, v: f32| {
            map.insert(name, Tensor::F32 { shape: vec![n],
                                           data: vec![v; n] });
        };
        put_m(&mut map, "tok_emb".into(), cfg.vocab, cfg.d, &mut rng);
        put_m(&mut map, "pos_emb".into(), cfg.max_len, cfg.d, &mut rng);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            put_v(&mut map, format!("{p}ln1.g"), cfg.d, 1.0);
            put_v(&mut map, format!("{p}ln1.b"), cfg.d, 0.0);
            for m in ["wq", "wk", "wv", "wo"] {
                put_m(&mut map, format!("{p}attn.{m}"), cfg.d, cfg.d,
                      &mut rng);
            }
            for b in ["bq", "bk", "bv", "bo"] {
                put_v(&mut map, format!("{p}attn.{b}"), cfg.d, 0.01);
            }
            put_v(&mut map, format!("{p}ln2.g"), cfg.d, 1.0);
            put_v(&mut map, format!("{p}ln2.b"), cfg.d, 0.0);
            put_m(&mut map, format!("{p}mlp.wu"), cfg.d_i, cfg.d, &mut rng);
            put_v(&mut map, format!("{p}mlp.bu"), cfg.d_i, 0.01);
            put_m(&mut map, format!("{p}mlp.wd"), cfg.d, cfg.d_i, &mut rng);
            put_v(&mut map, format!("{p}mlp.bd"), cfg.d, 0.0);
        }
        put_v(&mut map, "lnf.g".into(), cfg.d, 1.0);
        put_v(&mut map, "lnf.b".into(), cfg.d, 0.0);
        Weights::new(map)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::random_weights;
    use super::*;
    use crate::model::config::OPT_MINI_S;

    #[test]
    fn pipeline_hits_target_ratio() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 100);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 256, 7);
        for method in [Method::AsvdRootCov, Method::LatentLlm] {
            for ratio in [0.2f64, 0.4] {
                let (_, rep) = compress_model(&cfg, &w, &cal, method, ratio,
                                              3, 2).unwrap();
                let got = rep.achieved_ratio();
                assert!((got - ratio).abs() < 0.05,
                        "{method:?}@{ratio}: achieved {got}");
            }
        }
    }

    #[test]
    fn latentllm_blockid_credit_gives_higher_ranks() {
        // at equal ratio, latentllm's −r² credit buys strictly larger ranks
        let cfg = OPT_MINI_S;
        let keep = 0.7;
        let r_dense = rank::local_rank(cfg.d, cfg.d, keep, false);
        let r_block = rank::local_rank(cfg.d, cfg.d, keep, true);
        assert!(r_block > r_dense, "{r_block} vs {r_dense}");
    }

    #[test]
    fn layer_parallel_matches_serial_bitwise() {
        // the acceptance bar for the parallel pipeline: byte-for-byte
        // identical tensors at every pool width
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 55);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 192, 5);
        for method in [Method::LatentLlm, Method::AsvdRootCov] {
            let (w1, r1) = compress_model_on(&Pool::new(1), &cfg, &w, &cal,
                                             method, 0.3, 2, 1).unwrap();
            let (w4, r4) = compress_model_on(&Pool::new(4), &cfg, &w, &cal,
                                             method, 0.3, 2, 1).unwrap();
            assert_eq!(w1.names().count(), w4.names().count());
            for name in w1.names() {
                let a = w1.tensor(name).unwrap().as_f32().unwrap();
                let b = w4.tensor(name).unwrap().as_f32().unwrap();
                assert!(a.iter().zip(b.iter())
                            .all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{method:?}: {name} diverged between serial and \
                         parallel compression");
            }
            assert_eq!(r1.new_linear_params, r4.new_linear_params);
            assert_eq!(r1.layers.len(), r4.layers.len());
            for (l1, l4) in r1.layers.iter().zip(&r4.layers) {
                assert_eq!(l1.layer, l4.layer);
                assert_eq!(l1.params, l4.params);
                assert_eq!(l1.qk_loss.to_bits(), l4.qk_loss.to_bits());
            }
        }
    }

    #[test]
    fn all_methods_produce_finite_weights() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 101);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 200, 8);
        for method in TABLE2_METHODS {
            let (nw, _) = compress_model(&cfg, &w, &cal, method, 0.3, 2, 1)
                .unwrap();
            for name in nw.names() {
                let t = nw.tensor(name).unwrap();
                if let Ok(data) = t.as_f32() {
                    assert!(data.iter().all(|v| v.is_finite()),
                            "{method:?}: {name} has non-finite values");
                }
            }
        }
    }
}
