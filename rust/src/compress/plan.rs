//! Composable compression plans — the subsystem's public API.
//!
//! A [`CompressionPlan`] describes *what* to do to every layer as data: an
//! attention stage and an MLP stage (names resolved through a [`Registry`]
//! of [`Compressor`]s), a pre-conditioner, a junction, a target ratio or
//! per-layer ratio schedule, per-module rank overrides, iteration budgets,
//! and optional post-stages ([`PostOp`]) that wire the App I sparse/quant
//! machinery into the whole-model path. [`compress_plan`] executes a plan
//! layer-parallel on the [`Pool`] with the same bit-identical merge
//! contract as the historical `compress_model` (which is now a thin shim:
//! `Method::plan()` in [`super::pipeline`]).
//!
//! Plans have TOML serde ([`CompressionPlan::load`] /
//! [`CompressionPlan::to_toml`]) so `latentllm compress --plan plan.toml`,
//! `[compress]` config sections, and the report sweeps all speak the same
//! schema. `latentllm compress --plan … --dry-run` resolves ranks without
//! compressing (see [`CompressionPlan::resolve`]).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::asvd::{self, AsvdOpts};
use super::joint_qk::{self, JointQkOpts};
use super::joint_ud::{self, JointUdOpts};
use super::joint_vo::{self, JointVoOpts};
use super::junction::Junction;
use super::precond::Precond;
use super::{quant, rank, sparse};
use crate::data::CalibSet;
use crate::model::{MiniConfig, Weights};
use crate::util::pool::Pool;
use crate::util::toml::{self, Table, Value};
use crate::{Matrix, PackedMat};

// ---------------------------------------------------------------------------
// per-layer report / output containers

#[derive(Clone, Debug, Default)]
pub struct LayerReport {
    pub layer: usize,
    pub qk_rank: usize,
    pub qk_loss: f64,
    pub ud_loss: f64,
    pub params: usize,
}

/// Whole-model compression report (one per [`compress_plan`] run).
#[derive(Clone, Debug)]
pub struct Report {
    /// display label of the plan that produced this report
    pub plan: String,
    /// the plan's base target ratio (per-layer schedules may deviate)
    pub ratio: f64,
    pub layers: Vec<LayerReport>,
    pub orig_linear_params: usize,
    pub new_linear_params: usize,
}

impl Report {
    pub fn achieved_ratio(&self) -> f64 {
        1.0 - self.new_linear_params as f64
            / self.orig_linear_params.max(1) as f64
    }
}

/// One layer's compression output, staged for the deterministic merge:
/// tensors are *named*, not written, so layers can run on any thread.
#[derive(Clone, Debug)]
pub struct LayerOut {
    pub rep: LayerReport,
    pub mats: Vec<(String, Matrix)>,
    /// Weights already in their execution layout (the 8-bit quant
    /// post-stage emits these instead of dequantized f64 simulations);
    /// the merge stores them natively via [`Weights::set_packed`].
    pub packed: Vec<(String, PackedMat)>,
    pub biases: Vec<(String, Vec<f64>)>,
}

impl LayerOut {
    pub fn new(layer: usize) -> LayerOut {
        LayerOut {
            rep: LayerReport { layer, ..Default::default() },
            mats: Vec::new(),
            packed: Vec::new(),
            biases: Vec::new(),
        }
    }

    /// Merge another stage's output for the same layer (params add; the
    /// QK/UD diagnostics come from whichever stage produced them).
    pub fn absorb(&mut self, other: LayerOut) {
        self.mats.extend(other.mats);
        self.packed.extend(other.packed);
        self.biases.extend(other.biases);
        self.rep.params += other.rep.params;
        if other.rep.qk_rank != 0 {
            self.rep.qk_rank = other.rep.qk_rank;
        }
        if other.rep.qk_loss != 0.0 {
            self.rep.qk_loss = other.rep.qk_loss;
        }
        if other.rep.ud_loss != 0.0 {
            self.rep.ud_loss = other.rep.ud_loss;
        }
    }
}

// ---------------------------------------------------------------------------
// layer context

/// Everything a [`Compressor`] may read while compressing one layer.
pub struct LayerCtx<'a> {
    pub cfg: &'a MiniConfig,
    pub weights: &'a Weights,
    pub calib: &'a CalibSet,
    pub layer: usize,
    /// resolved keep fraction for this layer (1 − ratio)
    pub keep: f64,
    pub plan: &'a CompressionPlan,
}

impl LayerCtx<'_> {
    /// Tensor-name prefix of this layer (`layers.<i>.`).
    pub fn prefix(&self) -> String {
        format!("layers.{}.", self.layer)
    }

    /// Per-module rank: the plan's override if present, else `default`.
    pub fn rank_for(&self, module: &str, default: usize) -> usize {
        self.plan.rank_override(module).unwrap_or(default)
    }

    pub fn matrix(&self, module: &str) -> Result<Matrix> {
        self.weights.matrix(&format!("{}{module}", self.prefix()))
    }

    pub fn bias(&self, module: &str) -> Result<Vec<f64>> {
        self.weights.bias(&format!("{}{module}", self.prefix()))
    }
}

// ---------------------------------------------------------------------------
// the Compressor trait + registry

/// Resolved rank/param schedule entry for one module (dry-run output).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResolvedModule {
    pub module: String,
    pub rank: usize,
    pub params: usize,
}

/// Resolved schedule for one layer.
#[derive(Clone, Debug)]
pub struct ResolvedLayer {
    pub layer: usize,
    pub ratio: f64,
    pub modules: Vec<ResolvedModule>,
}

impl ResolvedLayer {
    pub fn params(&self) -> usize {
        self.modules.iter().map(|m| m.params).sum()
    }
}

/// A per-layer compression stage. Implementations must be pure w.r.t. the
/// context (read `ctx.weights`/`ctx.calib`, return named tensors) so the
/// pipeline can run layers on any thread and still merge bit-identically.
pub trait Compressor: Send + Sync {
    /// Registry key (also the TOML stage name).
    fn name(&self) -> &'static str;

    /// Compress one layer's modules; returns the staged output.
    fn compress(&self, ctx: &LayerCtx) -> Result<LayerOut>;

    /// Rank/param schedule without touching weights (dry-run validation).
    fn resolve(&self, cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
        let _ = (cfg, plan, keep);
        Vec::new()
    }
}

pub const ATTN_LOCAL: &str = "attn_local";
pub const ATTN_LATENT: &str = "attn_latent";
pub const ATTN_LATENT_JOINTVO: &str = "attn_latent_jointvo";
pub const MLP_LOCAL: &str = "mlp_local";
pub const MLP_JOINT_UD: &str = "mlp_joint_ud";

/// Every stage registered by [`Registry::builtin`].
pub const BUILTIN_STAGES: [&str; 5] = [
    ATTN_LOCAL, ATTN_LATENT, ATTN_LATENT_JOINTVO, MLP_LOCAL, MLP_JOINT_UD,
];

/// Name-keyed compressor registry. [`Registry::builtin`] holds the paper's
/// stages; callers may [`Registry::register`] their own before executing a
/// plan that names them.
pub struct Registry {
    map: BTreeMap<String, Arc<dyn Compressor>>,
}

impl Registry {
    pub fn empty() -> Registry {
        Registry { map: BTreeMap::new() }
    }

    pub fn builtin() -> Registry {
        let mut r = Registry::empty();
        r.register(Arc::new(AttnLocal));
        r.register(Arc::new(AttnLatent { joint_vo: false }));
        r.register(Arc::new(AttnLatent { joint_vo: true }));
        r.register(Arc::new(MlpLocal));
        r.register(Arc::new(MlpJointUd));
        r
    }

    pub fn register(&mut self, c: Arc<dyn Compressor>) {
        self.map.insert(c.name().to_string(), c);
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn Compressor>> {
        self.map.get(name).cloned().ok_or_else(|| {
            anyhow!("unknown compressor {name:?} (available: {})",
                    self.names().join(", "))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.map.keys().map(|s| s.as_str()).collect()
    }
}

// ---------------------------------------------------------------------------
// built-in stages

/// Local ASVD of the four attention linears (§3.2 baselines).
struct AttnLocal;

impl Compressor for AttnLocal {
    fn name(&self) -> &'static str {
        ATTN_LOCAL
    }

    fn compress(&self, ctx: &LayerCtx) -> Result<LayerOut> {
        let p = ctx.prefix();
        let pk = ctx.plan.precond;
        let junction = ctx.plan.junction;
        let blockid = junction == Junction::BlockId;
        let x_attn = ctx.calib.x(ctx.layer, "attn_x");
        let x_o = ctx.calib.x(ctx.layer, "o_x");
        let mut out = LayerOut::new(ctx.layer);
        // explicit (weight, bias) name pairs — never derived by string
        // substitution, so weight keys containing 'w' cannot corrupt the
        // merge
        let jobs: [(&str, &str); 4] = [
            ("attn.wq", "attn.bq"), ("attn.wk", "attn.bk"),
            ("attn.wv", "attn.bv"), ("attn.wo", "attn.bo"),
        ];
        for (wname, bname) in jobs {
            let w = ctx.matrix(wname)?;
            let b = ctx.bias(bname)?;
            let x = if wname == "attn.wo" { x_o } else { x_attn };
            let r = ctx.rank_for(
                wname, rank::local_rank(w.rows(), w.cols(), ctx.keep,
                                        blockid));
            let res = asvd::compress(&w, r, &AsvdOpts {
                kind: pk, junction, x: Some(x), bias: Some(&b),
                ..Default::default()
            });
            let bias = res.bias.with_context(|| {
                format!("local ASVD of {p}{wname} returned no bias update")
            })?;
            out.mats.push((format!("{p}{wname}"), res.w_hat));
            out.biases.push((format!("{p}{bname}"), bias));
            out.rep.params += res.params;
        }
        Ok(out)
    }

    fn resolve(&self, cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
        let blockid = plan.junction == Junction::BlockId;
        let d = cfg.d;
        ["attn.wq", "attn.wk", "attn.wv", "attn.wo"].iter().map(|m| {
            let r = plan.rank_override(m)
                .unwrap_or_else(|| rank::local_rank(d, d, keep, blockid))
                .clamp(1, d);
            ResolvedModule {
                module: (*m).to_string(),
                rank: r,
                params: rank::local_params(d, d, r, blockid),
            }
        }).collect()
    }
}

/// Joint QK Tucker/HOSVD (§4.1 Algorithm 1) plus either split V/O
/// (paper default) or joint VO (Remark 11 ablation).
struct AttnLatent {
    joint_vo: bool,
}

impl Compressor for AttnLatent {
    fn name(&self) -> &'static str {
        if self.joint_vo { ATTN_LATENT_JOINTVO } else { ATTN_LATENT }
    }

    fn compress(&self, ctx: &LayerCtx) -> Result<LayerOut> {
        let cfg = ctx.cfg;
        let (d, dh, h) = (cfg.d, cfg.d_h(), cfg.n_heads);
        let p = ctx.prefix();
        let pk = ctx.plan.precond;
        let junction = ctx.plan.junction;
        let blockid = junction == Junction::BlockId;
        let x_attn = ctx.calib.x(ctx.layer, "attn_x");
        let x_o = ctx.calib.x(ctx.layer, "o_x");
        let mut out = LayerOut::new(ctx.layer);

        let wq = ctx.matrix("attn.wq")?;
        let wk = ctx.matrix("attn.wk")?;
        let wv = ctx.matrix("attn.wv")?;
        let wo = ctx.matrix("attn.wo")?;
        let bq = ctx.bias("attn.bq")?;
        let bk = ctx.bias("attn.bk")?;
        let bv = ctx.bias("attn.bv")?;
        let bo = ctx.bias("attn.bo")?;

        // ---- joint QK (§4.1, Alg 1)
        let r_qk = ctx.rank_for(
            "attn.qk", rank::joint_qk_rank(d, dh, h, h, ctx.keep, blockid));
        let jq = joint_qk::compress(&wq, &wk, h, dh, r_qk, r_qk,
                                    &JointQkOpts {
                                        kind: pk,
                                        n_iter: ctx.plan.qk_iters,
                                        x: Some(x_attn),
                                        bq: Some(&bq), bk: Some(&bk),
                                        ..Default::default()
                                    });
        let layer_tag = ctx.layer;
        out.mats.push((format!("{p}attn.wq"), jq.wq_hat));
        out.mats.push((format!("{p}attn.wk"), jq.wk_hat));
        out.biases.push((format!("{p}attn.bq"), jq.bq_bias.with_context(
            || format!("joint QK on layer {layer_tag} produced no bias \
                        update (calibration activations missing?)"))?));
        out.biases.push((format!("{p}attn.bk"), jq.bk_bias.with_context(
            || format!("joint QK on layer {layer_tag} produced no bk bias \
                        update"))?));
        out.rep.qk_rank = r_qk;
        out.rep.qk_loss = *jq.losses.last().with_context(
            || format!("joint QK on layer {layer_tag} recorded no \
                        attention-map loss (zero iterations?)"))?;
        out.rep.params += jq.params;

        // ---- V / O
        if self.joint_vo {
            let r_vo = ctx.rank_for(
                "attn.vo", rank::local_rank(d, d, ctx.keep, blockid));
            let jv = joint_vo::compress(&wv, &wo, h, dh, r_vo, r_vo,
                                        &JointVoOpts {
                                            kind: pk,
                                            n_iter: ctx.plan.ud_iters,
                                            x: Some(x_attn),
                                            bv: Some(&bv), bo: Some(&bo),
                                            ..Default::default()
                                        });
            out.mats.push((format!("{p}attn.wv"), jv.wv_hat));
            out.mats.push((format!("{p}attn.wo"), jv.wo_hat));
            out.biases.push((format!("{p}attn.bo"), jv.bo_bias
                .with_context(|| format!("joint VO on layer {layer_tag} \
                                          produced no bias update"))?));
            out.rep.params += jv.params;
        } else {
            // paper default: split V/O at the latent junction
            let r_v = ctx.rank_for(
                "attn.wv", rank::local_rank(d, d, ctx.keep, blockid));
            let rv = asvd::compress(&wv, r_v, &AsvdOpts {
                kind: pk, junction, x: Some(x_attn), bias: Some(&bv),
                ..Default::default()
            });
            let r_o = ctx.rank_for(
                "attn.wo", rank::local_rank(d, d, ctx.keep, blockid));
            let ro = asvd::compress(&wo, r_o, &AsvdOpts {
                kind: pk, junction, x: Some(x_o), bias: Some(&bo),
                ..Default::default()
            });
            out.mats.push((format!("{p}attn.wv"), rv.w_hat));
            out.biases.push((format!("{p}attn.bv"), rv.bias.with_context(
                || format!("V compression on layer {layer_tag} returned \
                            no bias"))?));
            out.mats.push((format!("{p}attn.wo"), ro.w_hat));
            out.biases.push((format!("{p}attn.bo"), ro.bias.with_context(
                || format!("O compression on layer {layer_tag} returned \
                            no bias"))?));
            out.rep.params += rv.params + ro.params;
        }
        Ok(out)
    }

    fn resolve(&self, cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
        let blockid = plan.junction == Junction::BlockId;
        let (d, dh, h) = (cfg.d, cfg.d_h(), cfg.n_heads);
        let r_qk = plan.rank_override("attn.qk")
            .unwrap_or_else(|| rank::joint_qk_rank(d, dh, h, h, keep,
                                                   blockid))
            .clamp(1, d);
        let mut out = vec![ResolvedModule {
            module: "attn.qk".into(),
            rank: r_qk,
            params: rank::joint_qk_params(d, dh, h, h, r_qk, r_qk, blockid),
        }];
        if self.joint_vo {
            let r_vo = plan.rank_override("attn.vo")
                .unwrap_or_else(|| rank::local_rank(d, d, keep, blockid))
                .clamp(1, d);
            out.push(ResolvedModule {
                module: "attn.vo".into(),
                rank: r_vo,
                params: rank::joint_vo_params(d, d, h, dh, r_vo, r_vo),
            });
        } else {
            for m in ["attn.wv", "attn.wo"] {
                let r = plan.rank_override(m)
                    .unwrap_or_else(|| rank::local_rank(d, d, keep, blockid))
                    .clamp(1, d);
                out.push(ResolvedModule {
                    module: m.to_string(),
                    rank: r,
                    params: rank::local_params(d, d, r, blockid),
                });
            }
        }
        out
    }
}

/// Local ASVD of the MLP pair; the down-projection is fit against the
/// post-activation hidden state σ(Wu x + bu) of the *original* Wu.
struct MlpLocal;

impl Compressor for MlpLocal {
    fn name(&self) -> &'static str {
        MLP_LOCAL
    }

    fn compress(&self, ctx: &LayerCtx) -> Result<LayerOut> {
        let p = ctx.prefix();
        let pk = ctx.plan.precond;
        let junction = ctx.plan.junction;
        let blockid = junction == Junction::BlockId;
        let x_mlp = ctx.calib.x(ctx.layer, "mlp_x");
        let mut out = LayerOut::new(ctx.layer);

        let wu = ctx.matrix("mlp.wu")?;
        let bu = ctx.bias("mlp.bu")?;
        let wd = ctx.matrix("mlp.wd")?;
        let bd = ctx.bias("mlp.bd")?;

        let r_u = ctx.rank_for(
            "mlp.wu", rank::local_rank(wu.rows(), wu.cols(), ctx.keep,
                                       blockid));
        let res_u = asvd::compress(&wu, r_u, &AsvdOpts {
            kind: pk, junction, x: Some(x_mlp), bias: Some(&bu),
            ..Default::default()
        });
        out.mats.push((format!("{p}mlp.wu"), res_u.w_hat));
        out.biases.push((format!("{p}mlp.bu"), res_u.bias.with_context(
            || format!("Wu compression on layer {} returned no bias",
                       ctx.layer))?));
        out.rep.params += res_u.params;

        // wd sees σ(Wu_orig x + bu)
        let z = mlp_hidden(ctx)?;
        let r_d = ctx.rank_for(
            "mlp.wd", rank::local_rank(wd.rows(), wd.cols(), ctx.keep,
                                       blockid));
        let res_d = asvd::compress(&wd, r_d, &AsvdOpts {
            kind: pk, junction, x: Some(&z), bias: Some(&bd),
            ..Default::default()
        });
        out.mats.push((format!("{p}mlp.wd"), res_d.w_hat));
        out.biases.push((format!("{p}mlp.bd"), res_d.bias.with_context(
            || format!("Wd compression on layer {} returned no bias",
                       ctx.layer))?));
        out.rep.params += res_d.params;
        Ok(out)
    }

    fn resolve(&self, cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
        resolve_mlp(cfg, plan, keep)
    }
}

/// SparseLLM-style decoupled joint Up/Down compression (§4.3).
struct MlpJointUd;

impl Compressor for MlpJointUd {
    fn name(&self) -> &'static str {
        MLP_JOINT_UD
    }

    fn compress(&self, ctx: &LayerCtx) -> Result<LayerOut> {
        let cfg = ctx.cfg;
        let (d, di) = (cfg.d, cfg.d_i);
        let p = ctx.prefix();
        let junction = ctx.plan.junction;
        let blockid = junction == Junction::BlockId;
        let x_mlp = ctx.calib.x(ctx.layer, "mlp_x");
        let mut out = LayerOut::new(ctx.layer);

        let wu = ctx.matrix("mlp.wu")?;
        let bu = ctx.bias("mlp.bu")?;
        let wd = ctx.matrix("mlp.wd")?;
        let bd = ctx.bias("mlp.bd")?;

        let r_u = ctx.rank_for(
            "mlp.wu", rank::local_rank(di, d, ctx.keep, blockid));
        let r_d = ctx.rank_for(
            "mlp.wd", rank::local_rank(d, di, ctx.keep, blockid));
        let ud = joint_ud::compress(&wu, &bu, &wd, &bd, x_mlp, r_u, r_d,
                                    &JointUdOpts {
                                        n_iter: ctx.plan.ud_iters,
                                        junction,
                                        ..Default::default()
                                    });
        out.mats.push((format!("{p}mlp.wu"), ud.wu_hat));
        out.biases.push((format!("{p}mlp.bu"), ud.bu));
        out.mats.push((format!("{p}mlp.wd"), ud.wd_hat));
        out.biases.push((format!("{p}mlp.bd"), ud.bd));
        out.rep.ud_loss = ud.losses.iter().copied()
            .fold(f64::INFINITY, f64::min);
        out.rep.params += ud.params;
        Ok(out)
    }

    fn resolve(&self, cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
        resolve_mlp(cfg, plan, keep)
    }
}

/// Both MLP stages share the rank/param schedule (the joint refit keeps
/// the same factor shapes).
fn resolve_mlp(cfg: &MiniConfig, plan: &CompressionPlan, keep: f64)
               -> Vec<ResolvedModule> {
    let blockid = plan.junction == Junction::BlockId;
    let (d, di) = (cfg.d, cfg.d_i);
    let r_u = plan.rank_override("mlp.wu")
        .unwrap_or_else(|| rank::local_rank(di, d, keep, blockid))
        .clamp(1, d.min(di));
    let r_d = plan.rank_override("mlp.wd")
        .unwrap_or_else(|| rank::local_rank(d, di, keep, blockid))
        .clamp(1, d.min(di));
    vec![
        ResolvedModule { module: "mlp.wu".into(), rank: r_u,
                         params: rank::local_params(di, d, r_u, blockid) },
        ResolvedModule { module: "mlp.wd".into(), rank: r_d,
                         params: rank::local_params(d, di, r_d, blockid) },
    ]
}

// ---------------------------------------------------------------------------
// post-stages (App I wiring)

/// A whole-model post-stage applied to every compressed weight of a layer
/// after the attention/MLP stages ran.
#[derive(Clone, Debug, PartialEq)]
pub enum PostOp {
    /// Add a sparse correction D to each compressed Ŵ: hard top-κ
    /// projected GD on the residual W − Ŵ against the module's activation
    /// covariance (App I, Eq 237). κ = `keep_frac` · numel; the kept
    /// entries count toward the layer's parameter total.
    Sparse { keep_frac: f64, n_iter: usize },
    /// Chunk-wise `bits`-bit uniform quantization of each compressed
    /// weight (App I.1, Eq 242) — quantization-aware serving variants.
    Quant { bits: u32, chunk: usize },
}

impl PostOp {
    pub fn name(&self) -> &'static str {
        match self {
            PostOp::Sparse { .. } => "sparse",
            PostOp::Quant { .. } => "quant",
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            PostOp::Sparse { keep_frac, n_iter } => {
                ensure!((0.0..=1.0).contains(keep_frac) && *keep_frac > 0.0,
                        "sparse keep_frac {keep_frac} outside (0, 1]");
                ensure!(*n_iter >= 1, "sparse n_iter must be >= 1");
            }
            PostOp::Quant { bits, chunk } => {
                ensure!((1..=16).contains(bits),
                        "quant bits {bits} outside 1..=16");
                ensure!(*chunk >= 1, "quant chunk must be >= 1");
            }
        }
        Ok(())
    }

    pub fn apply(&self, ctx: &LayerCtx, out: &mut LayerOut) -> Result<()> {
        match self {
            PostOp::Sparse { keep_frac, n_iter } => {
                let prefix = ctx.prefix();
                // one covariance per distinct calibration input — the
                // q/k/v modules all share attn_x
                let mut covs: BTreeMap<&'static str, Matrix> =
                    BTreeMap::new();
                let mut added = 0usize;
                for (name, m) in out.mats.iter_mut() {
                    let name = name.clone();
                    let module =
                        name.strip_prefix(&prefix).unwrap_or(name.as_str());
                    let kind = sparse_input_kind(module)?;
                    if !covs.contains_key(kind) {
                        let x = module_input(ctx, module)?;
                        covs.insert(kind, x.covariance(1e-6));
                    }
                    let c = covs.get(kind).expect("inserted above");
                    let w = ctx.weights.matrix(&name)?;
                    let resid = w.sub(m);
                    let kappa = ((keep_frac * resid.data().len() as f64)
                        as usize).max(1);
                    let (dmat, _) =
                        sparse::projected_gd(&resid, c, kappa, *n_iter);
                    added += sparse::nnz(&dmat);
                    *m = m.add(&dmat);
                }
                out.rep.params += added;
            }
            PostOp::Quant { bits, chunk } => {
                if *bits == 8 {
                    // int8 maps onto the execution layout exactly (same
                    // Eq 242 grid, i8 codes + per-chunk affine params), so
                    // emit `QuantI8` weights directly instead of
                    // round-tripping through a dequantized f64 copy.
                    // Terminal for these tensors: run quant last.
                    for (name, m) in out.mats.drain(..) {
                        out.packed.push(
                            (name, PackedMat::quantize_i8(&m, *chunk)));
                    }
                } else {
                    // other widths have no typed layout yet — keep the
                    // simulated (dequantized f64) weights
                    for (_, m) in out.mats.iter_mut() {
                        *m = quant::quantize_uniform(m, *bits, *chunk);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Which calibration stream a module's input comes from (cache key for
/// the sparse post-stage).
fn sparse_input_kind(module: &str) -> Result<&'static str> {
    Ok(match module {
        "attn.wq" | "attn.wk" | "attn.wv" => "attn_x",
        "attn.wo" => "o_x",
        "mlp.wu" => "mlp_x",
        "mlp.wd" => "mlp_z",
        other => bail!("no calibration input known for module {other:?}"),
    })
}

/// σ(Wu x + bu) through the *original* up-projection — the input the
/// down-projection sees (shared by [`MlpLocal`] and the sparse
/// post-stage).
fn mlp_hidden(ctx: &LayerCtx) -> Result<Matrix> {
    let wu = ctx.matrix("mlp.wu")?;
    let bu = ctx.bias("mlp.bu")?;
    let mut z = wu.matmul(ctx.calib.x(ctx.layer, "mlp_x"));
    for r in 0..z.rows() {
        let bi = bu[r];
        for v in z.row_mut(r) {
            *v = (*v + bi).max(0.0);
        }
    }
    Ok(z)
}

/// Calibration input of a module (the activations its weight multiplies).
fn module_input(ctx: &LayerCtx, module: &str) -> Result<Matrix> {
    Ok(match sparse_input_kind(module)? {
        "mlp_z" => mlp_hidden(ctx)?,
        kind => ctx.calib.x(ctx.layer, kind).clone(),
    })
}

// ---------------------------------------------------------------------------
// the plan

/// A whole-model compression recipe as data. See the module docs for the
/// TOML schema; [`super::pipeline::Method::plan`] builds the eight
/// historical presets.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionPlan {
    /// identifier (report rows, filenames)
    pub name: String,
    /// optional pretty display label (falls back to `name`)
    pub label: Option<String>,
    /// attention-stage registry name
    pub attn: String,
    /// MLP-stage registry name
    pub mlp: String,
    pub precond: Precond,
    pub junction: Junction,
    /// default target compression ratio (fraction of params removed)
    pub ratio: f64,
    /// optional per-layer ratio schedule; layer `i` uses entry
    /// `min(i, len-1)`, empty = uniform `ratio`
    pub layer_ratios: Vec<f64>,
    /// per-module rank overrides, keyed by module (`attn.wq`, `attn.qk`,
    /// `attn.vo`, `mlp.wu`, `mlp.wd`)
    pub ranks: BTreeMap<String, usize>,
    pub qk_iters: usize,
    pub ud_iters: usize,
    /// post-stages applied in order after the attention/MLP stages
    pub post: Vec<PostOp>,
}

impl Default for CompressionPlan {
    /// The paper's §5 protocol (LatentLLM / RootCov / block identity).
    fn default() -> Self {
        CompressionPlan {
            name: "latentllm".into(),
            label: None,
            attn: ATTN_LATENT.into(),
            mlp: MLP_JOINT_UD.into(),
            precond: Precond::RootCov,
            junction: Junction::BlockId,
            ratio: 0.3,
            layer_ratios: Vec::new(),
            ranks: BTreeMap::new(),
            qk_iters: 8,
            ud_iters: 4,
            post: Vec::new(),
        }
    }
}

impl CompressionPlan {
    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn labeled(mut self, label: &str) -> Self {
        self.label = Some(label.to_string());
        self
    }

    /// Re-target the plan at a uniform `ratio`. Clears any per-layer
    /// schedule so the new target actually takes effect (set
    /// [`Self::with_layer_ratios`] *after* this to combine both).
    pub fn with_ratio(mut self, ratio: f64) -> Self {
        self.ratio = ratio;
        self.layer_ratios.clear();
        self
    }

    pub fn with_layer_ratios(mut self, ratios: Vec<f64>) -> Self {
        self.layer_ratios = ratios;
        self
    }

    pub fn with_iters(mut self, qk: usize, ud: usize) -> Self {
        self.qk_iters = qk;
        self.ud_iters = ud;
        self
    }

    pub fn with_post(mut self, op: PostOp) -> Self {
        self.post.push(op);
        self
    }

    pub fn with_rank(mut self, module: &str, rank: usize) -> Self {
        self.ranks.insert(module.to_string(), rank);
        self
    }

    pub fn display_label(&self) -> &str {
        self.label.as_deref().unwrap_or(&self.name)
    }

    pub fn rank_override(&self, module: &str) -> Option<usize> {
        self.ranks.get(module).copied()
    }

    /// Target ratio of layer `i` under the schedule.
    pub fn layer_ratio(&self, layer: usize) -> f64 {
        if self.layer_ratios.is_empty() {
            self.ratio
        } else {
            self.layer_ratios[layer.min(self.layer_ratios.len() - 1)]
        }
    }

    /// Cheap structural validation (stage names, ratio bounds, post-op
    /// parameters). Run by [`compress_plan_on`] and `--dry-run`.
    pub fn validate(&self, registry: &Registry) -> Result<()> {
        registry.get(&self.attn).context("attention stage")?;
        registry.get(&self.mlp).context("mlp stage")?;
        for r in self.layer_ratios.iter().chain(std::iter::once(&self.ratio))
        {
            ensure!((0.0..1.0).contains(r),
                    "compression ratio {r} outside [0, 1)");
        }
        ensure!(self.qk_iters >= 1, "qk_iters must be >= 1");
        ensure!(self.ud_iters >= 1, "ud_iters must be >= 1");
        for (module, r) in &self.ranks {
            ensure!(*r >= 1, "rank override for {module:?} must be >= 1");
        }
        for op in &self.post {
            op.validate()?;
        }
        Ok(())
    }

    /// Resolve the full rank/param schedule without touching weights.
    pub fn resolve(&self, registry: &Registry, cfg: &MiniConfig)
                   -> Result<Vec<ResolvedLayer>> {
        self.validate(registry)?;
        let attn = registry.get(&self.attn)?;
        let mlp = registry.get(&self.mlp)?;
        Ok((0..cfg.n_layers).map(|i| {
            let ratio = self.layer_ratio(i);
            let keep = 1.0 - ratio;
            let mut modules = attn.resolve(cfg, self, keep);
            modules.extend(mlp.resolve(cfg, self, keep));
            ResolvedLayer { layer: i, ratio, modules }
        }).collect())
    }

    // -- TOML serde ---------------------------------------------------------

    /// Parse from a flat TOML table under `prefix` (e.g. `plan` for
    /// standalone files, `compress` for config sections), starting from
    /// `defaults`. Absent keys keep their default.
    pub fn from_table_with(t: &Table, prefix: &str,
                           mut plan: CompressionPlan)
                           -> Result<CompressionPlan> {
        let key = |k: &str| -> String {
            if prefix.is_empty() { k.to_string() } else {
                format!("{prefix}.{k}")
            }
        };
        if let Some(v) = t.get(&key("name")).and_then(|v| v.as_str()) {
            plan.name = v.to_string();
        }
        if let Some(v) = t.get(&key("label")).and_then(|v| v.as_str()) {
            plan.label = Some(v.to_string());
        }
        if let Some(v) = t.get(&key("attn")).and_then(|v| v.as_str()) {
            plan.attn = v.to_string();
        }
        if let Some(v) = t.get(&key("mlp")).and_then(|v| v.as_str()) {
            plan.mlp = v.to_string();
        }
        if let Some(v) = t.get(&key("precond")).and_then(|v| v.as_str()) {
            plan.precond = Precond::from_name(v)
                .with_context(|| format!("unknown precond {v:?}"))?;
        }
        if let Some(v) = t.get(&key("junction")).and_then(|v| v.as_str()) {
            plan.junction = Junction::from_name(v)
                .with_context(|| format!("unknown junction {v:?}"))?;
        }
        if let Some(v) = t.get(&key("ratio")).and_then(|v| v.as_f64()) {
            plan.ratio = v;
        }
        if let Some(Value::Arr(a)) = t.get(&key("layer_ratios")) {
            plan.layer_ratios = a.iter()
                .map(|v| v.as_f64()
                    .context("layer_ratios entries must be numbers"))
                .collect::<Result<Vec<f64>>>()?;
        }
        if let Some(v) = t.get(&key("qk_iters")).and_then(|v| v.as_i64()) {
            ensure!(v >= 1, "qk_iters must be >= 1");
            plan.qk_iters = v as usize;
        }
        if let Some(v) = t.get(&key("ud_iters")).and_then(|v| v.as_i64()) {
            ensure!(v >= 1, "ud_iters must be >= 1");
            plan.ud_iters = v as usize;
        }
        // [<prefix>.ranks]: module = rank
        let rank_prefix = format!("{}.", key("ranks"));
        for (k, v) in t.iter() {
            if let Some(module) = k.strip_prefix(&rank_prefix) {
                let r = v.as_i64().with_context(
                    || format!("rank override {k} must be an integer"))?;
                ensure!(r >= 1, "rank override {k} must be >= 1");
                plan.ranks.insert(module.to_string(), r as usize);
            }
        }
        // post = ["sparse", "quant"], parameters in [<prefix>.sparse] /
        // [<prefix>.quant]
        if let Some(Value::Arr(a)) = t.get(&key("post")) {
            plan.post.clear();
            for v in a {
                let name = v.as_str()
                    .context("post entries must be stage names")?;
                let op = match name {
                    "sparse" => PostOp::Sparse {
                        keep_frac: t.get(&key("sparse.keep_frac"))
                            .and_then(|v| v.as_f64()).unwrap_or(0.05),
                        n_iter: t.get(&key("sparse.n_iter"))
                            .and_then(|v| v.as_i64()).unwrap_or(30)
                            .max(1) as usize,
                    },
                    "quant" => PostOp::Quant {
                        bits: t.get(&key("quant.bits"))
                            .and_then(|v| v.as_i64()).unwrap_or(8)
                            .clamp(1, 16) as u32,
                        chunk: t.get(&key("quant.chunk"))
                            .and_then(|v| v.as_i64()).unwrap_or(64)
                            .max(1) as usize,
                    },
                    other => bail!("unknown post stage {other:?} \
                                    (expected sparse|quant)"),
                };
                plan.post.push(op);
            }
        }
        Ok(plan)
    }

    pub fn from_table(t: &Table, prefix: &str) -> Result<CompressionPlan> {
        Self::from_table_with(t, prefix, CompressionPlan::default())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<CompressionPlan>
    {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read plan {}", path.display()))?;
        Self::from_table(&toml::parse(&text)?, "plan")
            .with_context(|| format!("parse plan {}", path.display()))
    }

    /// Serialize to the `[plan]` TOML schema ([`CompressionPlan::load`]
    /// round-trips it).
    pub fn to_toml(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "[plan]");
        let _ = writeln!(s, "name = \"{}\"", self.name);
        if let Some(l) = &self.label {
            let _ = writeln!(s, "label = \"{l}\"");
        }
        let _ = writeln!(s, "attn = \"{}\"", self.attn);
        let _ = writeln!(s, "mlp = \"{}\"", self.mlp);
        let _ = writeln!(s, "precond = \"{}\"", self.precond.name());
        let _ = writeln!(s, "junction = \"{}\"", self.junction.name());
        let _ = writeln!(s, "ratio = {}", self.ratio);
        if !self.layer_ratios.is_empty() {
            let items: Vec<String> = self.layer_ratios.iter()
                .map(|r| format!("{r}")).collect();
            let _ = writeln!(s, "layer_ratios = [{}]", items.join(", "));
        }
        let _ = writeln!(s, "qk_iters = {}", self.qk_iters);
        let _ = writeln!(s, "ud_iters = {}", self.ud_iters);
        if !self.post.is_empty() {
            let items: Vec<String> = self.post.iter()
                .map(|op| format!("\"{}\"", op.name())).collect();
            let _ = writeln!(s, "post = [{}]", items.join(", "));
            for op in &self.post {
                match op {
                    PostOp::Sparse { keep_frac, n_iter } => {
                        let _ = writeln!(s, "\n[plan.sparse]");
                        let _ = writeln!(s, "keep_frac = {keep_frac}");
                        let _ = writeln!(s, "n_iter = {n_iter}");
                    }
                    PostOp::Quant { bits, chunk } => {
                        let _ = writeln!(s, "\n[plan.quant]");
                        let _ = writeln!(s, "bits = {bits}");
                        let _ = writeln!(s, "chunk = {chunk}");
                    }
                }
            }
        }
        if !self.ranks.is_empty() {
            let _ = writeln!(s, "\n[plan.ranks]");
            for (module, r) in &self.ranks {
                let _ = writeln!(s, "{module} = {r}");
            }
        }
        s
    }
}

// ---------------------------------------------------------------------------
// execution

/// Layer-completion hook; the layer-parallel pool invokes it from worker
/// threads as each layer finishes (hence `Send + Sync`). Completion order
/// is pool order, not necessarily layer order.
pub trait ProgressObserver: Send + Sync {
    fn layer_done(&self, layer: usize, n_layers: usize, rep: &LayerReport);
}

/// Execute `plan` over every layer of `weights` on the global [`Pool`]
/// with the builtin [`Registry`]. Returns the effective (reconstructed
/// Ŵ + updated biases) weight set plus the report.
pub fn compress_plan(cfg: &MiniConfig, weights: &Weights, calib: &CalibSet,
                     plan: &CompressionPlan) -> Result<(Weights, Report)> {
    compress_plan_on(&Pool::global(), &Registry::builtin(), cfg, weights,
                     calib, plan, None)
}

/// [`compress_plan`] with an explicit pool, registry, and optional
/// progress observer. Layers run in parallel; results merge in layer
/// order, so the output is bit-identical to the serial path at any pool
/// width (pinned by `layer_parallel_matches_serial_bitwise`).
pub fn compress_plan_on(pool: &Pool, registry: &Registry, cfg: &MiniConfig,
                        weights: &Weights, calib: &CalibSet,
                        plan: &CompressionPlan,
                        observer: Option<&dyn ProgressObserver>)
                        -> Result<(Weights, Report)> {
    plan.validate(registry)?;
    let attn = registry.get(&plan.attn)?;
    let mlp = registry.get(&plan.mlp)?;
    let n_layers = cfg.n_layers;
    let layer_outs = pool.run(n_layers, |i| -> Result<LayerOut> {
        let ctx = LayerCtx {
            cfg, weights, calib,
            layer: i,
            keep: 1.0 - plan.layer_ratio(i),
            plan,
        };
        let mut out = attn.compress(&ctx)
            .with_context(|| format!("stage {} on layer {i}", plan.attn))?;
        out.absorb(mlp.compress(&ctx)
            .with_context(|| format!("stage {} on layer {i}", plan.mlp))?);
        for op in &plan.post {
            op.apply(&ctx, &mut out).with_context(
                || format!("post stage {} on layer {i}", op.name()))?;
        }
        if let Some(obs) = observer {
            obs.layer_done(i, n_layers, &out.rep);
        }
        Ok(out)
    });
    let mut report = Report {
        plan: plan.display_label().to_string(),
        ratio: plan.ratio,
        layers: Vec::new(),
        orig_linear_params: cfg.linear_params(),
        new_linear_params: 0,
    };
    let mut out = weights.clone();
    for (i, res) in layer_outs.into_iter().enumerate() {
        let lo = res.with_context(|| format!("compress layer {i}"))?;
        for (name, m) in &lo.mats {
            out.set_matrix(name, m);
        }
        for (name, p) in &lo.packed {
            out.set_packed(name, p);
        }
        for (name, b) in &lo.biases {
            out.set_bias(name, b);
        }
        report.new_linear_params += lo.rep.params;
        report.layers.push(lo.rep);
    }
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::tests_support::random_weights;
    use crate::model::config::OPT_MINI_S;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn full_plan() -> CompressionPlan {
        CompressionPlan::default()
            .named("mixed")
            .labeled("Mixed sweep")
            .with_ratio(0.25)
            .with_layer_ratios(vec![0.2, 0.5])
            .with_iters(3, 2)
            .with_rank("attn.qk", 48)
            .with_rank("mlp.wu", 24)
            .with_post(PostOp::Sparse { keep_frac: 0.02, n_iter: 10 })
            .with_post(PostOp::Quant { bits: 8, chunk: 64 })
    }

    #[test]
    fn toml_round_trip() {
        let plan = full_plan();
        let text = plan.to_toml();
        let parsed = CompressionPlan::from_table(
            &toml::parse(&text).unwrap(), "plan").unwrap();
        assert_eq!(plan, parsed, "plan ↔ TOML round trip:\n{text}");
        // a second round trip is a fixed point
        assert_eq!(parsed.to_toml(), text);
    }

    #[test]
    fn registry_resolves_every_builtin() {
        let reg = Registry::builtin();
        for name in BUILTIN_STAGES {
            let c = reg.get(name).unwrap();
            assert_eq!(c.name(), name);
        }
        assert_eq!(reg.names().len(), BUILTIN_STAGES.len());
        let err = reg.get("nope").unwrap_err().to_string();
        assert!(err.contains("attn_latent"), "{err}");
    }

    #[test]
    fn validate_rejects_malformed_plans() {
        let reg = Registry::builtin();
        let bad_stage = CompressionPlan {
            attn: "nope".into(), ..CompressionPlan::default()
        };
        assert!(bad_stage.validate(&reg).is_err());
        let bad_ratio = CompressionPlan::default().with_ratio(1.5);
        assert!(bad_ratio.validate(&reg).is_err());
        let bad_layer = CompressionPlan::default()
            .with_layer_ratios(vec![0.2, -0.1]);
        assert!(bad_layer.validate(&reg).is_err());
        let bad_post = CompressionPlan::default()
            .with_post(PostOp::Sparse { keep_frac: 0.0, n_iter: 5 });
        assert!(bad_post.validate(&reg).is_err());
        let bad_quant = CompressionPlan::default()
            .with_post(PostOp::Quant { bits: 32, chunk: 64 });
        assert!(bad_quant.validate(&reg).is_err());
        assert!(full_plan().validate(&reg).is_ok());
    }

    #[test]
    fn resolve_hits_param_target() {
        let cfg = OPT_MINI_S;
        let reg = Registry::builtin();
        for plan in [CompressionPlan::default().with_ratio(0.3),
                     CompressionPlan {
                         attn: ATTN_LOCAL.into(),
                         mlp: MLP_LOCAL.into(),
                         junction: Junction::Left,
                         ..CompressionPlan::default()
                     }.with_ratio(0.3)] {
            let layers = plan.resolve(&reg, &cfg).unwrap();
            assert_eq!(layers.len(), cfg.n_layers);
            let total: usize = layers.iter().map(|l| l.params()).sum();
            let target = 0.7 * cfg.linear_params() as f64;
            let rel = (total as f64 - target).abs() / target;
            assert!(rel < 0.1,
                    "{}: resolved {total} vs target {target}", plan.attn);
        }
    }

    #[test]
    fn with_ratio_retargets_uniformly() {
        // a stale per-layer schedule must not silently swallow the new
        // target (--ratio overrides, table2/fig5 ratio sweeps)
        let p = CompressionPlan::default()
            .with_layer_ratios(vec![0.1, 0.7])
            .with_ratio(0.4);
        assert!(p.layer_ratios.is_empty());
        assert_eq!(p.layer_ratio(0), 0.4);
        assert_eq!(p.layer_ratio(1), 0.4);
    }

    #[test]
    fn resolve_respects_overrides_and_schedule() {
        let cfg = OPT_MINI_S;
        let reg = Registry::builtin();
        let plan = CompressionPlan::default()
            .with_layer_ratios(vec![0.2, 0.6])
            .with_rank("mlp.wu", 17);
        let layers = plan.resolve(&reg, &cfg).unwrap();
        assert_eq!(layers[0].ratio, 0.2);
        assert_eq!(layers[1].ratio, 0.6);
        // the shallow layer keeps a larger QK rank than the deep one
        let qk = |l: &ResolvedLayer| l.modules.iter()
            .find(|m| m.module == "attn.qk").unwrap().rank;
        assert!(qk(&layers[0]) > qk(&layers[1]));
        for l in &layers {
            let wu = l.modules.iter().find(|m| m.module == "mlp.wu")
                .unwrap();
            assert_eq!(wu.rank, 17, "override applies to every layer");
        }
    }

    struct Counter(AtomicUsize);
    impl ProgressObserver for Counter {
        fn layer_done(&self, _layer: usize, _n: usize, rep: &LayerReport) {
            assert!(rep.params > 0);
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn observer_reports_every_layer() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 71);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 160, 3);
        let plan = CompressionPlan::default().with_ratio(0.3)
            .with_iters(2, 1);
        let obs = Counter(AtomicUsize::new(0));
        let (_, rep) = compress_plan_on(&Pool::new(2), &Registry::builtin(),
                                        &cfg, &w, &cal, &plan, Some(&obs))
            .unwrap();
        assert_eq!(obs.0.load(Ordering::SeqCst), cfg.n_layers);
        assert_eq!(rep.layers.len(), cfg.n_layers);
    }

    #[test]
    fn per_layer_schedule_changes_ranks() {
        let cfg = OPT_MINI_S;
        let w = random_weights(&cfg, 72);
        let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 160, 4);
        let plan = CompressionPlan::default()
            .with_layer_ratios(vec![0.15, 0.6])
            .with_iters(2, 1);
        let (nw, rep) = compress_plan(&cfg, &w, &cal, &plan).unwrap();
        assert!(rep.layers[0].qk_rank > rep.layers[1].qk_rank,
                "lighter ratio must buy a larger rank: {} vs {}",
                rep.layers[0].qk_rank, rep.layers[1].qk_rank);
        assert!(rep.layers[0].params > rep.layers[1].params);
        for name in nw.names() {
            let t = nw.tensor(name).unwrap();
            if let Ok(data) = t.as_f32() {
                assert!(data.iter().all(|v| v.is_finite()),
                        "{name} has non-finite values");
            }
        }
    }
}
