//! Pre-conditioning matrices P for activation-aware SVD
//! (paper §3.2, Table 1, App B.1).
//!
//! The optimal choice is the root covariance P = C^{1/2} (Eq 5); the others
//! are the published baselines reproduced for Table 2 and Figs 7/16.

use crate::tensor::{pinv_psd, sqrt_and_invsqrt_psd};
use crate::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precond {
    /// P = I — plain SVD [Denton'14; Sainath'13]
    Identity,
    /// diag[(XXᵀ+λI)^{-1}]^{-1/2} — OBS / GPTQ / SparseGPT
    DiagHessian,
    /// diag[Σ_j |X_ij|]^α — ASVD / AWQ (α = 0.5)
    DiagL1,
    /// diag[XXᵀ]^{1/2} — WandA
    DiagL2,
    /// XXᵀ + λI — CorDA
    Cov,
    /// (XXᵀ + λI)^{1/2} — LatentLLM (optimal)
    RootCov,
}

pub const ALL: [Precond; 6] = [
    Precond::Identity, Precond::DiagHessian, Precond::DiagL1,
    Precond::DiagL2, Precond::Cov, Precond::RootCov,
];

impl Precond {
    pub fn name(&self) -> &'static str {
        match self {
            Precond::Identity => "identity",
            Precond::DiagHessian => "diag_hessian",
            Precond::DiagL1 => "diag_l1",
            Precond::DiagL2 => "diag_l2",
            Precond::Cov => "cov",
            Precond::RootCov => "rootcov",
        }
    }

    pub fn from_name(name: &str) -> Option<Precond> {
        ALL.iter().copied().find(|p| p.name() == name)
    }

    /// Build (P, P⁺) from covariance C (and optionally raw activations
    /// for the ℓ1 variant).
    pub fn build(&self, c: &Matrix, x: Option<&Matrix>) -> (Matrix, Matrix) {
        let d = c.rows();
        match self {
            Precond::Identity => (Matrix::eye(d), Matrix::eye(d)),
            Precond::DiagHessian => {
                let mut creg = c.clone();
                for i in 0..d {
                    creg[(i, i)] += 1e-10;
                }
                let h = crate::tensor::solve(&creg, &Matrix::eye(d));
                let dg: Vec<f64> = (0..d)
                    .map(|i| h[(i, i)].max(1e-30).powf(-0.5))
                    .collect();
                diag_pair(&dg)
            }
            Precond::DiagL1 => {
                let dg: Vec<f64> = match x {
                    Some(x) => (0..d)
                        .map(|i| {
                            let s: f64 =
                                x.row(i).iter().map(|v| v.abs()).sum();
                            (s / x.cols().max(1) as f64).max(1e-30).sqrt()
                        })
                        .collect(),
                    None => (0..d)
                        .map(|i| c[(i, i)].max(1e-30).sqrt().sqrt())
                        .collect(),
                };
                diag_pair(&dg)
            }
            Precond::DiagL2 => {
                let dg: Vec<f64> =
                    (0..d).map(|i| c[(i, i)].max(1e-30).sqrt()).collect();
                diag_pair(&dg)
            }
            Precond::Cov => (c.clone(), pinv_psd(c)),
            Precond::RootCov => sqrt_and_invsqrt_psd(c),
        }
    }
}

fn diag_pair(dg: &[f64]) -> (Matrix, Matrix) {
    let d = dg.len();
    let mut p = Matrix::zeros(d, d);
    let mut pi = Matrix::zeros(d, d);
    for i in 0..d {
        p[(i, i)] = dg[i];
        pi[(i, i)] = 1.0 / dg[i];
    }
    (p, pi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{decaying_covariance, wishart, Rng};

    #[test]
    fn rootcov_inverse_pair() {
        let mut rng = Rng::new(21);
        let c = wishart(&mut rng, &decaying_covariance(10, 0.9), 64);
        let (p, pi) = Precond::RootCov.build(&c, None);
        assert!(p.matmul(&pi).max_abs_diff(&Matrix::eye(10)) < 1e-7);
        assert!(p.matmul(&p).max_abs_diff(&c) < 1e-7);
    }

    #[test]
    fn diagonal_variants_are_diagonal() {
        let mut rng = Rng::new(22);
        let x = rng.normal_matrix(6, 40);
        let c = x.covariance(1e-6);
        for kind in [Precond::DiagHessian, Precond::DiagL1, Precond::DiagL2] {
            let (p, pi) = kind.build(&c, Some(&x));
            for i in 0..6 {
                for j in 0..6 {
                    if i != j {
                        assert_eq!(p[(i, j)], 0.0);
                    }
                }
                assert!((p[(i, i)] * pi[(i, i)] - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn names_roundtrip() {
        for p in ALL {
            assert_eq!(Precond::from_name(p.name()), Some(p));
        }
    }
}
