//! Quantization-aware distillation of low-rank factors (paper App I.1):
//! chunk-wise q-bit uniform quantization (Eq 242) + STE-style projected
//! gradient refinement of (B, A) against the activation loss.
//!
//! The whole-model path reaches this through the `quant` post-stage of
//! [`super::plan`] (`PostOp::Quant` applies [`quantize_uniform`] to every
//! compressed effective weight).

use crate::tensor::eig::eigh;
use crate::tensor::linalg::act_loss;
use crate::Matrix;

/// Chunk-wise min/max uniform quantization over the flat buffer (Eq 242).
pub fn quantize_uniform(m: &Matrix, bits: u32, chunk: usize) -> Matrix {
    let levels = ((1u64 << bits) - 1) as f64;
    let mut out = m.clone();
    let data = out.data_mut();
    let n = data.len();
    let mut s = 0;
    while s < n {
        let e = (s + chunk).min(n);
        let seg = &mut data[s..e];
        let lo = seg.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = seg.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if hi - lo > 1e-12 {
            let scale = levels / (hi - lo);
            for v in seg.iter_mut() {
                *v = ((*v - lo) * scale).round() / scale + lo;
            }
        } else {
            // degenerate chunk: snap to the (shared) low endpoint instead
            // of silently passing values through unquantized, so the
            // chunk is representable on any grid — scale 0, zero-point
            // `lo`, all codes equal — and the int8 execution layout
            // (tensor/packed.rs) represents constant chunks exactly
            for v in seg.iter_mut() {
                *v = lo;
            }
        }
        s = e;
    }
    out
}

/// Quantize (B, A) then STE-refine. Returns (Bq, Aq, loss history) with
/// history[0] = post-quantization loss, history.last() = refined.
pub fn quantize_factors(b0: &Matrix, a0: &Matrix, w: &Matrix, c: &Matrix,
                        bits: u32, chunk: usize, n_iter: usize)
                        -> (Matrix, Matrix, Vec<f64>) {
    let (wc, _) = eigh(c);
    let lc = wc.last().copied().unwrap_or(0.0).max(1e-12);
    let mut fb = b0.clone(); // full-precision shadow (STE state)
    let mut fa = a0.clone();
    let mut bq = quantize_uniform(&fb, bits, chunk);
    let mut aq = quantize_uniform(&fa, bits, chunk);
    let mut hist = vec![act_loss(w, &bq.matmul(&aq), c)];
    for _ in 0..n_iter {
        let e = bq.matmul(&aq).sub(w).matmul(c);
        let gb = e.matmul_bt(&aq).scale(2.0);
        let ga = bq.matmul_at(&e).scale(2.0);
        let lb = 2.0 * lc * aq.frob2().max(1e-12);
        let la = 2.0 * lc * bq.frob2().max(1e-12);
        fb = fb.sub(&gb.scale(1.0 / lb));
        fa = fa.sub(&ga.scale(1.0 / la));
        bq = quantize_uniform(&fb, bits, chunk);
        aq = quantize_uniform(&fa, bits, chunk);
        hist.push(act_loss(w, &bq.matmul(&aq), c));
    }
    (bq, aq, hist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::asvd::{self, AsvdOpts};
    use crate::compress::junction::Junction;
    use crate::compress::precond::Precond;
    use crate::util::rng::{decaying_covariance, wishart, Rng};

    #[test]
    fn quantizer_level_count() {
        let mut rng = Rng::new(90);
        let m = rng.normal_matrix(8, 8);
        let q = quantize_uniform(&m, 2, 64);
        let uniq: std::collections::BTreeSet<i64> =
            q.data().iter().map(|v| (v * 1e9) as i64).collect();
        assert!(uniq.len() <= 4, "2-bit should give ≤4 levels per chunk");
        // identity at high precision
        let q16 = quantize_uniform(&m, 16, 64);
        assert!(q16.max_abs_diff(&m) < 1e-3);
    }

    #[test]
    fn degenerate_chunks_quantize_exactly() {
        // all-equal chunk: values are the shared endpoint, bit-unchanged
        let m = Matrix::from_fn(4, 4, |_, _| 1.25);
        let q = quantize_uniform(&m, 8, 8);
        assert_eq!(q, m, "constant chunks must be represented exactly");
        // single-element chunks are degenerate by construction
        let s = Matrix::from_fn(1, 5, |_, j| j as f64 * 0.3 - 0.7);
        let q1 = quantize_uniform(&s, 8, 1);
        assert_eq!(q1, s, "chunk=1 must pass every value through exactly");
        // near-degenerate spread (≤1e-12) snaps to the chunk's low
        // endpoint rather than leaking unquantized values
        let mut t = Matrix::from_fn(1, 4, |_, _| 2.0);
        t[(0, 2)] = 2.0 + 5e-13;
        let qt = quantize_uniform(&t, 8, 4);
        for j in 0..4 {
            assert_eq!(qt[(0, j)], 2.0);
        }
    }

    #[test]
    fn ste_refinement_reduces_loss() {
        let mut rng = Rng::new(91);
        let w = rng.normal_matrix(12, 12);
        let c = wishart(&mut rng, &decaying_covariance(12, 0.9), 24);
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::Left,
                              ..Default::default() };
        let lr = asvd::compress_with_cov(&w, 6, &c, &vec![0.0; 12], &opts);
        let (_, _, hist) = quantize_factors(&lr.factors.b, &lr.factors.a,
                                            &w, &c, 4, 32, 25);
        let best = hist.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(best <= hist[0] * (1.0 + 1e-9), "{hist:?}");
        assert!(best < hist[0], "refinement should improve: {hist:?}");
    }
}
