//! Compression-ratio → rank solvers (paper §3.3 parameter accounting).
//! Mirrors python/compile/latentllm/rank.py exactly.

/// Rank for one d_out×d_in linear so the factor params ≈ keep·d_out·d_in.
pub fn local_rank(d_out: usize, d_in: usize, keep: f64, blockid: bool)
                  -> usize {
    let target = keep * (d_out * d_in) as f64;
    let s = (d_out + d_in) as f64;
    let r = if blockid {
        let disc = (s * s - 4.0 * target).max(0.0);
        (s - disc.sqrt()) / 2.0
    } else {
        target / s
    };
    (r.round() as usize).clamp(1, d_out.min(d_in))
}

pub fn local_params(d_out: usize, d_in: usize, r: usize, blockid: bool)
                    -> usize {
    let n = r * (d_out + d_in);
    if blockid {
        n - r * r
    } else {
        n
    }
}

/// Shared rank rq = rk = r for the joint QK factorization (§4.1):
/// params = (rq+rk)(d + d_h·h) − rq² − rk² − d_h²·h.
pub fn joint_qk_rank(d: usize, d_h: usize, n_q: usize, n_kv: usize,
                     keep: f64, blockid: bool) -> usize {
    let orig = (d * d_h * (n_q + n_kv)) as f64;
    let target = keep * orig;
    let s = (2 * d + d_h * (n_q + n_kv)) as f64;
    let r = if blockid {
        let credit = (d_h * d_h * n_q.min(n_kv)) as f64;
        let disc = s * s - 8.0 * (target + credit);
        if disc < 0.0 {
            return d.min(d_h * n_q.min(n_kv));
        }
        (s - disc.sqrt()) / 4.0
    } else {
        target / s
    };
    (r.round() as usize).clamp(1, d)
}

pub fn joint_qk_params(d: usize, d_h: usize, n_q: usize, n_kv: usize,
                       rq: usize, rk: usize, blockid: bool) -> usize {
    let n = (rq + rk) * d + n_q * d_h * rq + n_kv * d_h * rk;
    if blockid {
        n - rq * rq - rk * rk - d_h * d_h * n_q.min(n_kv)
    } else {
        n
    }
}

/// Joint VO parameter count (§4.2): shared Av (rv×d) + Bo (d'×ro) plus
/// per-head Bv/Ao factors, with the identity-junction credit — the single
/// source of truth for `joint_vo::compress` and the plan dry-run.
pub fn joint_vo_params(d: usize, d_out: usize, n_heads: usize, d_h: usize,
                       rv: usize, ro: usize) -> usize {
    let n = rv * d + ro * d_out + n_heads * d_h * (rv + ro);
    n.saturating_sub(rv * rv + ro * ro + d_h * d_h * n_heads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{dim, run_cases};

    #[test]
    fn rank_inverts_param_count() {
        run_cases("rank-params-roundtrip", 60, 0x51, |rng, _| {
            let d_out = dim(rng, 8, 256);
            let d_in = dim(rng, 8, 256);
            let keep = 0.2 + 0.7 * rng.uniform();
            for blockid in [false, true] {
                let r = local_rank(d_out, d_in, keep, blockid);
                let p = local_params(d_out, d_in, r, blockid) as f64;
                let target = keep * (d_out * d_in) as f64;
                // within one rank step of the target (or clamped)
                let step = (d_out + d_in) as f64;
                if r < d_out.min(d_in) && r > 1 {
                    prop_assert!((p - target).abs() <= step,
                                 "params {p} target {target} \
                                  (d'={d_out}, d={d_in}, keep={keep})");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blockid_always_shrinks() {
        // §3.3: r(d+d')−r² < d·d' for every r < min(d,d').
        run_cases("blockid-always-shrinks", 40, 0x52, |rng, _| {
            let d = dim(rng, 4, 128);
            let r = dim(rng, 1, d - 1);
            prop_assert!(local_params(d, d, r, true) < d * d,
                         "d={d} r={r}");
            Ok(())
        });
    }

    #[test]
    fn paper_example_25pct_latent() {
        // §3.3 worked example: d=d', r=0.75d → dense 1.5d² (50% MORE than
        // d²), blockid (15/16)d² (< d²).
        let d = 1024usize;
        let r = 3 * d / 4;
        assert_eq!(local_params(d, d, r, false), 3 * d * d / 2);
        assert_eq!(local_params(d, d, r, true), 15 * d * d / 16);
    }

    #[test]
    fn joint_vo_params_formula() {
        let (d, dh, h) = (96usize, 24usize, 4usize);
        let r = 40usize;
        let manual = (r * d + r * d + h * dh * 2 * r)
            - (2 * r * r + dh * dh * h);
        assert_eq!(joint_vo_params(d, d, h, dh, r, r), manual);
        // credit can never underflow to a huge value
        assert_eq!(joint_vo_params(4, 4, 2, 2, 1, 1), 16usize
                       .saturating_sub(1 + 1 + 8));
    }

    #[test]
    fn joint_qk_rank_solves_target() {
        let (d, dh, h) = (128usize, 32usize, 4usize);
        for keep in [0.5, 0.7, 0.9] {
            let r = joint_qk_rank(d, dh, h, h, keep, true);
            let p = joint_qk_params(d, dh, h, h, r, r, true) as f64;
            let target = keep * (2 * d * d) as f64;
            assert!(p <= target + (4 * d) as f64,
                    "keep {keep}: params {p} > target {target}");
        }
    }
}
