//! RoPE-aware attention-map loss (paper App F.3, Fig 12).
//!
//! With rotary position embeddings the attention kernel at relative offset
//! δ = n−m is  Δ_{i,δ} = Wq,iᵀ Θ_{i,δ} Wk,i; the RoPE-aware loss sums the
//! whitened kernel error over a window of offsets (the paper uses a
//! 10-token window). Each (head, offset) pair becomes one more HOSVD slice,
//! so the same alternating solver applies.

use super::joint_qk::attention_map_loss;
use super::precond::Precond;
use crate::tensor::topk_eigvecs;
use crate::Matrix;

/// Block-diagonal RoPE rotation Θ_δ for head dim d_h (Llama-2 layout,
/// Eq 174/175): pairs (2i, 2i+1) rotated by δ·θ^(−2i/d_h).
pub fn rope_rotation(d_h: usize, delta: f64, theta: f64) -> Matrix {
    let mut m = Matrix::zeros(d_h, d_h);
    for i in 0..d_h / 2 {
        let ang = delta * theta.powf(-2.0 * i as f64 / d_h as f64);
        let (s, c) = ang.sin_cos();
        m[(2 * i, 2 * i)] = c;
        m[(2 * i, 2 * i + 1)] = -s;
        m[(2 * i + 1, 2 * i)] = s;
        m[(2 * i + 1, 2 * i + 1)] = c;
    }
    if d_h % 2 == 1 {
        m[(d_h - 1, d_h - 1)] = 1.0;
    }
    m
}

pub struct RopeQkResult {
    pub aq: Matrix,
    pub ak: Matrix,
    /// loss over the RoPE window per iteration
    pub losses: Vec<f64>,
}

/// RoPE-aware joint QK HOSVD: slices G̃_{i,δ} = (Wq,i P)ᵀ Θ_{i,δ} (Wk,i P)
/// for causal offsets δ ∈ [0, window).
pub fn compress_rope_aware(wq: &Matrix, wk: &Matrix, n_heads: usize,
                           d_h: usize, rq: usize, rk: usize, window: usize,
                           theta: f64, n_iter: usize, kind: Precond,
                           c: &Matrix) -> RopeQkResult {
    let d = wq.cols();
    let (p, _) = kind.build(c, None);
    let mut g = Vec::with_capacity(n_heads * window);
    for i in 0..n_heads {
        let qi = wq.slice_rows(i * d_h, (i + 1) * d_h).matmul(&p);
        let ki = wk.slice_rows(i * d_h, (i + 1) * d_h).matmul(&p);
        for delta in 0..window {
            let rot = rope_rotation(d_h, delta as f64, theta);
            g.push(qi.matmul_at(&rot.matmul(&ki)));
        }
    }
    let mut acc = Matrix::zeros(d, d);
    for gi in &g {
        acc.add_inplace(&gi.matmul_bt(gi));
    }
    let mut aq = topk_eigvecs(&acc, rq);
    let mut acc_k0 = Matrix::zeros(d, d);
    for gi in &g {
        acc_k0.add_inplace(&gi.matmul_at(gi));
    }
    let mut ak = topk_eigvecs(&acc_k0, rk);
    let mut losses = vec![attention_map_loss(&g, &aq, &ak)];
    for _ in 0..n_iter {
        let mut acc_k = Matrix::zeros(d, d);
        for gi in &g {
            let ag = aq.matmul(gi);
            acc_k.add_inplace(&ag.matmul_at(&ag));
        }
        ak = topk_eigvecs(&acc_k, rk);
        let mut acc_q = Matrix::zeros(d, d);
        for gi in &g {
            let ga = ak.matmul(&gi.transpose());
            acc_q.add_inplace(&ga.matmul_at(&ga));
        }
        aq = topk_eigvecs(&acc_q, rq);
        losses.push(attention_map_loss(&g, &aq, &ak));
    }
    RopeQkResult { aq, ak, losses }
}

/// Evaluate an (Aq, Ak) pair under the RoPE-window loss (for comparing the
/// RoPE-blind solution on the RoPE-aware objective — Fig 12's comparison).
pub fn rope_window_loss(wq: &Matrix, wk: &Matrix, n_heads: usize, d_h: usize,
                        aq: &Matrix, ak: &Matrix, window: usize, theta: f64,
                        kind: Precond, c: &Matrix) -> f64 {
    let (p, _) = kind.build(c, None);
    let mut g = Vec::new();
    for i in 0..n_heads {
        let qi = wq.slice_rows(i * d_h, (i + 1) * d_h).matmul(&p);
        let ki = wk.slice_rows(i * d_h, (i + 1) * d_h).matmul(&p);
        for delta in 0..window {
            let rot = rope_rotation(d_h, delta as f64, theta);
            g.push(qi.matmul_at(&rot.matmul(&ki)));
        }
    }
    attention_map_loss(&g, aq, ak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rotation_is_orthogonal_and_composes() {
        let r1 = rope_rotation(8, 1.0, 1e4);
        let r2 = rope_rotation(8, 2.0, 1e4);
        assert!(r1.matmul_bt(&r1).max_abs_diff(&Matrix::eye(8)) < 1e-12);
        // Θ_1 Θ_1 = Θ_2 (relative-position property Θᵀ_m Θ_n = Θ_{n−m})
        assert!(r1.matmul(&r1).max_abs_diff(&r2) < 1e-12);
        // δ=0 is identity
        assert!(rope_rotation(8, 0.0, 1e4).max_abs_diff(&Matrix::eye(8))
                < 1e-12);
    }

    #[test]
    fn rope_aware_beats_rope_blind_on_rope_loss(// Fig 12
    ) {
        let mut rng = Rng::new(95);
        let (d, dh, h) = (24usize, 6usize, 4usize);
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        let c = Matrix::eye(d);
        let (rq, rk) = (10, 10);
        let aware = compress_rope_aware(&wq, &wk, h, dh, rq, rk, 10, 1e4, 6,
                                        Precond::Identity, &c);
        // rope-blind: plain joint QK (δ=0 only), then evaluate on the window
        let blind = compress_rope_aware(&wq, &wk, h, dh, rq, rk, 1, 1e4, 6,
                                        Precond::Identity, &c);
        let blind_on_window = rope_window_loss(&wq, &wk, h, dh, &blind.aq,
                                               &blind.ak, 10, 1e4,
                                               Precond::Identity, &c);
        let aware_loss = *aware.losses.last().unwrap();
        assert!(aware_loss <= blind_on_window * (1.0 + 1e-9),
                "aware {aware_loss} vs blind {blind_on_window}");
    }

    #[test]
    fn losses_monotone() {
        let mut rng = Rng::new(96);
        let (d, dh, h) = (16usize, 4usize, 4usize);
        let wq = rng.normal_matrix(d, d);
        let wk = rng.normal_matrix(d, d);
        let res = compress_rope_aware(&wq, &wk, h, dh, 6, 6, 5, 1e4, 5,
                                      Precond::Identity, &Matrix::eye(d));
        for w in res.losses.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9));
        }
    }
}
