//! Sparse and low-rank+sparse approximation (paper App I):
//! FISTA soft-shrink (Eqs 233–235), projected-GD hard top-κ (the STE
//! variant, Eq 237), WandA-style diagonal one-shot (Eq 238), alternating
//! low-rank+sparse, and factor sparsification — backing Figs 11/13/14/15/16.
//!
//! The whole-model path reaches these through the `sparse` post-stage of
//! [`super::plan`] (`PostOp::Sparse` runs [`projected_gd`] on each
//! module's low-rank residual).

use super::asvd::{self, AsvdOpts};
use super::junction::Junction;
use super::precond::Precond;
use crate::tensor::eig::eigh;
use crate::tensor::linalg::act_loss;
use crate::Matrix;

/// Keep the κ entries of largest magnitude (global), zero the rest.
pub fn hard_topk(m: &Matrix, k: usize) -> Matrix {
    let n = m.data().len();
    if k == 0 {
        return Matrix::zeros(m.rows(), m.cols());
    }
    if k >= n {
        return m.clone();
    }
    let mut mags: Vec<f64> = m.data().iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| b.total_cmp(a));
    let thresh = mags[k - 1];
    let mut out = m.clone();
    let mut kept = 0usize;
    for v in out.data_mut() {
        if v.abs() >= thresh && kept < k {
            kept += 1;
        } else {
            *v = 0.0;
        }
    }
    out
}

pub fn soft_shrink(m: &Matrix, alpha: f64) -> Matrix {
    let mut out = m.clone();
    for v in out.data_mut() {
        *v = v.signum() * (v.abs() - alpha).max(0.0);
    }
    out
}

pub fn nnz(m: &Matrix) -> usize {
    m.data().iter().filter(|&&v| v != 0.0).count()
}

fn lmax(c: &Matrix) -> f64 {
    let (w, _) = eigh(c);
    w.last().copied().unwrap_or(0.0).max(1e-12)
}

/// FISTA soft-shrink (Eq 232–235) with λ bisection toward target κ.
/// Returns (D, loss).
pub fn fista(w: &Matrix, c: &Matrix, kappa: usize, n_iter: usize)
             -> (Matrix, f64) {
    let step = 1.0 / (2.0 * lmax(c));
    let run = |lam: f64| -> Matrix {
        let mut d = Matrix::zeros(w.rows(), w.cols());
        let mut yk = d.clone();
        let mut t = 1.0f64;
        for _ in 0..n_iter {
            let grad = yk.sub(w).matmul(c).scale(2.0);
            let d_new = soft_shrink(&yk.sub(&grad.scale(step)), lam * step);
            let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            yk = d_new.add(&d_new.sub(&d).scale((t - 1.0) / t_new));
            d = d_new;
            t = t_new;
        }
        d
    };
    let gmax = w.matmul(c).scale(2.0).data().iter()
        .map(|v| v.abs()).fold(0.0, f64::max) + 1e-9;
    let (mut lo, mut hi) = (1e-8f64, gmax);
    for _ in 0..12 {
        let mid = (lo * hi).sqrt();
        if nnz(&run(mid)) > kappa {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let d = run(hi);
    let loss = act_loss(w, &d, c);
    (d, loss)
}

/// Projected gradient with hard top-κ projection — the STE variant
/// (Eq 237): deterministic target sparsity.
pub fn projected_gd(w: &Matrix, c: &Matrix, kappa: usize, n_iter: usize)
                    -> (Matrix, f64) {
    let step = 1.0 / (2.0 * lmax(c));
    let mut d = hard_topk(w, kappa);
    for _ in 0..n_iter {
        let grad = d.sub(w).matmul(c).scale(2.0);
        d = hard_topk(&d.sub(&grad.scale(step)), kappa);
    }
    (d.clone(), act_loss(w, &d, c))
}

/// WandA/SparseGPT-style one-shot with diagonal C only (Eq 238, Fig 16).
pub fn wanda_diag(w: &Matrix, c: &Matrix, kappa: usize) -> (Matrix, f64) {
    let imp = Matrix::from_fn(w.rows(), w.cols(), |i, j| {
        w[(i, j)].abs() * c[(j, j)].max(0.0).sqrt()
    });
    let mask = hard_topk(&imp, kappa);
    let d = Matrix::from_fn(w.rows(), w.cols(), |i, j| {
        if mask[(i, j)] != 0.0 { w[(i, j)] } else { 0.0 }
    });
    let loss = act_loss(w, &d, c);
    (d, loss)
}

/// Alternating low-rank + sparse (App I, Fig 14): svd_r[(W−D)P] ↔ sparse
/// fit of (W−BA). Returns (BA, D, per-round losses).
pub fn lowrank_plus_sparse(w: &Matrix, c: &Matrix, rank: usize, kappa: usize,
                           rounds: usize) -> (Matrix, Matrix, Vec<f64>) {
    let mut d = Matrix::zeros(w.rows(), w.cols());
    let mut ba = Matrix::zeros(w.rows(), w.cols());
    let mut hist = Vec::new();
    let opts = AsvdOpts { kind: Precond::RootCov, junction: Junction::Left,
                          ..Default::default() };
    for _ in 0..rounds {
        let res = asvd::compress_with_cov(&w.sub(&d), rank, c,
                                          &vec![0.0; w.cols()], &opts);
        ba = res.w_hat;
        let (d_new, _) = projected_gd(&w.sub(&ba), c, kappa, 30);
        d = d_new;
        hist.push(act_loss(w, &ba.add(&d), c));
    }
    (ba, d, hist)
}

/// Fig 15: hard-sparsify the low-rank factors themselves with alternating
/// projected refits against the activation loss.
pub fn sparsify_factors(b0: &Matrix, a0: &Matrix, w: &Matrix, c: &Matrix,
                        keep_frac: f64, n_iter: usize)
                        -> (Matrix, Matrix, Vec<f64>) {
    let mut b = b0.clone();
    let mut a = a0.clone();
    let kb = ((keep_frac * b.data().len() as f64) as usize).max(1);
    let ka = ((keep_frac * a.data().len() as f64) as usize).max(1);
    let lc = lmax(c);
    let mut hist = Vec::new();
    for _ in 0..n_iter {
        let e = b.matmul(&a).sub(w).matmul(c);
        let gb = e.matmul_bt(&a).scale(2.0);
        let ga = b.matmul_at(&e).scale(2.0);
        let lb = 2.0 * lc * a.frob2().max(1e-12);
        let la = 2.0 * lc * b.frob2().max(1e-12);
        b = hard_topk(&b.sub(&gb.scale(1.0 / lb)), kb);
        a = hard_topk(&a.sub(&ga.scale(1.0 / la)), ka);
        hist.push(act_loss(w, &b.matmul(&a), c));
    }
    (b, a, hist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{decaying_covariance, wishart, Rng};

    fn problem(seed: u64, d: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_matrix(d, d);
        let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
        (w, c)
    }

    #[test]
    fn hard_topk_exact_sparsity() {
        let (w, _) = problem(80, 10);
        for k in [0usize, 5, 37, 100] {
            let d = hard_topk(&w, k);
            assert_eq!(nnz(&d), k.min(100));
        }
    }

    #[test]
    fn projected_gd_hits_target_and_beats_oneshot() {
        let (w, c) = problem(81, 12);
        let kappa = 50;
        let (d, loss) = projected_gd(&w, &c, kappa, 60);
        assert!(nnz(&d) <= kappa);
        // iterative with full C beats magnitude one-shot with diag C (Fig 16)
        let (_, wanda_loss) = wanda_diag(&w, &c, kappa);
        assert!(loss <= wanda_loss * (1.0 + 1e-9),
                "pgd {loss} vs wanda {wanda_loss}");
    }

    #[test]
    fn fista_near_target_sparsity() {
        let (w, c) = problem(82, 10);
        let kappa = 40;
        let (d, _) = fista(&w, &c, kappa, 40);
        let got = nnz(&d);
        assert!(got <= kappa + 12, "nnz {got} vs κ {kappa}");
        assert!(got > 0);
    }

    #[test]
    fn sparse_beats_lowrank_at_equal_budget(// Fig 11's headline finding
    ) {
        let (w, c) = problem(83, 16);
        // budget: rank-4 factors of a 16x16 = 4*(16+16) = 128 params
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::Left,
                              ..Default::default() };
        let lr = asvd::compress_with_cov(&w, 4, &c, &vec![0.0; 16], &opts);
        let (_, sp_loss) = projected_gd(&w, &c, 128, 60);
        assert!(sp_loss <= lr.loss * (1.0 + 1e-9),
                "sparse {sp_loss} vs low-rank {}", lr.loss);
    }

    #[test]
    fn lowrank_plus_sparse_improves_over_rounds() {
        let (w, c) = problem(84, 12);
        let (_, _, hist) = lowrank_plus_sparse(&w, &c, 3, 30, 4);
        assert!(hist.last().unwrap() <= &(hist[0] * (1.0 + 1e-9)),
                "{hist:?}");
    }

    #[test]
    fn sparsify_factors_runs_and_reports() {
        let (w, c) = problem(85, 10);
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::Left,
                              ..Default::default() };
        let lr = asvd::compress_with_cov(&w, 6, &c, &vec![0.0; 10], &opts);
        let (b, a, hist) = sparsify_factors(&lr.factors.b, &lr.factors.a,
                                            &w, &c, 0.6, 25);
        assert!(nnz(&b) <= (0.6 * 60.0) as usize + 1);
        assert!(nnz(&a) <= (0.6 * 60.0) as usize + 1);
        assert_eq!(hist.len(), 25);
    }
}
