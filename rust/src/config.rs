//! Deployment configuration: TOML files → typed configs for the serving
//! coordinator and report runner (the launcher's `--config` path).
//!
//! Example (configs/serve.toml):
//! ```toml
//! [serve]
//! model = "opt-mini-m"
//! policy = "cache_aware"
//! max_batch = 8
//! max_wait_ms = 5
//! kv_budget_mb = 8
//! latent_ratio = 0.3
//! workers = 2
//! sched = true          # continuous-batching scheduler (default on)
//! sched_live = 8        # live decode sessions per worker
//! sched_block = 4       # KV page size in tokens (nominal rate)
//! sched_chunk = 16      # prefill tokens fed per scheduler iteration
//! prefix_cache = true   # content-addressed prefix reuse (default on)
//! fused_step = true     # fused multi-sequence decode step (default on)
//! trace = true          # per-request lifecycle traces (default on)
//! profile_layers = false  # per-layer phase histograms (opt-in)
//! [report]
//! max_batches = 12
//! qk_iters = 8
//! ud_iters = 4
//! [compress]            # plan for serve's in-process latent variant —
//! attn = "attn_latent"  # same schema as `latentllm compress --plan`
//! mlp = "mlp_joint_ud"  # (see compress::plan), section optional
//! qk_iters = 4
//! ud_iters = 2
//! [http]                # HTTP/1.1 front door (off unless addr is set
//! addr = "127.0.0.1:8080"  # or `serve --http ADDR` overrides it)
//! threads = 4
//! max_inflight = 64
//! max_queue_depth = 1024
//! retry_after_s = 1
//! ```

use std::time::Duration;

use anyhow::{Context, Result};

use crate::compress::plan::CompressionPlan;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::http::HttpConfig;
use crate::coordinator::router::Policy;
use crate::coordinator::scheduler::SchedulerConfig;
use crate::util::toml::{self, Table};

#[derive(Clone, Debug, PartialEq)]
pub struct ServeSettings {
    pub model: String,
    pub policy: Policy,
    pub batcher: BatcherConfig,
    pub kv_budget_bytes: usize,
    pub latent_ratio: f64,
    pub program_batch: usize,
    pub seq_len: usize,
    /// server worker threads, each with its own engine ([serve] workers)
    pub workers: usize,
    /// continuous-batching scheduler for generate traffic ([serve]
    /// sched = false falls back to sequential sessions); the knobs
    /// mirror `--sched-live/--sched-block/--sched-chunk`
    pub sched: bool,
    pub scheduler: SchedulerConfig,
    /// content-addressed prefix cache over the paged KV pool ([serve]
    /// prefix_cache = false, or `serve --no-prefix-cache`, disables
    /// block sharing; freed prefix blocks then return straight to the
    /// free list instead of the cached-free LRU)
    pub prefix_cache: bool,
    /// request-scoped lifecycle traces ([serve] trace = false, or
    /// `serve --no-trace`, turns them off): timings on every response
    /// and span chains on `GET /debug/requests`
    pub trace: bool,
    /// per-layer phase profiling into labeled histograms ([serve]
    /// profile_layers = true, or `serve --profile-layers`); off by
    /// default — the hooks clock every layer phase
    pub profile_layers: bool,
}

impl Default for ServeSettings {
    fn default() -> Self {
        ServeSettings {
            model: "opt-mini-m".into(),
            policy: Policy::CacheAware,
            batcher: BatcherConfig::default(),
            kv_budget_bytes: 8 << 20,
            latent_ratio: 0.3,
            program_batch: 8,
            seq_len: 128,
            workers: 2,
            sched: true,
            scheduler: SchedulerConfig::default(),
            prefix_cache: true,
            trace: true,
            profile_layers: false,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ReportSettings {
    pub max_batches: usize,
    pub qk_iters: usize,
    pub ud_iters: usize,
}

impl Default for ReportSettings {
    fn default() -> Self {
        ReportSettings { max_batches: 12, qk_iters: 8, ud_iters: 4 }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub serve: ServeSettings,
    pub report: ReportSettings,
    /// `[compress]` — the plan used when serving builds its in-process
    /// latent variant (ratio comes from `serve.latent_ratio`). Defaults
    /// to the LatentLLM preset at light iteration budgets (4/2) so
    /// startup stays fast.
    pub compress: CompressionPlan,
    /// `[http]` — the HTTP/1.1 front door. An empty `addr` (the config
    /// default) leaves the listener off; `serve --http ADDR` overrides.
    pub http: HttpConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            serve: ServeSettings::default(),
            report: ReportSettings::default(),
            compress: CompressionPlan::default().with_iters(4, 2),
            http: HttpConfig { addr: String::new(),
                               ..HttpConfig::default() },
        }
    }
}

fn policy_from_str(s: &str) -> Option<Policy> {
    match s {
        "rr" | "round_robin" => Some(Policy::RoundRobin),
        "prefer_latent" => Some(Policy::PreferLatent),
        "cache_aware" => Some(Policy::CacheAware),
        _ => None,
    }
}

impl Config {
    pub fn from_table(t: &Table) -> Result<Config> {
        let mut cfg = Config::default();
        let get_usize = |key: &str, default: usize| -> usize {
            t.get(key).and_then(|v| v.as_i64()).map(|v| v as usize)
                .unwrap_or(default)
        };
        if let Some(v) = t.get("serve.model").and_then(|v| v.as_str()) {
            cfg.serve.model = v.to_string();
        }
        if let Some(v) = t.get("serve.policy").and_then(|v| v.as_str()) {
            cfg.serve.policy = policy_from_str(v)
                .with_context(|| format!("unknown policy {v:?}"))?;
        }
        cfg.serve.batcher.max_batch =
            get_usize("serve.max_batch", cfg.serve.batcher.max_batch);
        if let Some(ms) = t.get("serve.max_wait_ms").and_then(|v| v.as_f64())
        {
            cfg.serve.batcher.max_wait = Duration::from_micros(
                (ms * 1000.0) as u64);
        }
        cfg.serve.kv_budget_bytes =
            get_usize("serve.kv_budget_mb",
                      cfg.serve.kv_budget_bytes >> 20) << 20;
        if let Some(r) = t.get("serve.latent_ratio").and_then(|v| v.as_f64())
        {
            anyhow::ensure!((0.0..1.0).contains(&r),
                            "latent_ratio must be in [0,1)");
            cfg.serve.latent_ratio = r;
        }
        cfg.serve.program_batch =
            get_usize("serve.program_batch", cfg.serve.program_batch);
        cfg.serve.seq_len = get_usize("serve.seq_len", cfg.serve.seq_len);
        cfg.serve.workers =
            get_usize("serve.workers", cfg.serve.workers).max(1);
        if let Some(b) = t.get("serve.sched").and_then(|v| v.as_bool()) {
            cfg.serve.sched = b;
        }
        cfg.serve.scheduler.max_live =
            get_usize("serve.sched_live",
                      cfg.serve.scheduler.max_live).max(1);
        cfg.serve.scheduler.block_tokens =
            get_usize("serve.sched_block",
                      cfg.serve.scheduler.block_tokens).max(1);
        cfg.serve.scheduler.prefill_chunk =
            get_usize("serve.sched_chunk",
                      cfg.serve.scheduler.prefill_chunk).max(1);
        if let Some(b) = t.get("serve.fused_step").and_then(|v| v.as_bool())
        {
            cfg.serve.scheduler.fused = b;
        }
        if let Some(b) = t.get("serve.prefix_cache").and_then(|v| v.as_bool())
        {
            cfg.serve.prefix_cache = b;
        }
        if let Some(b) = t.get("serve.trace").and_then(|v| v.as_bool()) {
            cfg.serve.trace = b;
        }
        if let Some(b) = t.get("serve.profile_layers")
            .and_then(|v| v.as_bool()) {
            cfg.serve.profile_layers = b;
        }
        if let Some(v) = t.get("http.addr").and_then(|v| v.as_str()) {
            cfg.http.addr = v.to_string();
        }
        cfg.http.threads =
            get_usize("http.threads", cfg.http.threads).max(1);
        cfg.http.max_inflight =
            get_usize("http.max_inflight", cfg.http.max_inflight).max(1);
        if let Some(v) = t.get("http.max_queue_depth")
            .and_then(|v| v.as_i64()) {
            cfg.http.max_queue_depth = v.max(0);
        }
        if let Some(v) = t.get("http.retry_after_s")
            .and_then(|v| v.as_i64()) {
            cfg.http.retry_after_secs = v.max(0) as u64;
        }
        cfg.report.max_batches =
            get_usize("report.max_batches", cfg.report.max_batches);
        cfg.report.qk_iters = get_usize("report.qk_iters",
                                        cfg.report.qk_iters);
        cfg.report.ud_iters = get_usize("report.ud_iters",
                                        cfg.report.ud_iters);
        cfg.compress = CompressionPlan::from_table_with(
            t, "compress", cfg.compress.clone())?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        Config::from_table(&toml::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let t = toml::parse(
            "[serve]\nmodel = \"opt-mini-l\"\npolicy = \"prefer_latent\"\n\
             max_batch = 16\nmax_wait_ms = 2.5\nkv_budget_mb = 32\n\
             latent_ratio = 0.4\n[report]\nmax_batches = 6\n").unwrap();
        let c = Config::from_table(&t).unwrap();
        assert_eq!(c.serve.model, "opt-mini-l");
        assert_eq!(c.serve.policy, Policy::PreferLatent);
        assert_eq!(c.serve.batcher.max_batch, 16);
        assert_eq!(c.serve.batcher.max_wait, Duration::from_micros(2500));
        assert_eq!(c.serve.kv_budget_bytes, 32 << 20);
        assert_eq!(c.serve.latent_ratio, 0.4);
        assert_eq!(c.report.max_batches, 6);
        assert_eq!(c.report.qk_iters, 8); // default survives
    }

    #[test]
    fn defaults_when_empty() {
        let c = Config::from_table(&Table::new()).unwrap();
        assert_eq!(c, Config::default());
    }

    #[test]
    fn rejects_bad_values() {
        let t = toml::parse("[serve]\npolicy = \"nope\"\n").unwrap();
        assert!(Config::from_table(&t).is_err());
        let t = toml::parse("[serve]\nlatent_ratio = 1.5\n").unwrap();
        assert!(Config::from_table(&t).is_err());
        let t = toml::parse("[compress]\nprecond = \"nope\"\n").unwrap();
        assert!(Config::from_table(&t).is_err());
    }

    #[test]
    fn parses_scheduler_knobs() {
        let t = toml::parse(
            "[serve]\nsched = false\nsched_live = 12\nsched_block = 8\n\
             sched_chunk = 32\nprefix_cache = false\n\
             fused_step = false\ntrace = false\n\
             profile_layers = true\n").unwrap();
        let c = Config::from_table(&t).unwrap();
        assert!(!c.serve.sched);
        assert!(!c.serve.prefix_cache);
        assert!(!c.serve.trace);
        assert!(c.serve.profile_layers);
        assert_eq!(c.serve.scheduler.max_live, 12);
        assert_eq!(c.serve.scheduler.block_tokens, 8);
        assert_eq!(c.serve.scheduler.prefill_chunk, 32);
        assert!(!c.serve.scheduler.fused);
        // defaults: scheduler on at the SchedulerConfig defaults,
        // tracing on, layer profiling off
        let d = Config::from_table(&Table::new()).unwrap();
        assert!(d.serve.sched);
        assert_eq!(d.serve.scheduler, SchedulerConfig::default());
        assert!(d.serve.trace);
        assert!(!d.serve.profile_layers);
    }

    #[test]
    fn parses_http_section() {
        let t = toml::parse(
            "[http]\naddr = \"127.0.0.1:8080\"\nthreads = 2\n\
             max_inflight = 7\nmax_queue_depth = 3\nretry_after_s = 5\n")
            .unwrap();
        let c = Config::from_table(&t).unwrap();
        assert_eq!(c.http.addr, "127.0.0.1:8080");
        assert_eq!(c.http.threads, 2);
        assert_eq!(c.http.max_inflight, 7);
        assert_eq!(c.http.max_queue_depth, 3);
        assert_eq!(c.http.retry_after_secs, 5);
        // the front door stays off until an address is configured
        let d = Config::from_table(&Table::new()).unwrap();
        assert!(d.http.addr.is_empty());
    }

    #[test]
    fn parses_compress_section() {
        let t = toml::parse(
            "[compress]\nattn = \"attn_local\"\nmlp = \"mlp_local\"\n\
             precond = \"cov\"\njunction = \"left\"\nqk_iters = 6\n")
            .unwrap();
        let c = Config::from_table(&t).unwrap();
        assert_eq!(c.compress.attn, "attn_local");
        assert_eq!(c.compress.mlp, "mlp_local");
        assert_eq!(c.compress.precond, crate::compress::Precond::Cov);
        assert_eq!(c.compress.qk_iters, 6);
        assert_eq!(c.compress.ud_iters, 2,
                   "serve default iteration budget survives");
    }
}
