//! Dynamic batcher: accumulates requests until `max_batch` or `max_wait`,
//! then flushes — the standard continuous-batching front half. Pure data
//! structure (the server thread drives the clock), so it is exhaustively
//! testable without timers.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
    pub flushes: u64,
    pub full_flushes: u64,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, queue: VecDeque::new(), flushes: 0, full_flushes: 0 }
    }

    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back(Pending { item, enqueued: now });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a flush should happen at `now`.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(p) => now.duration_since(p.enqueued) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Deadline at which the oldest pending request forces a flush.
    pub fn deadline(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.enqueued + self.cfg.max_wait)
    }

    /// Take up to max_batch requests (FIFO). Never returns an empty vec
    /// unless the queue is empty.
    pub fn flush(&mut self, now: Instant) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.cfg.max_batch);
        if n == 0 {
            return Vec::new();
        }
        self.flushes += 1;
        if n == self.cfg.max_batch {
            self.full_flushes += 1;
        }
        let _ = now;
        self.queue.drain(..n).collect()
    }

    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 3, max_wait: Duration::from_secs(100),
        });
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        assert!(!b.ready(now));
        b.push(3, now);
        assert!(b.ready(now));
        let batch = b.flush(now);
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
        assert_eq!(b.full_flushes, 1);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100, max_wait: Duration::from_millis(5),
        });
        let now = t0();
        b.push("a", now);
        assert!(!b.ready(now));
        let later = now + Duration::from_millis(6);
        assert!(b.ready(later));
        assert_eq!(b.flush(later).len(), 1);
    }

    #[test]
    fn fifo_order_and_partial_flush() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2, max_wait: Duration::from_millis(0),
        });
        let now = t0();
        for i in 0..5 {
            b.push(i, now);
        }
        let batch1 = b.flush(now);
        assert_eq!(batch1.iter().map(|p| p.item).collect::<Vec<_>>(),
                   vec![0, 1]);
        assert_eq!(b.flush(now).len(), 2);
        assert_eq!(b.flush(now).len(), 1);
        assert_eq!(b.flush(now).len(), 0);
        assert_eq!(b.flushes, 3);
    }

    #[test]
    fn never_exceeds_max_batch_property() {
        use crate::util::prop::run_cases;
        run_cases("batcher-max", 50, 0xbb, |rng, _| {
            let max_batch = 1 + rng.below(16);
            let mut b = Batcher::new(BatcherConfig {
                max_batch, max_wait: Duration::from_millis(1),
            });
            let now = t0();
            let n = rng.below(100);
            for i in 0..n {
                b.push(i, now);
            }
            let mut total = 0;
            loop {
                let batch = b.flush(now);
                if batch.is_empty() {
                    break;
                }
                if batch.len() > max_batch {
                    return Err(format!("batch {} > {}", batch.len(),
                                       max_batch));
                }
                total += batch.len();
            }
            if total != n {
                return Err(format!("lost requests: {total} != {n}"));
            }
            Ok(())
        });
    }
}
