//! std-only HTTP/1.1 front door for the coordinator: OpenAI-style
//! endpoints over the typed [`super::server`] API, hand-rolled on
//! `std::net` (tokio/hyper are unavailable offline) with the crate's
//! own JSON substrate (`util::json`) for bodies.
//!
//! | Endpoint               | Method | Purpose                         |
//! |------------------------|--------|---------------------------------|
//! | `/v1/completions`      | POST   | generate; `"stream": true` emits|
//! |                        |        | tokens as decode steps retire   |
//! | `/v1/score`            | POST   | sequence NLL through the batcher|
//! | `/healthz`             | GET    | liveness + worker count         |
//! | `/metrics`             | GET    | Prometheus text exposition      |
//! | `/debug/requests?n=K`  | GET    | last K completed request traces |
//! |                        |        | (span chains + timings)         |
//! | `/admin/shutdown`      | POST   | SIGTERM-equivalent: stop        |
//! |                        |        | accepting, drain, exit `wait()` |
//!
//! **Threading.** A pool of [`HttpConfig::threads`] workers shares one
//! nonblocking listener; each worker serves one connection at a time,
//! serially (keep-alive honored). That makes graceful drain exactly
//! "join the pool": when a shutdown is requested the workers stop
//! accepting, finish the request (or token stream) they are writing,
//! and exit — in-flight work is never cut off, which the drain test
//! pins as zero lost requests.
//!
//! **Backpressure.** Two knobs: [`HttpConfig::max_inflight`] bounds
//! concurrently-processed requests (excess gets `503` + `Retry-After`),
//! and [`HttpConfig::max_queue_depth`] turns the server's
//! `gen_queue_depth` level gauge into a `429 Too Many Requests` +
//! `Retry-After` for new completions once the decode queue is that
//! deep.
//!
//! **Streaming wire format.** `"stream": true` switches the response to
//! `Transfer-Encoding: chunked` with `text/event-stream` framing: one
//! `data: {"token": N}` event per decoded token (exactly the order and
//! values of the in-process decode — the sender fires at the sampling
//! site, once per token even across preemptions), a terminal
//! `data: {"done": true, ...}` event carrying the id/variant (or the
//! error), and a final `data: [DONE]` sentinel.
//!
//! Errors map [`ServeError`] onto status codes: `Empty`/`TooLong` → 400,
//! `Rejected`/`Evicted`/`EngineInit` → 503, `Internal` → 500, with a
//! JSON body `{"error": {"type": ..., "message": ...}}`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::server::{GenerateParams, ScoreParams, ServeError, Server};
use crate::util::json::{self, Value};

/// Coordinator reply deadline before the listener answers 504 — far
/// above any test decode, small enough that a wedged worker cannot pin
/// a connection forever.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);
/// Per-read socket timeout: the granularity at which idle keep-alive
/// connections notice a drain request.
const READ_TICK: Duration = Duration::from_millis(200);
const MAX_BODY_BYTES: usize = 8 << 20;
const MAX_HEADERS: usize = 100;

/// Listener knobs (`[http]` in the serve config, `serve --http ADDR`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpConfig {
    /// bind address; port 0 picks an ephemeral port (see
    /// [`HttpServer::local_addr`])
    pub addr: String,
    /// connection-handling worker threads — also the max number of
    /// concurrently-open connections (excess waits in the OS backlog)
    pub threads: usize,
    /// max concurrently-processed requests across the pool; beyond it
    /// new requests get 503 + Retry-After
    pub max_inflight: usize,
    /// new completions get 429 + Retry-After once the server's
    /// `gen_queue_depth` gauge reaches this (0 rejects all generates)
    pub max_queue_depth: i64,
    /// value of the `Retry-After` header on 429/503 backpressure
    pub retry_after_secs: u64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            max_inflight: 64,
            max_queue_depth: 1024,
            retry_after_secs: 1,
        }
    }
}

struct Ctx {
    server: Arc<Server>,
    cfg: HttpConfig,
    /// stop accepting new connections/requests (drain in progress)
    stop: AtomicBool,
    /// a client asked for shutdown via `/admin/shutdown`
    shutdown_req: AtomicBool,
    inflight: AtomicUsize,
}

/// RAII slot in the bounded in-flight set.
struct InflightGuard(Arc<Ctx>);

impl InflightGuard {
    fn try_acquire(ctx: &Arc<Ctx>) -> Option<InflightGuard> {
        let n = ctx.inflight.fetch_add(1, Ordering::SeqCst);
        if n >= ctx.cfg.max_inflight.max(1) {
            ctx.inflight.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        Some(InflightGuard(ctx.clone()))
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The running listener. Dropping it drains: stop accepting, finish
/// in-flight requests, join the worker pool.
pub struct HttpServer {
    addr: SocketAddr,
    ctx: Arc<Ctx>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `cfg.addr` and start the worker pool. The coordinator
    /// [`Server`] is shared — the in-process API keeps working next to
    /// the listener.
    pub fn start(server: Arc<Server>, cfg: HttpConfig)
                 -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind http listener {}", cfg.addr))?;
        listener.set_nonblocking(true)
            .context("nonblocking http listener")?;
        let addr = listener.local_addr().context("http local addr")?;
        let listener = Arc::new(listener);
        let ctx = Arc::new(Ctx {
            server,
            cfg: cfg.clone(),
            stop: AtomicBool::new(false),
            shutdown_req: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
        });
        let mut workers = Vec::new();
        for i in 0..cfg.threads.max(1) {
            let listener = listener.clone();
            let ctx = ctx.clone();
            workers.push(std::thread::Builder::new()
                .name(format!("latentllm-http-{i}"))
                .spawn(move || accept_loop(&listener, &ctx))
                .expect("spawn http worker"));
        }
        Ok(HttpServer { addr, ctx, workers })
    }

    /// The bound address — the real port when `addr` asked for port 0.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Has a client requested shutdown (`POST /admin/shutdown`)?
    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown_req.load(Ordering::SeqCst)
    }

    /// Block until a client requests shutdown, then drain gracefully:
    /// stop accepting, let every in-flight request/stream finish, join
    /// the pool. The SIGTERM-equivalent serve loop (std cannot trap
    /// signals portably).
    pub fn wait(mut self) {
        while !self.ctx.shutdown_req.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.drain();
    }

    /// Programmatic graceful shutdown (same drain as [`Self::wait`]).
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.drain();
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock
                       | std::io::ErrorKind::TimedOut)
}

fn accept_loop(listener: &TcpListener, ctx: &Arc<Ctx>) {
    while !ctx.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                ctx.server.metrics.incr("http_conns", 1);
                if let Err(e) = handle_conn(ctx, stream) {
                    ctx.server.metrics.incr("http_conn_errors", 1);
                    eprintln!("[http] connection error: {e:#}");
                }
            }
            Err(ref e) if would_block(e) => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                eprintln!("[http] accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Serve one connection until the client closes, asks to close, or a
/// drain begins (the request being handled always completes first).
fn handle_conn(ctx: &Arc<Ctx>, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_TICK))
        .context("set read timeout")?;
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    loop {
        let req = match read_request(&mut reader, ctx) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()),
            Err(e) => {
                // malformed framing: answer 400 and drop the connection
                let _ = respond_error(ctx, &mut writer, 400,
                                      "bad_request", &format!("{e:#}"),
                                      false, &[]);
                return Ok(());
            }
        };
        // once draining, answer this request and then close
        let keep = !ctx.stop.load(Ordering::SeqCst)
            && !req.header_is("connection", "close");
        let keep = handle_request(ctx, &mut writer, req, keep)?;
        if !keep {
            return Ok(());
        }
    }
}

struct HttpRequest {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl HttpRequest {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn header_is(&self, name: &str, value: &str) -> bool {
        self.header(name)
            .is_some_and(|v| v.eq_ignore_ascii_case(value))
    }
}

/// Read one line, tolerating up to `max_ticks` read-timeout ticks
/// (idle keep-alive waits run through this with a large budget).
fn read_line_retry(reader: &mut BufReader<TcpStream>, max_ticks: usize)
                   -> Result<Option<String>> {
    let mut line = String::new();
    let mut ticks = 0;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None); // clean EOF
                }
                bail!("connection closed mid line");
            }
            Ok(_) => {
                if line.ends_with('\n') {
                    return Ok(Some(line));
                }
                bail!("truncated line");
            }
            Err(ref e) if would_block(e) => {
                ticks += 1;
                if ticks > max_ticks {
                    bail!("timed out reading");
                }
            }
            Err(e) => return Err(e).context("read line"),
        }
    }
}

/// Parse one request off the connection. `Ok(None)` means the client
/// closed (or the server is draining and the connection is idle).
fn read_request(reader: &mut BufReader<TcpStream>, ctx: &Ctx)
                -> Result<Option<HttpRequest>> {
    // wait for the request line; an idle wait ends quietly on drain,
    // and a half-sent line gets the same tick budget as the rest of
    // the request (a stalled client must not pin a worker)
    let budget = (REQUEST_TIMEOUT.as_millis()
                  / READ_TICK.as_millis().max(1)) as usize;
    let mut line = String::new();
    let mut ticks = 0;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid request line");
            }
            Ok(_) => {
                if line.ends_with('\n') {
                    break;
                }
                bail!("truncated request line");
            }
            Err(ref e) if would_block(e) => {
                if line.is_empty() {
                    if ctx.stop.load(Ordering::SeqCst) {
                        return Ok(None);
                    }
                } else {
                    ticks += 1;
                    if ticks > budget {
                        bail!("timed out reading the request line");
                    }
                }
            }
            Err(e) => return Err(e).context("read request line"),
        }
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty()
        || !version.starts_with("HTTP/1") {
        bail!("malformed request line {line:?}");
    }
    // headers (bounded; the whole request must keep arriving)
    let mut headers = Vec::new();
    loop {
        let Some(h) = read_line_retry(reader, budget)? else {
            bail!("connection closed mid headers");
        };
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            bail!("too many headers");
        }
        let (name, value) = h.split_once(':')
            .ok_or_else(|| anyhow!("malformed header {h:?}"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    let req = HttpRequest { method, path, headers, body: Vec::new() };
    let len: usize = match req.header("content-length") {
        Some(v) => v.trim().parse()
            .map_err(|_| anyhow!("bad content-length {v:?}"))?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        bail!("body of {len} bytes exceeds the {MAX_BODY_BYTES} limit");
    }
    let mut body = vec![0u8; len];
    let mut filled = 0;
    let mut ticks = 0;
    while filled < len {
        match reader.read(&mut body[filled..]) {
            Ok(0) => bail!("connection closed mid body"),
            Ok(n) => {
                filled += n;
                ticks = 0;
            }
            Err(ref e) if would_block(e) => {
                ticks += 1;
                if ticks > budget {
                    bail!("timed out reading body");
                }
            }
            Err(e) => return Err(e).context("read body"),
        }
    }
    Ok(Some(HttpRequest { body, ..req }))
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn status_class(ctx: &Ctx, status: u16) {
    ctx.server.metrics.incr("http_requests", 1);
    let class = match status {
        200..=299 => "http_2xx",
        400..=499 => "http_4xx",
        _ => "http_5xx",
    };
    ctx.server.metrics.incr(class, 1);
}

/// Write one fixed-length response (and account it in the metrics).
fn respond_raw(ctx: &Ctx, w: &mut TcpStream, status: u16, ctype: &str,
               body: &[u8], keep: bool, extra: &[(&str, String)])
               -> Result<()> {
    status_class(ctx, status);
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\n", reason_phrase(status), body.len());
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(if keep {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    w.write_all(head.as_bytes()).context("write head")?;
    w.write_all(body).context("write body")?;
    w.flush().context("flush response")
}

fn respond_json(ctx: &Ctx, w: &mut TcpStream, status: u16, body: &Value,
                keep: bool, extra: &[(&str, String)]) -> Result<()> {
    let mut text = body.to_string_compact();
    text.push('\n');
    respond_raw(ctx, w, status, "application/json", text.as_bytes(),
                keep, extra)
}

fn respond_error(ctx: &Ctx, w: &mut TcpStream, status: u16, kind: &str,
                 message: &str, keep: bool, extra: &[(&str, String)])
                 -> Result<()> {
    let body = Value::obj(vec![("error", Value::obj(vec![
        ("type", kind.into()),
        ("message", message.into()),
    ]))]);
    respond_json(ctx, w, status, &body, keep, extra)
}

/// Map a [`ServeError`] to `(status, error.type)` — the one place the
/// typed taxonomy meets HTTP.
fn status_for(err: &ServeError) -> (u16, &'static str) {
    match err {
        ServeError::Rejected { .. } => (503, "rejected"),
        ServeError::Evicted { .. } => (503, "evicted"),
        ServeError::TooLong { .. } => (400, "too_long"),
        ServeError::Empty => (400, "empty"),
        ServeError::EngineInit { .. } => (503, "engine_init"),
        ServeError::Internal { .. } => (500, "internal"),
    }
}

fn respond_serve_error(ctx: &Ctx, w: &mut TcpStream, err: &ServeError,
                       keep: bool) -> Result<()> {
    let (status, kind) = status_for(err);
    respond_error(ctx, w, status, kind, &err.to_string(), keep, &[])
}

fn retry_after(ctx: &Ctx) -> Vec<(&'static str, String)> {
    vec![("Retry-After", ctx.cfg.retry_after_secs.to_string())]
}

/// `?key=value` lookup on a raw query string (no percent decoding —
/// the debug endpoints take numeric params only).
fn query_usize(query: &str, key: &str) -> Option<usize> {
    query.split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .and_then(|(_, v)| v.parse().ok())
}

/// Dispatch one parsed request; returns whether to keep the connection.
fn handle_request(ctx: &Arc<Ctx>, w: &mut TcpStream, req: HttpRequest,
                  keep: bool) -> Result<bool> {
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (req.path.clone(), String::new()),
    };
    match (req.method.as_str(), path.as_str()) {
        ("GET", "/healthz") => {
            let workers = ctx.server.live_workers();
            let (status, state) =
                if workers > 0 { (200, "ok") } else { (503, "down") };
            let body = Value::obj(vec![
                ("status", state.into()),
                ("workers", workers.into()),
            ]);
            respond_json(ctx, w, status, &body, keep, &[])?;
            Ok(keep)
        }
        ("GET", "/metrics") => {
            let text = ctx.server.metrics.render_prometheus();
            respond_raw(ctx, w, 200, "text/plain; version=0.0.4",
                        text.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", "/debug/requests") => {
            let n = query_usize(&query, "n").unwrap_or(32);
            let traces: Vec<Value> = ctx.server.traces.recent(n)
                .iter().map(|t| t.to_json()).collect();
            let body = Value::obj(vec![
                ("count", traces.len().into()),
                ("requests", Value::Arr(traces)),
            ]);
            respond_json(ctx, w, 200, &body, keep, &[])?;
            Ok(keep)
        }
        ("POST", "/v1/score") => {
            handle_score(ctx, w, &req, keep)?;
            Ok(keep)
        }
        ("POST", "/v1/completions") => {
            handle_completions(ctx, w, &req, keep)?;
            Ok(keep)
        }
        (_, "/admin/shutdown") => {
            // SIGTERM-equivalent: stop accepting, then `wait()` drains
            ctx.shutdown_req.store(true, Ordering::SeqCst);
            ctx.stop.store(true, Ordering::SeqCst);
            let body = Value::obj(vec![("status", "draining".into())]);
            respond_json(ctx, w, 200, &body, false, &[])?;
            Ok(false)
        }
        _ => {
            respond_error(ctx, w, 404, "not_found",
                          &format!("no handler for {} {}", req.method,
                                   req.path), keep, &[])?;
            Ok(keep)
        }
    }
}

fn parse_body(req: &HttpRequest) -> Result<Value> {
    let text = std::str::from_utf8(&req.body)
        .context("request body is not UTF-8")?;
    json::parse(text).context("request body is not valid JSON")
}

fn int_array(v: &Value, key: &str) -> Result<Vec<i32>> {
    let arr = v.get(key).and_then(|a| a.as_arr())
        .ok_or_else(|| anyhow!("missing or non-array field {key:?}"))?;
    arr.iter()
        .map(|t| t.as_f64().map(|f| f as i32)
            .ok_or_else(|| anyhow!("non-numeric element in {key:?}")))
        .collect()
}

fn handle_score(ctx: &Arc<Ctx>, w: &mut TcpStream, req: &HttpRequest,
                keep: bool) -> Result<()> {
    let Some(_slot) = InflightGuard::try_acquire(ctx) else {
        return respond_error(ctx, w, 503, "overloaded",
                             "too many in-flight requests", keep,
                             &retry_after(ctx));
    };
    let params = match parse_body(req)
        .and_then(|v| int_array(&v, "tokens").map(|tokens| {
            ScoreParams { tokens }
        })) {
        Ok(p) => p,
        Err(e) => {
            return respond_error(ctx, w, 400, "bad_request",
                                 &format!("{e:#}"), keep, &[]);
        }
    };
    let handle = match ctx.server.submit_score(params) {
        Ok(h) => h,
        Err(e) => return respond_serve_error(ctx, w, &e, keep),
    };
    match handle.recv_timeout(REQUEST_TIMEOUT) {
        Ok(resp) => match &resp.result {
            Ok(out) => {
                let mut fields = vec![
                    ("id", (resp.id as f64).into()),
                    ("object", "score".into()),
                    ("variant", resp.variant.as_str().into()),
                    ("nll", f64::from(out.nll).into()),
                ];
                if let Some(t) = &resp.timings {
                    fields.push(("timings", t.to_json()));
                }
                let body = Value::obj(fields);
                respond_json(ctx, w, 200, &body, keep, &[])
            }
            Err(e) => respond_serve_error(ctx, w, e, keep),
        },
        Err(_) => respond_error(ctx, w, 504, "timeout",
                                "no response from the coordinator in \
                                 time", keep, &[]),
    }
}

struct CompletionBody {
    params: GenerateParams,
    stream: bool,
}

fn parse_completion(req: &HttpRequest) -> Result<CompletionBody> {
    let v = parse_body(req)?;
    let prompt = int_array(&v, "prompt")?;
    let max_new = v.get("max_new").and_then(|x| x.as_usize())
        .unwrap_or(16);
    let temperature = v.get("temperature").and_then(|x| x.as_f64())
        .unwrap_or(0.0);
    let seed = v.get("seed").and_then(|x| x.as_f64()).unwrap_or(0.0)
        as u64;
    let stream = matches!(v.get("stream"), Some(Value::Bool(true)));
    Ok(CompletionBody {
        params: GenerateParams { prompt, max_new, temperature, seed },
        stream,
    })
}

fn handle_completions(ctx: &Arc<Ctx>, w: &mut TcpStream,
                      req: &HttpRequest, keep: bool) -> Result<()> {
    let Some(_slot) = InflightGuard::try_acquire(ctx) else {
        return respond_error(ctx, w, 503, "overloaded",
                             "too many in-flight requests", keep,
                             &retry_after(ctx));
    };
    let body = match parse_completion(req) {
        Ok(b) => b,
        Err(e) => {
            return respond_error(ctx, w, 400, "bad_request",
                                 &format!("{e:#}"), keep, &[]);
        }
    };
    // backpressure: the decode queue's level gauge is the knob
    let depth = ctx.server.metrics.level("gen_queue_depth");
    if depth >= ctx.cfg.max_queue_depth.max(0) {
        return respond_error(ctx, w, 429, "backpressure",
                             &format!("generate queue depth {depth} at \
                                       the limit; retry later"),
                             keep, &retry_after(ctx));
    }
    if !body.stream {
        let handle = match ctx.server.submit_generate(body.params) {
            Ok(h) => h,
            Err(e) => return respond_serve_error(ctx, w, &e, keep),
        };
        return match handle.recv_timeout(REQUEST_TIMEOUT) {
            Ok(resp) => match &resp.result {
                Ok(out) => {
                    let toks = Value::Arr(out.tokens.iter()
                        .map(|&t| Value::Num(t as f64)).collect());
                    let mut fields = vec![
                        ("id", (resp.id as f64).into()),
                        ("object", "completion".into()),
                        ("variant", resp.variant.as_str().into()),
                        ("tokens", toks),
                    ];
                    if let Some(t) = &resp.timings {
                        fields.push(("timings", t.to_json()));
                    }
                    let body = Value::obj(fields);
                    respond_json(ctx, w, 200, &body, keep, &[])
                }
                Err(e) => respond_serve_error(ctx, w, e, keep),
            },
            Err(_) => respond_error(ctx, w, 504, "timeout",
                                    "no response from the coordinator \
                                     in time", keep, &[]),
        };
    }
    // streaming: tokens flow as the scheduler retires decode steps
    let (stx, srx) = mpsc::channel();
    let handle = match ctx.server
        .submit_generate_streaming(body.params, stx) {
        Ok(h) => h,
        Err(e) => return respond_serve_error(ctx, w, &e, keep),
    };
    status_class(ctx, 200);
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
         Transfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
        if keep { "keep-alive" } else { "close" });
    w.write_all(head.as_bytes()).context("write stream head")?;
    w.flush().context("flush stream head")?;
    // the worker drops the sender when the request retires, so this
    // loop ends on disconnect; each event is one sampled token
    loop {
        match srx.recv_timeout(REQUEST_TIMEOUT) {
            Ok(tok) => {
                let ev = Value::obj(vec![("token",
                                          Value::Num(tok as f64))]);
                write_event(w, &ev)?;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let ev = Value::obj(vec![("error", Value::obj(vec![
                    ("type", "timeout".into()),
                    ("message", "decode stalled".into()),
                ]))]);
                write_event(w, &ev)?;
                return end_stream(w);
            }
        }
    }
    let fin = match handle.recv_timeout(REQUEST_TIMEOUT) {
        Ok(resp) => match &resp.result {
            Ok(out) => {
                let mut fields = vec![
                    ("done", true.into()),
                    ("id", (resp.id as f64).into()),
                    ("variant", resp.variant.as_str().into()),
                    ("count", out.tokens.len().into()),
                ];
                if let Some(t) = &resp.timings {
                    fields.push(("timings", t.to_json()));
                }
                Value::obj(fields)
            }
            Err(e) => {
                let (_, kind) = status_for(e);
                Value::obj(vec![
                    ("done", true.into()),
                    ("id", (resp.id as f64).into()),
                    ("error", Value::obj(vec![
                        ("type", kind.into()),
                        ("message", e.to_string().into()),
                    ])),
                ])
            }
        },
        Err(_) => Value::obj(vec![
            ("done", true.into()),
            ("error", Value::obj(vec![
                ("type", "timeout".into()),
                ("message", "no terminal response".into()),
            ])),
        ]),
    };
    write_event(w, &fin)?;
    write_chunk(w, b"data: [DONE]\n\n")?;
    end_stream(w)
}

fn write_event(w: &mut TcpStream, v: &Value) -> Result<()> {
    let data = format!("data: {}\n\n", v.to_string_compact());
    write_chunk(w, data.as_bytes())
}

fn write_chunk(w: &mut TcpStream, data: &[u8]) -> Result<()> {
    write!(w, "{:x}\r\n", data.len()).context("write chunk size")?;
    w.write_all(data).context("write chunk")?;
    w.write_all(b"\r\n").context("write chunk end")?;
    w.flush().context("flush chunk")
}

fn end_stream(w: &mut TcpStream) -> Result<()> {
    w.write_all(b"0\r\n\r\n").context("write last chunk")?;
    w.flush().context("flush last chunk")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = HttpConfig::default();
        assert!(!c.addr.is_empty());
        assert!(c.threads >= 1);
        assert!(c.max_inflight >= 1);
        assert!(c.max_queue_depth >= 1);
    }

    #[test]
    fn serve_error_status_mapping() {
        assert_eq!(status_for(&ServeError::Empty).0, 400);
        assert_eq!(status_for(&ServeError::TooLong { need: 9, max: 4 }).0,
                   400);
        assert_eq!(status_for(&ServeError::Evicted {
            reason: "x".into() }).0, 503);
        assert_eq!(status_for(&ServeError::Rejected {
            reason: "x".into() }).0, 503);
        assert_eq!(status_for(&ServeError::Internal {
            reason: "x".into() }).0, 500);
    }

    #[test]
    fn query_strings_parse_numeric_params() {
        assert_eq!(query_usize("n=5", "n"), Some(5));
        assert_eq!(query_usize("a=1&n=12", "n"), Some(12));
        assert_eq!(query_usize("n=x", "n"), None);
        assert_eq!(query_usize("", "n"), None);
    }

    #[test]
    fn int_array_parses_and_rejects() {
        let v = json::parse("{\"tokens\": [1, 2, 3]}").unwrap();
        assert_eq!(int_array(&v, "tokens").unwrap(), vec![1, 2, 3]);
        assert!(int_array(&v, "missing").is_err());
        let bad = json::parse("{\"tokens\": [1, \"x\"]}").unwrap();
        assert!(int_array(&bad, "tokens").is_err());
    }
}
