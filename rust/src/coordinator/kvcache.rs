//! KV-cache manager with MLA-aware accounting (paper benefit (ii) and the
//! DeepSeek-V3 motivation): a dense MHA layer caches 2·d floats per token;
//! a latent layer caches only r_k + r_v. The manager tracks per-sequence
//! allocations against a byte budget and admits/evicts accordingly —
//! the piece of a serving stack the paper's compression directly enlarges.
//!
//! Since the decode refactor this is no longer paper arithmetic on the
//! side: the footprints it budgets are the [`crate::runtime::DecodeState`]
//! tensors server workers actually hold ([`CacheKind`] lives in
//! `runtime::decode` and is re-exported here), and its verdicts have
//! teeth — a failed [`KvCacheManager::extend`] mid-decode drops the
//! worker's live session and the request gets an eviction error
//! (`coordinator::server::run_generate`).

use std::collections::HashMap;

pub use crate::runtime::decode::CacheKind;

#[derive(Clone, Debug)]
struct SeqAlloc {
    tokens: usize,
    /// the rate this sequence is billed at — usually the variant's
    /// nominal [`KvCacheManager::bytes_per_token`], but decode sessions
    /// are charged what their `DecodeState` actually holds
    /// ([`KvCacheManager::admit_with`])
    bytes_per_token: usize,
}

/// Byte-budgeted cache accounting for one model variant.
#[derive(Debug)]
pub struct KvCacheManager {
    kind: CacheKind,
    n_layers: usize,
    bytes_per_el: usize,
    budget_bytes: usize,
    used_bytes: usize,
    seqs: HashMap<u64, SeqAlloc>,
    pub peak_bytes: usize,
    pub evictions: u64,
}

impl KvCacheManager {
    pub fn new(kind: CacheKind, n_layers: usize, bytes_per_el: usize,
               budget_bytes: usize) -> Self {
        KvCacheManager {
            kind, n_layers, bytes_per_el, budget_bytes,
            used_bytes: 0, seqs: HashMap::new(),
            peak_bytes: 0, evictions: 0,
        }
    }

    pub fn bytes_per_token(&self) -> usize {
        self.kind.bytes_per_token_layer(self.bytes_per_el) * self.n_layers
    }

    /// Bytes/token this manager charges for a session with the given
    /// footprint descriptor and layer count — what a decode session's
    /// real state costs, which may differ from the variant's nominal
    /// kind (e.g. serve's latent-accounted variant running dense-layout
    /// compressed weights).
    pub fn bytes_per_token_for(&self, kind: CacheKind, n_layers: usize)
                               -> usize {
        kind.bytes_per_token_layer(self.bytes_per_el) * n_layers
    }

    /// Try to reserve `tokens` cache slots for a sequence at the
    /// variant's nominal rate. Returns false if the budget cannot fit it
    /// even after evicting nothing (admission control — the batcher
    /// backs off). Re-admitting a live `seq_id` replaces its allocation:
    /// release-then-reserve, so the old reservation cannot leak (the
    /// pre-fix `HashMap::insert` overwrote the `SeqAlloc` while
    /// `used_bytes` kept counting it, permanently shrinking the budget).
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> bool {
        let bpt = self.bytes_per_token();
        self.admit_with(seq_id, tokens, bpt)
    }

    /// [`KvCacheManager::admit`] at an explicit per-token rate: the
    /// decode path re-admits each session at the bytes its
    /// [`crate::runtime::DecodeState`] actually holds
    /// ([`KvCacheManager::bytes_per_token_for`] of the *session's*
    /// cache kind), so a variant whose step program runs a different
    /// architecture than its nominal accounting is still billed
    /// honestly.
    pub fn admit_with(&mut self, seq_id: u64, tokens: usize,
                      bytes_per_token: usize) -> bool {
        self.release(seq_id);
        let need = tokens * bytes_per_token;
        if self.used_bytes + need > self.budget_bytes {
            return false;
        }
        self.used_bytes += need;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.seqs.insert(seq_id, SeqAlloc { tokens, bytes_per_token });
        true
    }

    /// Grow a sequence by one decoded token (billed at its admission
    /// rate); evicts the sequence and reports false if the budget is
    /// exhausted.
    pub fn extend(&mut self, seq_id: u64) -> bool {
        match self.seqs.get_mut(&seq_id) {
            Some(s) => {
                let bpt = s.bytes_per_token;
                if self.used_bytes + bpt > self.budget_bytes {
                    let bytes = s.tokens * bpt;
                    self.used_bytes -= bytes;
                    self.seqs.remove(&seq_id);
                    self.evictions += 1;
                    return false;
                }
                s.tokens += 1;
                self.used_bytes += bpt;
                self.peak_bytes = self.peak_bytes.max(self.used_bytes);
                true
            }
            None => false,
        }
    }

    pub fn release(&mut self, seq_id: u64) {
        if let Some(s) = self.seqs.remove(&seq_id) {
            self.used_bytes -= s.tokens * s.bytes_per_token;
        }
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn capacity_tokens(&self) -> usize {
        self.budget_bytes / self.bytes_per_token().max(1)
    }

    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_cache_fits_more_sequences() {
        // paper benefit (ii): MLA cache is (rk+rv)/(2d) of dense.
        let budget = 1 << 20;
        let mut dense = KvCacheManager::new(CacheKind::Dense { d: 128 }, 4,
                                            2, budget);
        let mut latent = KvCacheManager::new(
            CacheKind::Latent { rk: 32, rv: 32 }, 4, 2, budget);
        let mut n_dense = 0u64;
        while dense.admit(n_dense, 128) {
            n_dense += 1;
        }
        let mut n_latent = 0u64;
        while latent.admit(n_latent, 128) {
            n_latent += 1;
        }
        assert_eq!(dense.bytes_per_token(), 4 * 2 * 128 * 2);
        assert_eq!(latent.bytes_per_token(), 4 * 64 * 2);
        assert_eq!(n_latent, n_dense * 4, "2d/(rk+rv) = 4x capacity");
    }

    #[test]
    fn accounting_balances() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 2, 2,
                                        1 << 16);
        assert!(m.admit(1, 10));
        assert!(m.admit(2, 5));
        let used = m.used_bytes();
        assert_eq!(used, 15 * m.bytes_per_token());
        assert!(m.extend(1));
        assert_eq!(m.used_bytes(), 16 * m.bytes_per_token());
        m.release(1);
        assert_eq!(m.used_bytes(), 5 * m.bytes_per_token());
        m.release(2);
        assert_eq!(m.used_bytes(), 0);
    }

    #[test]
    fn readmitting_live_seq_releases_old_reservation() {
        // regression: admit() used to HashMap::insert over a live
        // allocation without returning its bytes — every re-admission
        // leaked used_bytes until the budget was permanently exhausted.
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 2, 2,
                                        1 << 16);
        assert!(m.admit(1, 10));
        assert!(m.admit(1, 4), "re-admission must fit");
        assert_eq!(m.used_bytes(), 4 * m.bytes_per_token(),
                   "old reservation must be released, not leaked");
        m.release(1);
        assert_eq!(m.used_bytes(), 0, "release must return every byte");
        // repeated churn on one id must never creep used_bytes upward
        for _ in 0..100 {
            assert!(m.admit(7, 12));
        }
        m.release(7);
        assert_eq!(m.used_bytes(), 0);
    }

    #[test]
    fn admit_with_bills_the_actual_footprint() {
        // a latent-accounted variant running dense sessions must charge
        // the dense rate: admission, extension, and release all follow
        // the per-sequence rate, not the nominal one
        let mut m = KvCacheManager::new(
            CacheKind::Latent { rk: 4, rv: 4 }, 2, 2, 1 << 12);
        let dense_bpt = m.bytes_per_token_for(CacheKind::Dense { d: 16 }, 2);
        assert_eq!(dense_bpt, 2 * 16 * 2 * 2);
        assert!(dense_bpt > m.bytes_per_token(), "dense must cost more");
        assert!(m.admit_with(1, 5, dense_bpt));
        assert_eq!(m.used_bytes(), 5 * dense_bpt);
        assert!(m.extend(1));
        assert_eq!(m.used_bytes(), 6 * dense_bpt,
                   "extend must grow at the admitted rate");
        m.release(1);
        assert_eq!(m.used_bytes(), 0);
        // eviction at the admitted rate returns every byte too
        let cap = (1 << 12) / dense_bpt;
        assert!(m.admit_with(2, cap, dense_bpt));
        assert!(!m.extend(2), "over budget must evict");
        assert_eq!(m.used_bytes(), 0);
    }

    #[test]
    fn admission_control_and_eviction() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 10); // 10 tokens budget
        assert!(m.admit(1, 8));
        assert!(!m.admit(2, 8), "over budget must be rejected");
        assert!(m.extend(1));
        assert!(m.extend(1));
        // budget full: next extend evicts
        assert!(!m.extend(1));
        assert_eq!(m.evictions, 1);
        assert_eq!(m.active_sequences(), 0);
        assert_eq!(m.used_bytes(), 0);
    }
}
