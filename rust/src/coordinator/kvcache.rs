//! KV-cache manager with MLA-aware accounting (paper benefit (ii) and the
//! DeepSeek-V3 motivation): a dense MHA layer caches 2·d floats per token;
//! a latent layer caches only r_k + r_v. Since the scheduler PR the
//! manager is *paged*: its byte budget is carved into fixed-size blocks
//! ([`crate::coordinator::pages::PageAllocator`], vLLM/PagedAttention
//! style) of `block_tokens` tokens at the variant's nominal byte-rate,
//! and admission/growth is accounted in whole blocks off a free list —
//! which is what makes preemption-by-requeue cheap and exact, and what
//! the latent variants exploit: at `r_k + r_v` bytes/token each block
//! packs `2·d / (r_k + r_v)`× more tokens, so a matched pool admits that
//! many more live sessions.
//!
//! The footprints it budgets are the [`crate::runtime::DecodeState`]
//! tensors server workers actually hold ([`CacheKind`] lives in
//! `runtime::decode` and is re-exported here). Its verdicts have teeth
//! in two modes: the sequential decode path treats a failed
//! [`KvCacheManager::extend`] as an eviction (session dropped, request
//! errored — `coordinator::server::run_generate`), while the
//! continuous-batching scheduler uses [`KvCacheManager::try_extend`] and
//! answers a refusal with preemption-by-requeue
//! (`coordinator::scheduler`).

use super::pages::PageAllocator;
use super::prefixcache::{PrefixCache, PrefixHit, PrefixStats};
use crate::runtime::decode::PrefixSnapshot;
pub use crate::runtime::decode::CacheKind;

/// Default page size in tokens (at the variant's nominal byte-rate) —
/// small because the mini models' contexts are short; `latentllm serve
/// --sched-block` overrides it.
pub const DEFAULT_BLOCK_TOKENS: usize = 4;

/// Paged, byte-budgeted cache accounting for one model variant.
#[derive(Debug)]
pub struct KvCacheManager {
    kind: CacheKind,
    n_layers: usize,
    bytes_per_el: usize,
    block_tokens: usize,
    pages: PageAllocator,
    /// content-addressed prefix cache over this pool's blocks — on by
    /// default, `None` when killed via
    /// [`KvCacheManager::set_prefix_cache`]
    prefix: Option<PrefixCache>,
    pub peak_bytes: usize,
    pub evictions: u64,
}

impl KvCacheManager {
    /// Pool with the default page size ([`DEFAULT_BLOCK_TOKENS`]).
    pub fn new(kind: CacheKind, n_layers: usize, bytes_per_el: usize,
               budget_bytes: usize) -> Self {
        KvCacheManager::with_block_tokens(kind, n_layers, bytes_per_el,
                                          budget_bytes,
                                          DEFAULT_BLOCK_TOKENS)
    }

    /// Pool whose blocks hold `block_tokens` tokens at this variant's
    /// nominal byte-rate (sequences billed at a different real footprint
    /// are charged byte-honestly in whole blocks).
    pub fn with_block_tokens(kind: CacheKind, n_layers: usize,
                             bytes_per_el: usize, budget_bytes: usize,
                             block_tokens: usize) -> Self {
        let bpt =
            kind.bytes_per_token_layer(bytes_per_el) * n_layers;
        let block_tokens = block_tokens.max(1);
        let block_bytes = (block_tokens * bpt.max(1)).max(1);
        KvCacheManager {
            kind,
            n_layers,
            bytes_per_el,
            block_tokens,
            pages: PageAllocator::new(budget_bytes, block_bytes),
            prefix: Some(PrefixCache::new(block_tokens)),
            peak_bytes: 0,
            evictions: 0,
        }
    }

    pub fn bytes_per_token(&self) -> usize {
        self.kind.bytes_per_token_layer(self.bytes_per_el) * self.n_layers
    }

    /// Bytes/token this manager charges for a session with the given
    /// footprint descriptor and layer count — what a decode session's
    /// real state costs, which may differ from the variant's nominal
    /// kind (e.g. serve's latent-accounted variant running dense-layout
    /// compressed weights).
    pub fn bytes_per_token_for(&self, kind: CacheKind, n_layers: usize)
                               -> usize {
        kind.bytes_per_token_layer(self.bytes_per_el) * n_layers
    }

    /// Try to reserve pages for `tokens` cache slots at the variant's
    /// nominal rate. Returns false if the free list cannot cover it
    /// (admission control — the batcher backs off). Re-admitting a live
    /// `seq_id` replaces its allocation release-then-reserve, so the old
    /// reservation cannot leak.
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> bool {
        let bpt = self.bytes_per_token();
        self.admit_with(seq_id, tokens, bpt)
    }

    /// [`KvCacheManager::admit`] at an explicit per-token rate: the
    /// decode paths re-admit each session at the bytes its
    /// [`crate::runtime::DecodeState`] actually holds
    /// ([`KvCacheManager::bytes_per_token_for`] of the *session's*
    /// cache kind), so a variant whose step program runs a different
    /// architecture than its nominal accounting is still billed
    /// honestly — in whole blocks.
    pub fn admit_with(&mut self, seq_id: u64, tokens: usize,
                      bytes_per_token: usize) -> bool {
        let ok = self.pages.admit(seq_id, tokens, bytes_per_token);
        self.sync_prefix_reclaims();
        self.note_peak();
        ok
    }

    /// Scheduler admission through the prefix cache: probe for the
    /// longest cached prefix of `feed` (capped one token short, so the
    /// feed always runs ≥ 1 token forward and produces logits), then
    /// admit with the hit's blocks *shared* when the session is billed at
    /// the nominal rate — off-rate sessions get plain whole billing but
    /// still reuse the hit's tensor rows. Returns the hit only when the
    /// admission succeeded; effectiveness counters move only then, so a
    /// requeue-and-retry never double-counts.
    pub fn admit_prefixed(&mut self, seq_id: u64, feed: &[i32],
                          bytes_per_token: usize)
                          -> (bool, Option<PrefixHit>) {
        let nominal = bytes_per_token == self.bytes_per_token();
        let hit = self.prefix.as_ref()
            .and_then(|p| p.lookup(feed, feed.len().saturating_sub(1)));
        let ok = match &hit {
            Some(h) if nominal => self.pages.admit_shared(
                seq_id, feed.len(), bytes_per_token, &h.blocks),
            _ => self.pages.admit(seq_id, feed.len(), bytes_per_token),
        };
        self.sync_prefix_reclaims();
        self.note_peak();
        if ok {
            if let Some(p) = self.prefix.as_mut() {
                match &hit {
                    Some(h) => {
                        p.hits += 1;
                        p.saved_tokens += h.tokens as u64;
                    }
                    None => p.misses += 1,
                }
            }
        }
        (ok, if ok { hit } else { None })
    }

    /// Donate the leading full blocks of a live sequence's prompt (rows
    /// in `snap`, which must cover at least those tokens) into the prefix
    /// cache. Idempotent — existing entries are skipped — and restricted
    /// to sequences admitted at the nominal rate, where physical block i
    /// holds exactly token block i.
    pub fn donate_prefix(&mut self, seq_id: u64, tokens: &[i32],
                         snap: &PrefixSnapshot) {
        if self.pages.rate_of(seq_id) != Some(self.bytes_per_token()) {
            return;
        }
        let Some(p) = self.prefix.as_mut() else {
            return;
        };
        let Some(blocks) = self.pages.block_ids(seq_id) else {
            return;
        };
        for b in p.insert(tokens, blocks, snap) {
            self.pages.mark_cached(b);
        }
    }

    /// Full-block tokens of `tokens` the cache already serves (donation
    /// skip probe).
    pub fn prefix_matched_tokens(&self, tokens: &[i32]) -> usize {
        self.prefix.as_ref()
            .map(|p| p.matched_tokens(tokens))
            .unwrap_or(0)
    }

    /// Kill switch: turning the cache off forgets every entry and
    /// unflags its blocks (parked ones move to the free set); turning it
    /// on starts empty.
    pub fn set_prefix_cache(&mut self, on: bool) {
        if on {
            if self.prefix.is_none() {
                self.prefix = Some(PrefixCache::new(self.block_tokens));
            }
        } else if let Some(p) = self.prefix.take() {
            for b in p.all_blocks() {
                self.pages.uncache(b);
            }
        }
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Effectiveness counters (zeroes when the cache is off).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Blocks the allocator reclaimed under pressure carry prefix
    /// content that no longer exists: evict their entries (and every
    /// descendant) and unflag the orphans. Called after every operation
    /// that can allocate.
    fn sync_prefix_reclaims(&mut self) {
        let reclaimed = self.pages.take_reclaimed();
        if reclaimed.is_empty() {
            return;
        }
        if let Some(p) = self.prefix.as_mut() {
            for b in reclaimed {
                for orphan in p.forget_block(b) {
                    self.pages.uncache(orphan);
                }
            }
        }
    }

    /// Grow a sequence by one decoded token (billed at its admission
    /// rate); evicts the sequence — returning its blocks — and reports
    /// false when no free block remains. The sequential decode path's
    /// semantics; the scheduler uses [`KvCacheManager::try_extend`] and
    /// preempts a *chosen* victim instead.
    pub fn extend(&mut self, seq_id: u64) -> bool {
        if self.pages.extend(seq_id) {
            self.sync_prefix_reclaims();
            self.note_peak();
            return true;
        }
        if self.pages.contains(seq_id) {
            self.pages.release(seq_id);
            self.evictions += 1;
        }
        false
    }

    /// Non-destructive [`KvCacheManager::extend`]: a refusal leaves the
    /// sequence's pages untouched so the caller can preempt some other
    /// victim and retry. False for unknown sequences too.
    pub fn try_extend(&mut self, seq_id: u64) -> bool {
        let ok = self.pages.extend(seq_id);
        self.sync_prefix_reclaims();
        self.note_peak();
        ok
    }

    pub fn release(&mut self, seq_id: u64) {
        self.pages.release(seq_id);
    }

    /// Could a sequence of `tokens` tokens at `bytes_per_token` ever fit
    /// this pool, even with every block free? Separates
    /// requeue-and-retry from reject-now.
    pub fn fits_total(&self, tokens: usize, bytes_per_token: usize) -> bool {
        self.pages.fits_total(tokens, bytes_per_token)
    }

    /// Bytes pinned by in-use blocks (block-quantized).
    pub fn used_bytes(&self) -> usize {
        self.pages.used_bytes()
    }

    /// Whole-pool token capacity at the nominal rate.
    pub fn capacity_tokens(&self) -> usize {
        self.pages.total_blocks() * self.pages.block_bytes()
            / self.bytes_per_token().max(1)
    }

    /// Tokens (at the nominal rate) the free list still covers — the
    /// cache-aware router's headroom signal.
    pub fn free_tokens(&self) -> usize {
        self.pages.free_blocks() * self.pages.block_bytes()
            / self.bytes_per_token().max(1)
    }

    pub fn block_bytes(&self) -> usize {
        self.pages.block_bytes()
    }

    /// Tokens per block at the nominal rate (the prefix cache's keying
    /// granularity).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.pages.total_blocks()
    }

    pub fn free_blocks(&self) -> usize {
        self.pages.free_blocks()
    }

    pub fn blocks_of(&self, seq_id: u64) -> usize {
        self.pages.blocks_of(seq_id)
    }

    pub fn active_sequences(&self) -> usize {
        self.pages.active_sequences()
    }

    /// The underlying allocator (invariant audits in tests).
    pub fn pages(&self) -> &PageAllocator {
        &self.pages
    }

    fn note_peak(&mut self) {
        self.peak_bytes = self.peak_bytes
            .max(self.pages.peak_blocks * self.pages.block_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::decode::LayerCache;
    use crate::Matrix;

    /// One dense layer whose rows encode token ids — adoption and
    /// resurrection stay checkable bit-for-bit.
    fn snap_for(tokens: &[i32], d: usize) -> PrefixSnapshot {
        let n = tokens.len();
        PrefixSnapshot {
            tokens: n,
            layers: vec![LayerCache::Dense {
                k: Matrix::from_fn(n, d, |r, c| tokens[r] as f64
                                                + c as f64),
                v: Matrix::from_fn(n, d, |r, _| tokens[r] as f64),
            }],
        }
    }

    #[test]
    fn prefix_donation_hit_release_and_reclaim_cycle() {
        // 1 layer d=8 at 2 B → 32 B/token; 4 blocks of 4 tokens
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 16);
        assert!(m.prefix_enabled(), "prefix cache defaults on");
        assert_eq!(m.block_tokens(), 4);
        let bpt = m.bytes_per_token();
        let prompt: Vec<i32> = (0..8).collect(); // exactly 2 full blocks

        // cold: admission is a miss, donation caches both blocks
        let (ok, hit) = m.admit_prefixed(1, &prompt, bpt);
        assert!(ok && hit.is_none());
        m.donate_prefix(1, &prompt, &snap_for(&prompt, 8));
        m.donate_prefix(1, &prompt, &snap_for(&prompt, 8)); // idempotent
        let st = m.prefix_stats();
        assert_eq!((st.cached_blocks, st.inserts, st.misses), (2, 2, 1));

        // warm: a longer prompt sharing the prefix reuses both blocks
        let mut p2 = prompt.clone();
        p2.push(41);
        let (ok, hit) = m.admit_prefixed(2, &p2, bpt);
        assert!(ok);
        let h = hit.unwrap();
        assert_eq!(h.tokens, 8);
        assert_eq!(m.used_bytes(), 3 * m.block_bytes(),
                   "2 shared + 1 private, shared billed once");
        let st = m.prefix_stats();
        assert_eq!((st.hits, st.saved_tokens), (1, 8));
        m.pages().check_invariants().unwrap();

        // both holders gone: blocks park cached-free, still servable
        m.release(1);
        m.release(2);
        assert_eq!(m.pages().cached_free_blocks(), 2);
        assert_eq!(m.used_bytes(), 0);

        // resurrection: an identical prompt pulls them back off the list
        let (ok, hit) = m.admit_prefixed(3, &p2, bpt);
        assert!(ok && hit.unwrap().tokens == 8);
        m.release(3);

        // pressure: a full-pool admission reclaims the parked blocks and
        // the matching entries are evicted
        assert!(m.admit(4, 16));
        let st = m.prefix_stats();
        assert_eq!((st.cached_blocks, st.evictions), (0, 2));
        assert!(m.admit_prefixed(5, &p2, bpt).1.is_none(),
                "reclaimed content must not be served");
        m.pages().check_invariants().unwrap();
    }

    #[test]
    fn prefix_kill_switch_unflags_blocks() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 16);
        let bpt = m.bytes_per_token();
        let prompt: Vec<i32> = (0..8).collect();
        assert!(m.admit_prefixed(1, &prompt, bpt).0);
        m.donate_prefix(1, &prompt, &snap_for(&prompt, 8));
        m.release(1);
        assert_eq!(m.pages().cached_free_blocks(), 2);

        m.set_prefix_cache(false);
        assert!(!m.prefix_enabled());
        assert_eq!(m.pages().cached_free_blocks(), 0,
                   "kill switch returns parked blocks to the free set");
        assert_eq!(m.prefix_stats().cached_blocks, 0);
        // lookups are gone, admissions still work (and count nothing)
        let (ok, hit) = m.admit_prefixed(2, &prompt, bpt);
        assert!(ok && hit.is_none());
        assert_eq!(m.prefix_stats().misses, 0);
        m.release(2);
        // re-enabling starts empty
        m.set_prefix_cache(true);
        assert!(m.prefix_enabled());
        assert!(m.admit_prefixed(3, &prompt, bpt).1.is_none());
        m.pages().check_invariants().unwrap();
    }

    #[test]
    fn off_rate_sessions_reuse_data_but_never_share_blocks() {
        // latent-accounted pool, dense-billed sessions (serve's latent
        // variant running dense-layout weights): donation must refuse —
        // block i would not align with token block i
        let mut m = KvCacheManager::new(
            CacheKind::Latent { rk: 4, rv: 4 }, 2, 2, 1 << 12);
        let dense_bpt = m.bytes_per_token_for(CacheKind::Dense { d: 16 }, 2);
        assert_ne!(dense_bpt, m.bytes_per_token());
        let prompt: Vec<i32> = (0..8).collect();
        assert!(m.admit_prefixed(1, &prompt, dense_bpt).0);
        m.donate_prefix(1, &prompt, &snap_for(&prompt, 16));
        assert_eq!(m.prefix_stats().cached_blocks, 0,
                   "off-rate donation must be refused");
        m.release(1);
        m.pages().check_invariants().unwrap();
    }

    #[test]
    fn latent_cache_fits_more_sequences() {
        // paper benefit (ii) in pages: MLA blocks pack (2d)/(rk+rv) more
        // tokens, so a matched pool admits that many more sessions.
        let budget = 1 << 20;
        let mut dense = KvCacheManager::new(CacheKind::Dense { d: 128 }, 4,
                                            2, budget);
        let mut latent = KvCacheManager::new(
            CacheKind::Latent { rk: 32, rv: 32 }, 4, 2, budget);
        let mut n_dense = 0u64;
        while dense.admit(n_dense, 128) {
            n_dense += 1;
        }
        let mut n_latent = 0u64;
        while latent.admit(n_latent, 128) {
            n_latent += 1;
        }
        assert_eq!(dense.bytes_per_token(), 4 * 2 * 128 * 2);
        assert_eq!(latent.bytes_per_token(), 4 * 64 * 2);
        assert!(n_dense > 0);
        assert_eq!(n_latent, n_dense * 4, "2d/(rk+rv) = 4x capacity");
        assert_eq!(latent.capacity_tokens(), dense.capacity_tokens() * 4);
    }

    #[test]
    fn accounting_is_block_granular_and_balances() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 2, 2,
                                        1 << 16);
        let bpt = m.bytes_per_token();
        let bb = m.block_bytes();
        assert_eq!(bb, DEFAULT_BLOCK_TOKENS * bpt);
        assert!(m.admit(1, 10)); // 10 tokens -> 3 blocks of 4
        assert!(m.admit(2, 5)); // 2 blocks
        assert_eq!(m.used_bytes(), 5 * bb);
        assert!(m.extend(1)); // 11th token fits block 3
        assert_eq!(m.used_bytes(), 5 * bb);
        assert!(m.extend(1)); // 12th fills it
        assert!(m.extend(1)); // 13th opens block 4
        assert_eq!(m.used_bytes(), 6 * bb);
        m.release(1);
        assert_eq!(m.used_bytes(), 2 * bb);
        m.release(2);
        assert_eq!(m.used_bytes(), 0);
        assert_eq!(m.free_tokens(), m.capacity_tokens());
        m.pages().check_invariants().unwrap();
    }

    #[test]
    fn readmitting_live_seq_releases_old_reservation() {
        // regression (pre-pages): admit() used to overwrite a live
        // allocation without returning its bytes. Pages make the leak
        // structurally impossible; pin it anyway.
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 2, 2,
                                        1 << 16);
        assert!(m.admit(1, 10));
        assert!(m.admit(1, 4), "re-admission must fit");
        assert_eq!(m.used_bytes(), m.block_bytes(),
                   "old blocks must be freed, not leaked");
        m.release(1);
        assert_eq!(m.used_bytes(), 0, "release must return every block");
        for _ in 0..100 {
            assert!(m.admit(7, 12));
        }
        m.release(7);
        assert_eq!(m.used_bytes(), 0);
        m.pages().check_invariants().unwrap();
    }

    #[test]
    fn admit_with_bills_the_actual_footprint() {
        // a latent-accounted variant running dense sessions must charge
        // the dense rate: the same block pool, byte-honest block counts
        let mut m = KvCacheManager::new(
            CacheKind::Latent { rk: 4, rv: 4 }, 2, 2, 1 << 12);
        let dense_bpt = m.bytes_per_token_for(CacheKind::Dense { d: 16 }, 2);
        assert_eq!(dense_bpt, 2 * 16 * 2 * 2);
        assert!(dense_bpt > m.bytes_per_token(), "dense must cost more");
        let bb = m.block_bytes(); // 4 tokens at the *latent* rate
        assert!(m.admit_with(1, 5, dense_bpt));
        assert_eq!(m.used_bytes(),
                   (5 * dense_bpt).div_ceil(bb) * bb);
        assert!(m.try_extend(1));
        assert_eq!(m.used_bytes(),
                   (6 * dense_bpt).div_ceil(bb) * bb,
                   "extend must grow at the admitted rate");
        m.release(1);
        assert_eq!(m.used_bytes(), 0);
        // eviction at the admitted rate returns every block too
        let cap = (1 << 12) / dense_bpt;
        assert!(m.admit_with(2, cap, dense_bpt));
        assert!(!m.extend(2), "over budget must evict");
        assert_eq!(m.used_bytes(), 0);
        assert_eq!(m.evictions, 1);
    }

    #[test]
    fn admission_control_eviction_and_try_extend() {
        // 1 layer of d=8 at 2 B -> 32 B/token; 2-block pool of 4 tokens
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 8);
        assert_eq!(m.total_blocks(), 2);
        assert!(m.admit(1, 5)); // both blocks
        assert!(!m.admit(2, 1), "no free block must reject admission");
        assert!(m.extend(1)); // 6..8 fit the held blocks
        assert!(m.extend(1));
        assert!(m.extend(1));
        // pool full: try_extend refuses but keeps the sequence alive
        assert!(!m.try_extend(1));
        assert_eq!(m.active_sequences(), 1);
        assert_eq!(m.evictions, 0);
        // ... while extend() evicts it
        assert!(!m.extend(1));
        assert_eq!(m.evictions, 1);
        assert_eq!(m.active_sequences(), 0);
        assert_eq!(m.used_bytes(), 0);
        assert!(!m.try_extend(99), "unknown sequences refuse");
    }

    #[test]
    fn fits_total_separates_never_from_not_now() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 8); // 8-token pool
        assert!(m.fits_total(8, m.bytes_per_token()));
        assert!(!m.fits_total(9, m.bytes_per_token()));
        assert!(m.admit(1, 8));
        // not-now: would fit an empty pool, but blocks are held
        assert!(!m.admit(2, 4) && m.fits_total(4, m.bytes_per_token()));
    }
}
