//! KV-cache manager with MLA-aware accounting (paper benefit (ii) and the
//! DeepSeek-V3 motivation): a dense MHA layer caches 2·d floats per token;
//! a latent layer caches only r_k + r_v. The manager tracks per-sequence
//! allocations against a byte budget and admits/evicts accordingly —
//! the piece of a serving stack the paper's compression directly enlarges.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// dense MHA: 2·d per token per layer
    Dense { d: usize },
    /// MLA: r_k + r_v per token per layer
    Latent { rk: usize, rv: usize },
}

impl CacheKind {
    pub fn bytes_per_token_layer(&self, bytes_per_el: usize) -> usize {
        match self {
            CacheKind::Dense { d } => 2 * d * bytes_per_el,
            CacheKind::Latent { rk, rv } => (rk + rv) * bytes_per_el,
        }
    }
}

#[derive(Clone, Debug)]
struct SeqAlloc {
    tokens: usize,
}

/// Byte-budgeted cache accounting for one model variant.
#[derive(Debug)]
pub struct KvCacheManager {
    kind: CacheKind,
    n_layers: usize,
    bytes_per_el: usize,
    budget_bytes: usize,
    used_bytes: usize,
    seqs: HashMap<u64, SeqAlloc>,
    pub peak_bytes: usize,
    pub evictions: u64,
}

impl KvCacheManager {
    pub fn new(kind: CacheKind, n_layers: usize, bytes_per_el: usize,
               budget_bytes: usize) -> Self {
        KvCacheManager {
            kind, n_layers, bytes_per_el, budget_bytes,
            used_bytes: 0, seqs: HashMap::new(),
            peak_bytes: 0, evictions: 0,
        }
    }

    pub fn bytes_per_token(&self) -> usize {
        self.kind.bytes_per_token_layer(self.bytes_per_el) * self.n_layers
    }

    /// Try to reserve `tokens` cache slots for a sequence. Returns false if
    /// the budget cannot fit it even after evicting nothing (admission
    /// control — the batcher backs off).
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> bool {
        let need = tokens * self.bytes_per_token();
        if self.used_bytes + need > self.budget_bytes {
            return false;
        }
        self.used_bytes += need;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.seqs.insert(seq_id, SeqAlloc { tokens });
        true
    }

    /// Grow a sequence by one decoded token; evicts the sequence and
    /// reports false if the budget is exhausted.
    pub fn extend(&mut self, seq_id: u64) -> bool {
        let bpt = self.bytes_per_token();
        match self.seqs.get_mut(&seq_id) {
            Some(s) => {
                if self.used_bytes + bpt > self.budget_bytes {
                    let tokens = s.tokens;
                    self.used_bytes -= tokens * bpt;
                    self.seqs.remove(&seq_id);
                    self.evictions += 1;
                    return false;
                }
                s.tokens += 1;
                self.used_bytes += bpt;
                self.peak_bytes = self.peak_bytes.max(self.used_bytes);
                true
            }
            None => false,
        }
    }

    pub fn release(&mut self, seq_id: u64) {
        if let Some(s) = self.seqs.remove(&seq_id) {
            self.used_bytes -= s.tokens * self.bytes_per_token();
        }
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn capacity_tokens(&self) -> usize {
        self.budget_bytes / self.bytes_per_token().max(1)
    }

    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latent_cache_fits_more_sequences() {
        // paper benefit (ii): MLA cache is (rk+rv)/(2d) of dense.
        let budget = 1 << 20;
        let mut dense = KvCacheManager::new(CacheKind::Dense { d: 128 }, 4,
                                            2, budget);
        let mut latent = KvCacheManager::new(
            CacheKind::Latent { rk: 32, rv: 32 }, 4, 2, budget);
        let mut n_dense = 0u64;
        while dense.admit(n_dense, 128) {
            n_dense += 1;
        }
        let mut n_latent = 0u64;
        while latent.admit(n_latent, 128) {
            n_latent += 1;
        }
        assert_eq!(dense.bytes_per_token(), 4 * 2 * 128 * 2);
        assert_eq!(latent.bytes_per_token(), 4 * 64 * 2);
        assert_eq!(n_latent, n_dense * 4, "2d/(rk+rv) = 4x capacity");
    }

    #[test]
    fn accounting_balances() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 2, 2,
                                        1 << 16);
        assert!(m.admit(1, 10));
        assert!(m.admit(2, 5));
        let used = m.used_bytes();
        assert_eq!(used, 15 * m.bytes_per_token());
        assert!(m.extend(1));
        assert_eq!(m.used_bytes(), 16 * m.bytes_per_token());
        m.release(1);
        assert_eq!(m.used_bytes(), 5 * m.bytes_per_token());
        m.release(2);
        assert_eq!(m.used_bytes(), 0);
    }

    #[test]
    fn admission_control_and_eviction() {
        let mut m = KvCacheManager::new(CacheKind::Dense { d: 8 }, 1, 2,
                                        32 * 10); // 10 tokens budget
        assert!(m.admit(1, 8));
        assert!(!m.admit(2, 8), "over budget must be rejected");
        assert!(m.extend(1));
        assert!(m.extend(1));
        // budget full: next extend evicts
        assert!(!m.extend(1));
        assert_eq!(m.evictions, 1);
        assert_eq!(m.active_sequences(), 0);
        assert_eq!(m.used_bytes(), 0);
    }
}
