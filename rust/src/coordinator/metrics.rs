//! Lightweight metrics registry: labeled counters, gauges, and
//! fixed-log-bucket latency histograms (native Prometheus `histogram`
//! exposition), shared across coordinator threads. Every series is
//! O(1) memory regardless of traffic volume — a long-running server
//! never grows its registry past the set of (name, label-set) pairs it
//! touches.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;
use std::time::Duration;

/// Number of finite histogram buckets: upper bounds are 2^0..2^26 µs
/// (1 µs to ~67 s), one octave per bucket, plus a +Inf overflow slot.
/// Log-2 spacing bounds the quantile estimate to within one bucket
/// (≤2× relative) of the exact-sort answer at constant memory.
const BUCKETS: usize = 27;

/// Upper bound (µs) of finite bucket `i`.
fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

/// Index of the first bucket whose upper bound holds `us` (the +Inf
/// slot for anything past the last finite bound).
fn bucket_index(us: f64) -> usize {
    (0..BUCKETS)
        .find(|&i| us <= bucket_bound(i) as f64)
        .unwrap_or(BUCKETS)
}

/// One fixed-size latency histogram: per-bucket counts plus exact
/// sum/count so `_sum`/`_count` stay precise even though quantiles are
/// bucket-resolved.
#[derive(Clone, Default)]
struct Hist {
    counts: [u64; BUCKETS + 1],
    total: u64,
    sum: f64,
}

impl Hist {
    fn observe(&mut self, us: f64) {
        self.counts[bucket_index(us)] += 1;
        self.total += 1;
        self.sum += us;
    }

    /// Quantile estimate at the same rank the old exact-sort used
    /// (`(n-1)·p`), resolved to the holding bucket's upper bound — a
    /// conservative estimate within one bucket of the exact value.
    fn quantile(&self, p: f64) -> f64 {
        let target = ((self.total.saturating_sub(1)) as f64 * p) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 && cum > target {
                return bucket_bound(i.min(BUCKETS)) as f64;
            }
        }
        0.0
    }
}

/// Registry key: metric name plus a sorted label set. The empty label
/// set is the unlabeled series the plain `incr`/`observe` API touches.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct Key {
    name: String,
    labels: Vec<(String, String)>,
}

impl Key {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        Key { name: name.to_string(), labels }
    }

    fn plain(name: &str) -> Self {
        Key { name: name.to_string(), labels: Vec::new() }
    }

    /// Human form for the shutdown summary: `name` or `name{k=v,...}`.
    fn display(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let labels: Vec<String> = self.labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        format!("{}{{{}}}", self.name, labels.join(","))
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Map each distinct registry name to a unique sanitized exposition
/// name. `sanitize` is lossy (`a.b` and `a/b` both land on `a_b`), so
/// without this two distinct registry keys would silently merge into
/// one exposition series; later names that collide with a taken
/// spelling get a deterministic `_2`, `_3`, … suffix instead.
fn unique_names<'a>(names: impl Iterator<Item = &'a str>)
                    -> BTreeMap<&'a str, String> {
    let originals: BTreeSet<&str> = names.collect();
    let mut used: BTreeSet<String> = BTreeSet::new();
    let mut out = BTreeMap::new();
    for name in originals {
        let base = sanitize(name);
        let mut candidate = base.clone();
        let mut i = 2;
        while !used.insert(candidate.clone()) {
            candidate = format!("{base}_{i}");
            i += 1;
        }
        out.insert(name, candidate);
    }
    out
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a label set as `{k="v",...}` (empty string when there is
/// nothing to show), with an optional extra pair appended last — the
/// histogram renderer threads `le` through here.
fn label_str(labels: &[(String, String)], extra: Option<(&str, &str)>)
             -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize(k), escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<Key, u64>,
    hists: BTreeMap<Key, Hist>,
    /// high-water gauges (e.g. peak cache bytes across workers)
    gauges: BTreeMap<String, u64>,
    /// level gauges adjusted by +/- deltas (queue depth, live sessions);
    /// each also records its high-water mark under `<name>_peak`
    levels: BTreeMap<String, i64>,
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(Key::plain(name)).or_insert(0) += by;
    }

    /// Increment a labeled counter series — rendered as a Prometheus
    /// label set (`latentllm_<name>_total{variant="dense",...}`).
    pub fn incr_with(&self, name: &str, labels: &[(&str, &str)],
                     by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(Key::new(name, labels)).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap()
            .counters.get(&Key::plain(name)).copied().unwrap_or(0)
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)])
                        -> u64 {
        self.inner.lock().unwrap()
            .counters.get(&Key::new(name, labels)).copied().unwrap_or(0)
    }

    /// Record a high-water mark: the gauge keeps the max value observed
    /// (cache bytes are sampled by every worker; the fleet peak is what
    /// capacity planning reads).
    pub fn set_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0)
    }

    /// Adjust a level gauge by a signed delta (queue depth, live decode
    /// sessions) and record its high-water mark under `<name>_peak` —
    /// one call site per transition, no separate peak bookkeeping to
    /// forget.
    pub fn gauge_add(&self, name: &str, delta: i64) {
        let mut g = self.inner.lock().unwrap();
        let level = g.levels.entry(name.to_string()).or_insert(0);
        *level += delta;
        let now = *level;
        if now > 0 {
            let peak = g.gauges.entry(format!("{name}_peak")).or_insert(0);
            *peak = (*peak).max(now as u64);
        }
    }

    /// Set a level gauge to an absolute value (sampled levels like
    /// `prefix_blocks_cached`, where the source of truth lives elsewhere
    /// and is re-read periodically), recording `<name>_peak` like
    /// [`Metrics::gauge_add`] does.
    pub fn gauge_set(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        g.levels.insert(name.to_string(), value as i64);
        if value > 0 {
            let peak = g.gauges.entry(format!("{name}_peak")).or_insert(0);
            *peak = (*peak).max(value);
        }
    }

    /// Raise a counter to `value` if it is below it (no-op otherwise):
    /// reconciles a cumulative total kept elsewhere (per-variant prefix
    /// hit/evict counts summed under the router lock) into the registry
    /// idempotently — re-sampling never double-counts, and the counter
    /// stays monotone as Prometheus requires.
    pub fn counter_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(Key::plain(name)).or_insert(0);
        *e = (*e).max(value);
    }

    /// Labeled form of [`Metrics::counter_max`]: raise one series of a
    /// labeled counter family to `value` (per-variant prefix counters
    /// are reconciled this way, one series per cache).
    pub fn counter_max_with(&self, name: &str, labels: &[(&str, &str)],
                            value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(Key::new(name, labels)).or_insert(0);
        *e = (*e).max(value);
    }

    /// Current value of a level gauge (0 if never touched).
    pub fn level(&self, name: &str) -> i64 {
        self.inner.lock().unwrap().levels.get(name).copied().unwrap_or(0)
    }

    /// Ratio of two counters as a percentage string, `"n/a"` when the
    /// denominator is zero — the batch-occupancy readout
    /// (`sched_steps` over `sched_slots`) shared by the serve summary
    /// and the benches, so the derived metric has one definition.
    pub fn ratio_pct(&self, num: &str, den: &str) -> String {
        match self.counter(den) {
            0 => "n/a".to_string(),
            d => format!("{:.0}%",
                         100.0 * self.counter(num) as f64 / d as f64),
        }
    }

    pub fn observe(&self, name: &str, d: Duration) {
        self.observe_with(name, &[], d);
    }

    /// Record a latency sample into a labeled histogram series.
    pub fn observe_with(&self, name: &str, labels: &[(&str, &str)],
                        d: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.hists.entry(Key::new(name, labels)).or_default()
            .observe(d.as_secs_f64() * 1e6);
    }

    /// p50/p95/p99 estimates off the histogram buckets (µs): each is
    /// the upper bound of the bucket holding the exact-sort rank, so it
    /// is within one log-2 bucket of the old exact answer.
    pub fn quantiles(&self, name: &str) -> Option<(f64, f64, f64)> {
        self.quantiles_with(name, &[])
    }

    pub fn quantiles_with(&self, name: &str, labels: &[(&str, &str)])
                          -> Option<(f64, f64, f64)> {
        let g = self.inner.lock().unwrap();
        let h = g.hists.get(&Key::new(name, labels))?;
        if h.total == 0 {
            return None;
        }
        Some((h.quantile(0.50), h.quantile(0.95), h.quantile(0.99)))
    }

    pub fn count(&self, name: &str) -> usize {
        self.inner.lock().unwrap()
            .hists.get(&Key::plain(name))
            .map(|h| h.total as usize).unwrap_or(0)
    }

    /// Exact (sum µs, sample count) of a histogram series — what the
    /// benches use to report mean per-phase cost.
    pub fn sum_count_with(&self, name: &str, labels: &[(&str, &str)])
                          -> Option<(f64, u64)> {
        let g = self.inner.lock().unwrap();
        let h = g.hists.get(&Key::new(name, labels))?;
        if h.total == 0 {
            return None;
        }
        Some((h.sum, h.total))
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (what `GET /metrics` serves). Counters become
    /// `latentllm_<name>_total`, high-water and level gauges become
    /// `latentllm_<name>` gauges, and each latency series becomes a
    /// native `histogram` with log-2 `le` buckets plus `_sum`/`_count`
    /// (values are microseconds, as the `_us` metric names say). Label
    /// sets render inline; colliding sanitized names are suffix-
    /// disambiguated by `unique_names`. Everything is computed under
    /// one lock acquisition — the inner Mutex is not reentrant, so this
    /// must not call the public getters.
    pub fn render_prometheus(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();

        let counter_names =
            unique_names(g.counters.keys().map(|k| k.name.as_str()));
        let mut last: Option<&str> = None;
        for (k, v) in &g.counters {
            let n = &counter_names[k.name.as_str()];
            if last != Some(k.name.as_str()) {
                out.push_str(&format!(
                    "# TYPE latentllm_{n}_total counter\n"));
                last = Some(k.name.as_str());
            }
            out.push_str(&format!(
                "latentllm_{n}_total{} {v}\n",
                label_str(&k.labels, None)));
        }

        // gauges and levels share the plain-name exposition namespace
        let gauge_names = unique_names(
            g.gauges.keys().map(String::as_str)
                .chain(g.levels.keys().map(String::as_str)));
        for (k, v) in &g.gauges {
            let n = &gauge_names[k.as_str()];
            out.push_str(&format!(
                "# TYPE latentllm_{n} gauge\nlatentllm_{n} {v}\n"));
        }
        for (k, v) in &g.levels {
            if g.gauges.contains_key(k) {
                continue; // the gauge rendering above already owns it
            }
            let n = &gauge_names[k.as_str()];
            out.push_str(&format!(
                "# TYPE latentllm_{n} gauge\nlatentllm_{n} {v}\n"));
        }

        let hist_names =
            unique_names(g.hists.keys().map(|k| k.name.as_str()));
        let mut last: Option<&str> = None;
        for (k, h) in &g.hists {
            if h.total == 0 {
                continue;
            }
            let n = format!("latentllm_{}", hist_names[k.name.as_str()]);
            if last != Some(k.name.as_str()) {
                out.push_str(&format!("# TYPE {n} histogram\n"));
                last = Some(k.name.as_str());
            }
            let mut cum = 0u64;
            for (i, &c) in h.counts[..BUCKETS].iter().enumerate() {
                cum += c;
                out.push_str(&format!(
                    "{n}_bucket{} {cum}\n",
                    label_str(&k.labels,
                              Some(("le",
                                    &bucket_bound(i).to_string())))));
            }
            out.push_str(&format!(
                "{n}_bucket{} {}\n",
                label_str(&k.labels, Some(("le", "+Inf"))), h.total));
            out.push_str(&format!(
                "{n}_sum{} {}\n", label_str(&k.labels, None), h.sum));
            out.push_str(&format!(
                "{n}_count{} {}\n", label_str(&k.labels, None),
                h.total));
        }
        out
    }

    /// Render a human summary (the server prints this on shutdown).
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("  {}: {v}\n", k.display()));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("  {k}: {v} (peak)\n"));
        }
        for (k, v) in &g.levels {
            if *v != 0 {
                out.push_str(&format!("  {k}: {v} (now)\n"));
            }
        }
        for (k, h) in &g.hists {
            if h.total == 0 {
                continue;
            }
            out.push_str(&format!(
                "  {}: n={} p50={:.0}µs p95={:.0}µs p99={:.0}µs\n",
                k.display(), h.total, h.quantile(0.50),
                h.quantile(0.95), h.quantile(0.99)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = Metrics::new();
        m.incr("req", 3);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 5);
        for i in 1..=100u64 {
            m.observe("lat", Duration::from_micros(i));
        }
        // bucket-resolved quantiles: the estimate is the upper bound of
        // the bucket holding the exact value, so exact ≤ est < 2·exact
        let (p50, p95, p99) = m.quantiles("lat").unwrap();
        for (est, exact) in [(p50, 50.0), (p95, 95.0), (p99, 99.0)] {
            assert!(est >= exact && est < 2.0 * exact,
                    "estimate {est} not within one bucket of {exact}");
        }
        assert_eq!(m.count("lat"), 100);
        assert!(m.quantiles("missing").is_none());
    }

    #[test]
    fn histogram_quantiles_track_exact_sort_within_one_bucket() {
        // the pre-histogram implementation sorted the raw samples; the
        // bucketed estimate must stay within one log-2 bucket of it on
        // an awkward (clustered + heavy-tailed) distribution
        let mut samples: Vec<f64> = Vec::new();
        let mut x = 7u64;
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695);
            samples.push(1.0 + (x >> 33) as f64 % 9000.0);
        }
        samples.extend([120000.0; 25]); // tail well past the cluster
        let m = Metrics::new();
        for &s in &samples {
            m.observe_with("lat", &[("variant", "dense")],
                           Duration::from_secs_f64(s / 1e6));
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let exact =
            |p: f64| sorted[((sorted.len() as f64 - 1.0) * p) as usize];
        let (p50, p95, p99) =
            m.quantiles_with("lat", &[("variant", "dense")]).unwrap();
        for (est, p) in [(p50, 0.50), (p95, 0.95), (p99, 0.99)] {
            let want = exact(p);
            assert!(est >= want && est <= 2.0 * want + 1.0,
                    "p{p}: estimate {est} vs exact {want}");
        }
        // unlabeled series is a distinct key
        assert!(m.quantiles("lat").is_none());
    }

    #[test]
    fn gauges_keep_the_high_water_mark() {
        let m = Metrics::new();
        m.set_max("cache_bytes", 100);
        m.set_max("cache_bytes", 40);
        m.set_max("cache_bytes", 250);
        assert_eq!(m.gauge("cache_bytes"), 250);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.summary().contains("cache_bytes: 250 (peak)"));
    }

    #[test]
    fn level_gauges_track_current_and_peak() {
        let m = Metrics::new();
        assert_eq!(m.level("queue"), 0);
        m.gauge_add("queue", 3);
        m.gauge_add("queue", 2);
        m.gauge_add("queue", -4);
        assert_eq!(m.level("queue"), 1);
        assert_eq!(m.gauge("queue_peak"), 5);
        m.gauge_add("queue", -1);
        assert_eq!(m.level("queue"), 0);
        assert_eq!(m.gauge("queue_peak"), 5, "peak survives the drain");
        assert!(m.summary().contains("queue_peak: 5 (peak)"));
        assert!(!m.summary().contains("queue: 0 (now)"),
                "zero levels stay out of the summary");
    }

    #[test]
    fn gauge_set_and_counter_max_reconcile_idempotently() {
        let m = Metrics::new();
        m.gauge_set("prefix_blocks_cached", 7);
        m.gauge_set("prefix_blocks_cached", 3);
        assert_eq!(m.level("prefix_blocks_cached"), 3,
                   "gauge_set is absolute, not max");
        assert_eq!(m.gauge("prefix_blocks_cached_peak"), 7);
        m.counter_max("prefix_hits", 5);
        m.counter_max("prefix_hits", 5); // re-sample: no double count
        m.counter_max("prefix_hits", 2); // stale sample: monotone
        assert_eq!(m.counter("prefix_hits"), 5);
        m.counter_max("prefix_hits", 9);
        assert_eq!(m.counter("prefix_hits"), 9);
        let text = m.render_prometheus();
        assert!(text.contains("latentllm_prefix_hits_total 9"));
        assert!(text.contains("latentllm_prefix_blocks_cached 3"));
    }

    #[test]
    fn labeled_counters_round_trip_through_exposition() {
        let m = Metrics::new();
        m.incr_with("steps", &[("variant", "dense"), ("path", "fused")],
                    4);
        m.incr_with("steps", &[("path", "fused"), ("variant", "dense")],
                    1); // label order must not mint a second series
        m.incr_with("steps", &[("variant", "latent"), ("path", "fused")],
                    2);
        m.incr("steps", 10); // unlabeled sibling stays separate
        assert_eq!(m.counter_with(
            "steps", &[("variant", "dense"), ("path", "fused")]), 5);
        assert_eq!(m.counter_with(
            "steps", &[("path", "fused"), ("variant", "dense")]), 5);
        assert_eq!(m.counter("steps"), 10);
        let text = m.render_prometheus();
        assert!(text.contains(
            "latentllm_steps_total{path=\"fused\",variant=\"dense\"} 5"),
            "sorted label set missing:\n{text}");
        assert!(text.contains(
            "latentllm_steps_total{path=\"fused\",variant=\"latent\"} 2"));
        assert!(text.contains("latentllm_steps_total 10"));
        assert_eq!(
            text.matches("# TYPE latentllm_steps_total counter").count(),
            1, "one TYPE line per family:\n{text}");
        assert!(m.summary()
                    .contains("steps{path=fused,variant=dense}: 5"));
    }

    #[test]
    fn renders_prometheus_text() {
        let m = Metrics::new();
        m.incr("requests", 3);
        m.set_max("cache_bytes_peak", 42);
        m.gauge_add("gen_queue_depth", 2);
        m.observe("request_us", Duration::from_micros(100));
        m.observe("request_us", Duration::from_micros(300));
        m.observe_with("step_us", &[("variant", "dense")],
                       Duration::from_micros(3));
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE latentllm_requests_total counter"));
        assert!(text.contains("latentllm_requests_total 3"));
        assert!(text.contains("latentllm_cache_bytes_peak 42"));
        assert!(text.contains("latentllm_gen_queue_depth 2"));
        // native histogram exposition: cumulative log-2 `le` buckets,
        // a +Inf terminal, exact _sum/_count
        assert!(text.contains("# TYPE latentllm_request_us histogram"));
        assert!(text.contains("latentllm_request_us_bucket{le=\"128\"} 1"));
        assert!(text.contains("latentllm_request_us_bucket{le=\"512\"} 2"));
        assert!(text.contains(
            "latentllm_request_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("latentllm_request_us_count 2"));
        assert!(text.contains("latentllm_request_us_sum 400"));
        assert!(text.contains(
            "latentllm_step_us_bucket{variant=\"dense\",le=\"4\"} 1"),
            "labeled histogram buckets must merge labels with le:\n\
             {text}");
        // the exposition format contract: every non-comment line is
        // exactly "name[{labels}] value" with a numeric value
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().expect("metric name");
            let val = parts.next().expect("metric value");
            assert!(parts.next().is_none(), "extra field in {line:?}");
            assert!(val.parse::<f64>().is_ok(), "value in {line:?}");
            assert!(name.starts_with("latentllm_"), "prefix in {line:?}");
        }
    }

    #[test]
    fn colliding_sanitized_names_get_distinct_series() {
        // `a.b` and `a/b` both sanitize to `a_b`: without
        // disambiguation the exposition would show one merged series
        let m = Metrics::new();
        m.incr("gen.tokens", 7);
        m.incr("gen/tokens", 11);
        m.incr("gen_tokens", 13);
        let text = m.render_prometheus();
        assert!(text.contains("latentllm_gen_tokens_total 7"),
                "first sorted original keeps the base name:\n{text}");
        assert!(text.contains("latentllm_gen_tokens_2_total 11"),
                "second collider must be suffixed:\n{text}");
        assert!(text.contains("latentllm_gen_tokens_3_total 13"),
                "third collider must be suffixed:\n{text}");
        // same story for histograms
        m.observe("a.us", Duration::from_micros(5));
        m.observe("a_us", Duration::from_micros(9));
        let text = m.render_prometheus();
        assert!(text.contains("latentllm_a_us_count 1"));
        assert!(text.contains("latentllm_a_us_2_count 1"));
    }

    #[test]
    fn histogram_memory_is_bounded() {
        // a million observations must not grow the registry: one Hist
        // is a fixed array, unlike the old per-sample Vec<f64>
        let m = Metrics::new();
        for i in 0..1_000_000u64 {
            m.observe("gen_us", Duration::from_micros(i % 4096));
        }
        assert_eq!(m.count("gen_us"), 1_000_000);
        let (_, n) = m.sum_count_with("gen_us", &[]).unwrap();
        assert_eq!(n, 1_000_000);
        let (p50, _, _) = m.quantiles("gen_us").unwrap();
        assert!(p50 >= 2048.0 / 2.0 && p50 <= 4096.0,
                "p50 {p50} off a uniform 0..4096 distribution");
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
