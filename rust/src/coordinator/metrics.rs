//! Lightweight metrics registry: counters + latency histograms with
//! p50/p95/p99 summaries, shared across coordinator threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Vec<f64>>, // micros
    /// high-water gauges (e.g. peak cache bytes across workers)
    gauges: BTreeMap<String, u64>,
    /// level gauges adjusted by +/- deltas (queue depth, live sessions);
    /// each also records its high-water mark under `<name>_peak`
    levels: BTreeMap<String, i64>,
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Record a high-water mark: the gauge keeps the max value observed
    /// (cache bytes are sampled by every worker; the fleet peak is what
    /// capacity planning reads).
    pub fn set_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0)
    }

    /// Adjust a level gauge by a signed delta (queue depth, live decode
    /// sessions) and record its high-water mark under `<name>_peak` —
    /// one call site per transition, no separate peak bookkeeping to
    /// forget.
    pub fn gauge_add(&self, name: &str, delta: i64) {
        let mut g = self.inner.lock().unwrap();
        let level = g.levels.entry(name.to_string()).or_insert(0);
        *level += delta;
        let now = *level;
        if now > 0 {
            let peak = g.gauges.entry(format!("{name}_peak")).or_insert(0);
            *peak = (*peak).max(now as u64);
        }
    }

    /// Set a level gauge to an absolute value (sampled levels like
    /// `prefix_blocks_cached`, where the source of truth lives elsewhere
    /// and is re-read periodically), recording `<name>_peak` like
    /// [`Metrics::gauge_add`] does.
    pub fn gauge_set(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        g.levels.insert(name.to_string(), value as i64);
        if value > 0 {
            let peak = g.gauges.entry(format!("{name}_peak")).or_insert(0);
            *peak = (*peak).max(value);
        }
    }

    /// Raise a counter to `value` if it is below it (no-op otherwise):
    /// reconciles a cumulative total kept elsewhere (per-variant prefix
    /// hit/evict counts summed under the router lock) into the registry
    /// idempotently — re-sampling never double-counts, and the counter
    /// stays monotone as Prometheus requires.
    pub fn counter_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    /// Current value of a level gauge (0 if never touched).
    pub fn level(&self, name: &str) -> i64 {
        self.inner.lock().unwrap().levels.get(name).copied().unwrap_or(0)
    }

    /// Ratio of two counters as a percentage string, `"n/a"` when the
    /// denominator is zero — the batch-occupancy readout
    /// (`sched_steps` over `sched_slots`) shared by the serve summary
    /// and the benches, so the derived metric has one definition.
    pub fn ratio_pct(&self, num: &str, den: &str) -> String {
        match self.counter(den) {
            0 => "n/a".to_string(),
            d => format!("{:.0}%",
                         100.0 * self.counter(num) as f64 / d as f64),
        }
    }

    pub fn observe(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.latencies.entry(name.to_string()).or_default()
            .push(d.as_secs_f64() * 1e6);
    }

    pub fn quantiles(&self, name: &str) -> Option<(f64, f64, f64)> {
        let g = self.inner.lock().unwrap();
        let mut v = g.latencies.get(name)?.clone();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| v[((v.len() as f64 - 1.0) * p) as usize];
        Some((q(0.50), q(0.95), q(0.99)))
    }

    pub fn count(&self, name: &str) -> usize {
        self.inner.lock().unwrap()
            .latencies.get(name).map(|v| v.len()).unwrap_or(0)
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (what `GET /metrics` serves). Counters become
    /// `latentllm_<name>_total`, high-water and level gauges become
    /// `latentllm_<name>` gauges, and each latency series becomes a
    /// summary with p50/p95/p99 quantiles plus `_count`/`_sum` (values
    /// are microseconds, as the `_us` metric names say). Everything is
    /// computed under one lock acquisition — the inner Mutex is not
    /// reentrant, so this must not call the public getters.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        }
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            let n = sanitize(k);
            out.push_str(&format!(
                "# TYPE latentllm_{n}_total counter\n\
                 latentllm_{n}_total {v}\n"));
        }
        for (k, v) in &g.gauges {
            let n = sanitize(k);
            out.push_str(&format!(
                "# TYPE latentllm_{n} gauge\nlatentllm_{n} {v}\n"));
        }
        for (k, v) in &g.levels {
            let n = sanitize(k);
            out.push_str(&format!(
                "# TYPE latentllm_{n} gauge\nlatentllm_{n} {v}\n"));
        }
        for (k, vals) in &g.latencies {
            if vals.is_empty() {
                continue;
            }
            let n = format!("latentllm_{}", sanitize(k));
            let mut v = vals.clone();
            v.sort_by(|a, b| a.total_cmp(b));
            let q = |p: f64| v[((v.len() as f64 - 1.0) * p) as usize];
            let sum: f64 = v.iter().sum();
            out.push_str(&format!("# TYPE {n} summary\n"));
            for (label, p) in [("0.5", 0.5), ("0.95", 0.95),
                               ("0.99", 0.99)] {
                out.push_str(&format!(
                    "{n}{{quantile=\"{label}\"}} {}\n", q(p)));
            }
            out.push_str(&format!("{n}_sum {sum}\n"));
            out.push_str(&format!("{n}_count {}\n", v.len()));
        }
        out
    }

    /// Render a human summary (the server prints this on shutdown).
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("  {k}: {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("  {k}: {v} (peak)\n"));
        }
        for (k, v) in &g.levels {
            if *v != 0 {
                out.push_str(&format!("  {k}: {v} (now)\n"));
            }
        }
        drop(g);
        let names: Vec<String> = {
            let g = self.inner.lock().unwrap();
            g.latencies.keys().cloned().collect()
        };
        for name in names {
            if let Some((p50, p95, p99)) = self.quantiles(&name) {
                out.push_str(&format!(
                    "  {name}: n={} p50={:.0}µs p95={:.0}µs p99={:.0}µs\n",
                    self.count(&name), p50, p95, p99));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = Metrics::new();
        m.incr("req", 3);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 5);
        for i in 1..=100u64 {
            m.observe("lat", Duration::from_micros(i));
        }
        let (p50, p95, p99) = m.quantiles("lat").unwrap();
        assert!((p50 - 50.0).abs() <= 2.0);
        assert!((p95 - 95.0).abs() <= 2.0);
        assert!((p99 - 99.0).abs() <= 2.0);
        assert!(m.quantiles("missing").is_none());
    }

    #[test]
    fn gauges_keep_the_high_water_mark() {
        let m = Metrics::new();
        m.set_max("cache_bytes", 100);
        m.set_max("cache_bytes", 40);
        m.set_max("cache_bytes", 250);
        assert_eq!(m.gauge("cache_bytes"), 250);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.summary().contains("cache_bytes: 250 (peak)"));
    }

    #[test]
    fn level_gauges_track_current_and_peak() {
        let m = Metrics::new();
        assert_eq!(m.level("queue"), 0);
        m.gauge_add("queue", 3);
        m.gauge_add("queue", 2);
        m.gauge_add("queue", -4);
        assert_eq!(m.level("queue"), 1);
        assert_eq!(m.gauge("queue_peak"), 5);
        m.gauge_add("queue", -1);
        assert_eq!(m.level("queue"), 0);
        assert_eq!(m.gauge("queue_peak"), 5, "peak survives the drain");
        assert!(m.summary().contains("queue_peak: 5 (peak)"));
        assert!(!m.summary().contains("queue: 0 (now)"),
                "zero levels stay out of the summary");
    }

    #[test]
    fn gauge_set_and_counter_max_reconcile_idempotently() {
        let m = Metrics::new();
        m.gauge_set("prefix_blocks_cached", 7);
        m.gauge_set("prefix_blocks_cached", 3);
        assert_eq!(m.level("prefix_blocks_cached"), 3,
                   "gauge_set is absolute, not max");
        assert_eq!(m.gauge("prefix_blocks_cached_peak"), 7);
        m.counter_max("prefix_hits", 5);
        m.counter_max("prefix_hits", 5); // re-sample: no double count
        m.counter_max("prefix_hits", 2); // stale sample: monotone
        assert_eq!(m.counter("prefix_hits"), 5);
        m.counter_max("prefix_hits", 9);
        assert_eq!(m.counter("prefix_hits"), 9);
        let text = m.render_prometheus();
        assert!(text.contains("latentllm_prefix_hits_total 9"));
        assert!(text.contains("latentllm_prefix_blocks_cached 3"));
    }

    #[test]
    fn renders_prometheus_text() {
        let m = Metrics::new();
        m.incr("requests", 3);
        m.set_max("cache_bytes_peak", 42);
        m.gauge_add("gen_queue_depth", 2);
        m.observe("request_us", Duration::from_micros(100));
        m.observe("request_us", Duration::from_micros(300));
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE latentllm_requests_total counter"));
        assert!(text.contains("latentllm_requests_total 3"));
        assert!(text.contains("latentllm_cache_bytes_peak 42"));
        assert!(text.contains("latentllm_gen_queue_depth 2"));
        assert!(text.contains("# TYPE latentllm_request_us summary"));
        assert!(text.contains("latentllm_request_us{quantile=\"0.5\"}"));
        assert!(text.contains("latentllm_request_us_count 2"));
        assert!(text.contains("latentllm_request_us_sum 400"));
        // the exposition format contract: every non-comment line is
        // exactly "name[{labels}] value" with a numeric value
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().expect("metric name");
            let val = parts.next().expect("metric value");
            assert!(parts.next().is_none(), "extra field in {line:?}");
            assert!(val.parse::<f64>().is_ok(), "value in {line:?}");
            assert!(name.starts_with("latentllm_"), "prefix in {line:?}");
        }
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
