//! Lightweight metrics registry: counters + latency histograms with
//! p50/p95/p99 summaries, shared across coordinator threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Vec<f64>>, // micros
    /// high-water gauges (e.g. peak cache bytes across workers)
    gauges: BTreeMap<String, u64>,
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Record a high-water mark: the gauge keeps the max value observed
    /// (cache bytes are sampled by every worker; the fleet peak is what
    /// capacity planning reads).
    pub fn set_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0)
    }

    pub fn observe(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.latencies.entry(name.to_string()).or_default()
            .push(d.as_secs_f64() * 1e6);
    }

    pub fn quantiles(&self, name: &str) -> Option<(f64, f64, f64)> {
        let g = self.inner.lock().unwrap();
        let mut v = g.latencies.get(name)?.clone();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| v[((v.len() as f64 - 1.0) * p) as usize];
        Some((q(0.50), q(0.95), q(0.99)))
    }

    pub fn count(&self, name: &str) -> usize {
        self.inner.lock().unwrap()
            .latencies.get(name).map(|v| v.len()).unwrap_or(0)
    }

    /// Render a human summary (the server prints this on shutdown).
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("  {k}: {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("  {k}: {v} (peak)\n"));
        }
        drop(g);
        let names: Vec<String> = {
            let g = self.inner.lock().unwrap();
            g.latencies.keys().cloned().collect()
        };
        for name in names {
            if let Some((p50, p95, p99)) = self.quantiles(&name) {
                out.push_str(&format!(
                    "  {name}: n={} p50={:.0}µs p95={:.0}µs p99={:.0}µs\n",
                    self.count(&name), p50, p95, p99));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = Metrics::new();
        m.incr("req", 3);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 5);
        for i in 1..=100u64 {
            m.observe("lat", Duration::from_micros(i));
        }
        let (p50, p95, p99) = m.quantiles("lat").unwrap();
        assert!((p50 - 50.0).abs() <= 2.0);
        assert!((p95 - 95.0).abs() <= 2.0);
        assert!((p99 - 99.0).abs() <= 2.0);
        assert!(m.quantiles("missing").is_none());
    }

    #[test]
    fn gauges_keep_the_high_water_mark() {
        let m = Metrics::new();
        m.set_max("cache_bytes", 100);
        m.set_max("cache_bytes", 40);
        m.set_max("cache_bytes", 250);
        assert_eq!(m.gauge("cache_bytes"), 250);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.summary().contains("cache_bytes: 250 (peak)"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
