//! L3 serving coordinator: request router, dynamic batcher, KV-cache
//! manager with MLA-aware accounting, worker pool over PJRT executables,
//! and a metrics registry — the vLLM-router-shaped stack the paper's
//! compressed models plug into (std::thread + mpsc; tokio is unavailable
//! offline, see DESIGN.md §2).

pub mod batcher;
pub mod kvcache;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kvcache::{CacheKind, KvCacheManager};
pub use metrics::Metrics;
pub use router::{ModelVariant, Router};
pub use server::{GenerateRequest, GenerateResponse, Server, ServerConfig};
