//! L3 serving coordinator: request router, dynamic batcher, paged
//! KV-cache manager with MLA-aware accounting, a step-level
//! continuous-batching scheduler, worker pool over pluggable backends,
//! and a metrics registry — the vLLM-router-shaped stack the paper's
//! compressed models plug into (std::thread + mpsc; tokio is unavailable
//! offline, see DESIGN.md §2).

pub mod batcher;
pub mod http;
pub mod kvcache;
pub mod metrics;
pub mod pages;
pub mod prefixcache;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod trace;

pub use batcher::{Batcher, BatcherConfig};
pub use http::{HttpConfig, HttpServer};
pub use kvcache::{CacheKind, KvCacheManager};
pub use metrics::Metrics;
pub use pages::PageAllocator;
pub use prefixcache::{PrefixCache, PrefixHit, PrefixStats};
pub use router::{ModelVariant, Router};
pub use scheduler::{SchedulerConfig, WorkerScheduler};
pub use server::{
    Drain, GenerateParams, Handle, Output, Request, Response,
    ScoreParams, ServeError, Server, ServerConfig,
};
pub use trace::{CompletedTrace, RequestTrace, Timings, TraceRing};
