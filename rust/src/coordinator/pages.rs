//! Paged KV-cache block allocator — the vLLM/PagedAttention-shaped
//! replacement for byte-counter admission.
//!
//! The pool is a fixed set of equal-sized physical blocks carved out of
//! the variant's byte budget. A block is sized in *tokens at the
//! variant's nominal per-layer byte-rate* (`block_tokens ×
//! bytes/token`), so for nominally-billed sequences it is exactly a
//! vLLM-style fixed-size token block; sequences billed at a different
//! real footprint ([`crate::coordinator::kvcache::KvCacheManager::admit_with`])
//! are charged byte-honestly — `ceil(tokens × rate / block_bytes)`
//! blocks — which is where the paper's differentiator shows up: a latent
//! layer's `r_k + r_v` floats/token pack many more tokens into each
//! block than a dense layer's `2·d`, so the same pool admits more live
//! latent sessions than dense ones.
//!
//! The allocator only *accounts* — the tensors live in each session's
//! [`crate::runtime::decode::DecodeState`] and are freed by dropping the
//! session. Invariants (each block owned by exactly one sequence or the
//! free list, no double-frees, churn conserves the pool) are enforced
//! structurally and re-checkable via [`PageAllocator::check_invariants`]
//! (property-tested in `tests/properties.rs`).

use std::collections::HashMap;

#[derive(Debug)]
struct SeqPages {
    blocks: Vec<u32>,
    tokens: usize,
    /// the byte-rate this sequence is billed at (admission rate; see
    /// `KvCacheManager::admit_with`)
    bytes_per_token: usize,
}

/// Fixed-pool block allocator with LIFO free-list reuse.
#[derive(Debug)]
pub struct PageAllocator {
    block_bytes: usize,
    total_blocks: usize,
    /// LIFO: the most recently freed block is handed out first, keeping
    /// hot blocks hot
    free: Vec<u32>,
    seqs: HashMap<u64, SeqPages>,
    blocks_in_use: usize,
    /// high-water mark of `blocks_in_use`, monotone
    pub peak_blocks: usize,
}

impl PageAllocator {
    /// Carve `budget_bytes` into blocks of `block_bytes` (the remainder
    /// is unusable, as in any paged pool).
    pub fn new(budget_bytes: usize, block_bytes: usize) -> PageAllocator {
        let block_bytes = block_bytes.max(1);
        let total_blocks = budget_bytes / block_bytes;
        // reversed so block 0 pops first (free-list pops from the back)
        let free: Vec<u32> = (0..total_blocks as u32).rev().collect();
        PageAllocator {
            block_bytes,
            total_blocks,
            free,
            seqs: HashMap::new(),
            blocks_in_use: 0,
            peak_blocks: 0,
        }
    }

    /// Blocks a sequence of `tokens` tokens at `bytes_per_token` needs.
    pub fn blocks_for(&self, tokens: usize, bytes_per_token: usize)
                      -> usize {
        let bytes = tokens * bytes_per_token;
        bytes.div_ceil(self.block_bytes)
    }

    /// Reserve blocks for `tokens` tokens at `bytes_per_token`. A live
    /// `seq_id` is replaced release-then-reserve (re-admission after
    /// preemption), so a stale reservation can never leak. Returns false
    /// — leaving the sequence unregistered — when the free list cannot
    /// cover it.
    pub fn admit(&mut self, seq_id: u64, tokens: usize,
                 bytes_per_token: usize) -> bool {
        self.release(seq_id);
        let need = self.blocks_for(tokens, bytes_per_token);
        if need > self.free.len() {
            return false;
        }
        let at = self.free.len() - need;
        let blocks = self.free.split_off(at);
        self.blocks_in_use += need;
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use);
        self.seqs.insert(seq_id,
                         SeqPages { blocks, tokens, bytes_per_token });
        true
    }

    /// Grow a sequence by one token, allocating a fresh block when it
    /// crosses a block boundary. Returns false — without touching the
    /// sequence — when the sequence is unknown or the pool has no free
    /// block; the *caller* decides between eviction and
    /// preemption-by-requeue.
    pub fn extend(&mut self, seq_id: u64) -> bool {
        let Some(s) = self.seqs.get_mut(&seq_id) else {
            return false;
        };
        let bpt = s.bytes_per_token;
        let need = (s.tokens + 1) * bpt;
        let have = s.blocks.len() * self.block_bytes;
        if need <= have {
            s.tokens += 1;
            return true;
        }
        let grow = (need - have).div_ceil(self.block_bytes);
        if grow > self.free.len() {
            return false;
        }
        let at = self.free.len() - grow;
        s.blocks.extend(self.free.drain(at..));
        s.tokens += 1;
        self.blocks_in_use += grow;
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use);
        true
    }

    /// Return every block a sequence holds to the free list. Unknown ids
    /// are a no-op — release is idempotent, so a double-release cannot
    /// double-free.
    pub fn release(&mut self, seq_id: u64) {
        if let Some(s) = self.seqs.remove(&seq_id) {
            self.blocks_in_use -= s.blocks.len();
            self.free.extend(s.blocks);
        }
    }

    /// Whether a sequence of `tokens` tokens at `bytes_per_token` could
    /// fit the pool even with every block free — the "can this request
    /// EVER run" admission pre-check that separates requeue-and-wait
    /// from reject-now.
    pub fn fits_total(&self, tokens: usize, bytes_per_token: usize) -> bool {
        self.blocks_for(tokens, bytes_per_token) <= self.total_blocks
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks_in_use
    }

    /// Bytes the in-use blocks pin (block-quantized — a page pool cannot
    /// hand out fractions of a block).
    pub fn used_bytes(&self) -> usize {
        self.blocks_in_use * self.block_bytes
    }

    /// Whether a sequence is currently registered.
    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    /// Blocks a live sequence currently holds (0 for unknown ids).
    pub fn blocks_of(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.blocks.len()).unwrap_or(0)
    }

    /// Tokens a live sequence is billed for (0 for unknown ids).
    pub fn tokens_of(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.tokens).unwrap_or(0)
    }

    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Exhaustive ownership audit: every block id in range, owned by
    /// exactly one sequence or the free list, and the pool conserved.
    /// O(total²) worst case — a test/debug tool, not a hot-path check.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_blocks];
        let mut own = |b: u32, who: &str| -> Result<(), String> {
            let i = b as usize;
            if i >= self.total_blocks {
                return Err(format!("{who} holds out-of-range block {b}"));
            }
            if seen[i] {
                return Err(format!("block {b} owned twice (second: {who})"));
            }
            seen[i] = true;
            Ok(())
        };
        for &b in &self.free {
            own(b, "free list")?;
        }
        for (id, s) in &self.seqs {
            for &b in &s.blocks {
                own(b, &format!("seq {id}"))?;
            }
            let need = self.blocks_for(s.tokens, s.bytes_per_token);
            if s.blocks.len() < need {
                return Err(format!(
                    "seq {id}: {} tokens at {} B/tok need {need} blocks \
                     but only {} are held",
                    s.tokens, s.bytes_per_token, s.blocks.len()));
            }
        }
        let owned = self.free.len() + self.blocks_in_use;
        if owned != self.total_blocks || seen.iter().any(|s| !s) {
            return Err(format!(
                "pool not conserved: {} free + {} in use != {} total",
                self.free.len(), self.blocks_in_use, self.total_blocks));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_in_block_granularity() {
        // 8 blocks of 64 B; at 16 B/token a block holds 4 tokens
        let mut p = PageAllocator::new(512, 64);
        assert_eq!(p.total_blocks(), 8);
        assert_eq!(p.blocks_for(4, 16), 1);
        assert_eq!(p.blocks_for(5, 16), 2);
        assert!(p.admit(1, 5, 16));
        assert_eq!(p.blocks_of(1), 2);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(p.used_bytes(), 128);
        // a 7th..8th token fits the held blocks; the 9th needs a third
        assert!(p.extend(1) && p.extend(1) && p.extend(1));
        assert_eq!(p.blocks_of(1), 2);
        assert!(p.extend(1));
        assert_eq!(p.blocks_of(1), 3);
        assert_eq!(p.tokens_of(1), 9);
        p.release(1);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.used_blocks(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_and_recovery() {
        let mut p = PageAllocator::new(256, 64); // 4 blocks
        assert!(p.admit(1, 4, 16)); // 1 block
        assert!(p.admit(2, 12, 16)); // 3 blocks
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.extend(1), "no free block: extend must refuse");
        assert_eq!(p.tokens_of(1), 4, "a refused extend changes nothing");
        assert!(!p.admit(3, 1, 16), "full pool refuses admission");
        assert!(p.blocks_of(3) == 0);
        p.release(2);
        assert!(p.admit(3, 8, 16));
        p.check_invariants().unwrap();
        assert!(!p.extend(99), "unknown sequences refuse");
    }

    #[test]
    fn latent_rate_packs_more_tokens_per_block() {
        // the paper's benefit (ii) in paging terms: at 1/4 the byte-rate
        // a latent sequence needs 1/4 the blocks for the same tokens
        let p = PageAllocator::new(4096, 256);
        assert_eq!(p.blocks_for(32, 64), 8); // dense-ish rate
        assert_eq!(p.blocks_for(32, 16), 2); // latent rate
        assert!(p.fits_total(64, 64));
        assert!(!p.fits_total(65, 64));
        assert!(p.fits_total(256, 16));
    }

    #[test]
    fn readmission_replaces_and_release_is_idempotent() {
        let mut p = PageAllocator::new(512, 64);
        assert!(p.admit(7, 16, 16)); // 4 blocks
        assert!(p.admit(7, 4, 16), "re-admission must release first");
        assert_eq!(p.blocks_of(7), 1);
        assert_eq!(p.used_blocks(), 1);
        p.release(7);
        p.release(7); // idempotent — no double-free
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn zero_block_pool_refuses_everything() {
        let mut p = PageAllocator::new(63, 64);
        assert_eq!(p.total_blocks(), 0);
        assert!(!p.admit(1, 1, 1));
        assert!(!p.fits_total(1, 1));
        assert!(p.admit(2, 0, 16), "an empty reservation needs no blocks");
        p.check_invariants().unwrap();
    }
}
