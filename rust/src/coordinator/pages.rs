//! Paged KV-cache block allocator — the vLLM/PagedAttention-shaped
//! replacement for byte-counter admission, now with refcounted sharing
//! and copy-on-write for the prefix cache.
//!
//! The pool is a fixed set of equal-sized physical blocks carved out of
//! the variant's byte budget. A block is sized in *tokens at the
//! variant's nominal per-layer byte-rate* (`block_tokens ×
//! bytes/token`), so for nominally-billed sequences it is exactly a
//! vLLM-style fixed-size token block; sequences billed at a different
//! real footprint ([`crate::coordinator::kvcache::KvCacheManager::admit_with`])
//! are charged byte-honestly — `ceil(tokens × rate / block_bytes)`
//! blocks — which is where the paper's differentiator shows up: a latent
//! layer's `r_k + r_v` floats/token pack many more tokens into each
//! block than a dense layer's `2·d`, so the same pool admits more live
//! latent sessions than dense ones.
//!
//! **Sharing and copy-on-write.** Since the prefix-cache PR a physical
//! block can be held by *several* sequences at once (a shared prompt
//! prefix): each block carries a refcount, shared admission
//! ([`PageAllocator::admit_shared`]) bumps it instead of allocating, and
//! `used` accounting counts each distinct block once — shared prefixes
//! cost the pool nothing beyond their single copy. Writes stay exclusive:
//! [`PageAllocator::extend`] never grows into a block with refcount > 1 —
//! it copy-on-write swaps in a private replacement first (the
//! `cow_clones` counter) — so a writer can never alias a shared block.
//!
//! **Two free lists.** Truly-free blocks live in an ordered set (lowest
//! id first, deterministic reuse). Blocks whose last reference was
//! released but whose content the prefix cache still indexes park on an
//! LRU *cached-free* list instead: a future prefix hit resurrects them
//! for free, and when the free set runs dry the allocator reclaims them
//! oldest-first, recording the reclaimed ids in
//! [`PageAllocator::take_reclaimed`] so the owner can drop the matching
//! prefix-cache entries. Cached prefixes therefore cost zero *reserved*
//! capacity — `fits_total` and admission see cached-free blocks as
//! available.
//!
//! The allocator only *accounts* — the tensors live in each session's
//! [`crate::runtime::decode::DecodeState`] and are freed by dropping the
//! session. Invariants (each block free XOR cached-free XOR refcounted,
//! refcounts equal to the number of holders, churn conserves the pool)
//! are enforced structurally and re-checkable via
//! [`PageAllocator::check_invariants`] (property-tested in
//! `tests/properties.rs`).

use std::collections::{BTreeSet, HashMap, VecDeque};

#[derive(Debug)]
struct SeqPages {
    blocks: Vec<u32>,
    tokens: usize,
    /// the byte-rate this sequence is billed at (admission rate; see
    /// `KvCacheManager::admit_with`)
    bytes_per_token: usize,
}

/// The ordered free structure: truly-free blocks (no content anyone
/// wants) in an ascending set, plus the LRU list of **cached-free**
/// blocks — refcount 0 but still indexed by the prefix cache, eligible
/// for resurrection or reclaim.
#[derive(Debug, Default)]
struct FreeLists {
    /// truly free, handed out lowest-id-first (deterministic)
    free: BTreeSet<u32>,
    /// refcount-0 blocks the prefix cache still indexes; front = least
    /// recently released = first reclaimed
    cached: VecDeque<u32>,
}

impl FreeLists {
    fn len(&self) -> usize {
        self.free.len() + self.cached.len()
    }
}

/// Fixed-pool block allocator with refcounted sharing, copy-on-write,
/// and a truly-free / cached-free split free structure.
#[derive(Debug)]
pub struct PageAllocator {
    block_bytes: usize,
    total_blocks: usize,
    lists: FreeLists,
    /// per-block holder count; free and cached-free blocks are 0
    refcount: Vec<u32>,
    /// per-block "the prefix cache indexes this content" flag —
    /// orthogonal to refcount (a donor still holds its cached blocks)
    cached: Vec<bool>,
    seqs: HashMap<u64, SeqPages>,
    /// distinct blocks with refcount ≥ 1 (shared blocks count once)
    blocks_in_use: usize,
    /// cached-free blocks reclaimed for fresh allocation since the last
    /// [`PageAllocator::take_reclaimed`] — the owner must forget their
    /// prefix-cache entries
    reclaimed: Vec<u32>,
    /// high-water mark of `blocks_in_use`, monotone
    pub peak_blocks: usize,
    /// copy-on-write clones performed by [`PageAllocator::extend`]
    pub cow_clones: u64,
}

impl PageAllocator {
    /// Carve `budget_bytes` into blocks of `block_bytes` (the remainder
    /// is unusable, as in any paged pool).
    pub fn new(budget_bytes: usize, block_bytes: usize) -> PageAllocator {
        let block_bytes = block_bytes.max(1);
        let total_blocks = budget_bytes / block_bytes;
        PageAllocator {
            block_bytes,
            total_blocks,
            lists: FreeLists {
                free: (0..total_blocks as u32).collect(),
                cached: VecDeque::new(),
            },
            refcount: vec![0; total_blocks],
            cached: vec![false; total_blocks],
            seqs: HashMap::new(),
            blocks_in_use: 0,
            reclaimed: Vec::new(),
            peak_blocks: 0,
            cow_clones: 0,
        }
    }

    /// Blocks a sequence of `tokens` tokens at `bytes_per_token` needs.
    pub fn blocks_for(&self, tokens: usize, bytes_per_token: usize)
                      -> usize {
        let bytes = tokens * bytes_per_token;
        bytes.div_ceil(self.block_bytes)
    }

    /// Blocks allocatable right now: truly free plus reclaimable
    /// cached-free.
    fn available(&self) -> usize {
        self.lists.len()
    }

    /// Take `n` blocks for fresh (exclusive) use: truly-free first, then
    /// reclaiming cached-free oldest-first — those ids are appended to
    /// the reclaim log for the owner to forget. Returns `None` without
    /// mutating when `n` exceeds what is available.
    fn take_free(&mut self, n: usize) -> Option<Vec<u32>> {
        if n > self.available() {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let b = match self.lists.free.pop_first() {
                Some(b) => b,
                None => {
                    let b = self.lists.cached.pop_front()
                        .expect("available() promised a block");
                    self.cached[b as usize] = false;
                    self.reclaimed.push(b);
                    b
                }
            };
            self.refcount[b as usize] = 1;
            out.push(b);
        }
        self.blocks_in_use += n;
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use);
        Some(out)
    }

    /// Drop one reference to `b`, parking it on the right free list when
    /// the count hits zero.
    fn unref(&mut self, b: u32) {
        let i = b as usize;
        debug_assert!(self.refcount[i] > 0, "unref of free block {b}");
        self.refcount[i] -= 1;
        if self.refcount[i] == 0 {
            self.blocks_in_use -= 1;
            if self.cached[i] {
                self.lists.cached.push_back(b);
            } else {
                self.lists.free.insert(b);
            }
        }
    }

    /// Reserve blocks for `tokens` tokens at `bytes_per_token`. A live
    /// `seq_id` is replaced release-then-reserve (re-admission after
    /// preemption), so a stale reservation can never leak. Returns false
    /// — leaving the sequence unregistered — when the pool cannot cover
    /// it even after reclaiming cached-free blocks.
    pub fn admit(&mut self, seq_id: u64, tokens: usize,
                 bytes_per_token: usize) -> bool {
        self.admit_shared(seq_id, tokens, bytes_per_token, &[])
    }

    /// [`PageAllocator::admit`] with the leading blocks *shared*: each id
    /// in `shared` must be a live or cached-free block (the prefix cache
    /// hands these out); its refcount is bumped — resurrecting it off the
    /// cached-free list if parked there — instead of allocating, and only
    /// the remainder is drawn from the free lists. Atomic: on false
    /// nothing changed. `shared` must not exceed the sequence's total
    /// block need and must not repeat ids.
    pub fn admit_shared(&mut self, seq_id: u64, tokens: usize,
                        bytes_per_token: usize, shared: &[u32]) -> bool {
        self.release(seq_id);
        let need = self.blocks_for(tokens, bytes_per_token);
        if shared.len() > need {
            return false;
        }
        for (i, &b) in shared.iter().enumerate() {
            let valid = (b as usize) < self.total_blocks
                && (self.refcount[b as usize] > 0
                    || self.cached[b as usize]);
            if !valid || shared[..i].contains(&b) {
                return false;
            }
        }
        // private remainder must not count resurrect-targets as
        // reclaimable — they are about to leave the cached-free list
        let resurrecting = shared.iter()
            .filter(|&&b| self.refcount[b as usize] == 0)
            .count();
        let private = need - shared.len();
        if private > self.available() - resurrecting.min(self.available()) {
            return false;
        }
        let mut blocks = Vec::with_capacity(need);
        for &b in shared {
            let i = b as usize;
            if self.refcount[i] == 0 {
                // resurrect off the cached-free list
                self.lists.cached.retain(|&x| x != b);
                self.blocks_in_use += 1;
            }
            self.refcount[i] += 1;
            blocks.push(b);
        }
        self.peak_blocks = self.peak_blocks.max(self.blocks_in_use);
        blocks.extend(self.take_free(private)
            .expect("availability checked above"));
        self.seqs.insert(seq_id,
                         SeqPages { blocks, tokens, bytes_per_token });
        true
    }

    /// Grow a sequence by one token, allocating a fresh block when it
    /// crosses a block boundary — and **copy-on-write unsharing** the
    /// write target first when the token lands in a block with
    /// refcount > 1 (a writer never aliases a shared block). Returns
    /// false — without touching the sequence — when the sequence is
    /// unknown or no block can be found; the *caller* decides between
    /// eviction and preemption-by-requeue.
    pub fn extend(&mut self, seq_id: u64) -> bool {
        let Some(s) = self.seqs.get(&seq_id) else {
            return false;
        };
        let bpt = s.bytes_per_token;
        let need = (s.tokens + 1) * bpt;
        let have = s.blocks.len() * self.block_bytes;
        if need <= have {
            // the new token lands in the last held block: COW it first
            // if it is shared (zero-rate sequences hold no blocks and
            // have nothing to unshare)
            let last = s.blocks.last().copied();
            if let Some(last) = last.filter(|&b| {
                self.refcount[b as usize] > 1
            }) {
                let Some(fresh) = self.take_free(1) else {
                    return false;
                };
                self.unref(last);
                let s = self.seqs.get_mut(&seq_id).expect("checked");
                *s.blocks.last_mut().expect("non-empty") = fresh[0];
                self.cow_clones += 1;
            }
            let s = self.seqs.get_mut(&seq_id).expect("checked");
            s.tokens += 1;
            return true;
        }
        let grow = (need - have).div_ceil(self.block_bytes);
        let Some(fresh) = self.take_free(grow) else {
            return false;
        };
        let s = self.seqs.get_mut(&seq_id).expect("checked");
        s.blocks.extend(fresh);
        s.tokens += 1;
        true
    }

    /// Drop a sequence's references. Exclusive blocks return to the free
    /// set (or the cached-free list, if the prefix cache indexes them);
    /// shared blocks just lose one holder. Unknown ids are a no-op —
    /// release is idempotent, so a double-release cannot double-free.
    pub fn release(&mut self, seq_id: u64) {
        if let Some(s) = self.seqs.remove(&seq_id) {
            for b in s.blocks {
                self.unref(b);
            }
        }
    }

    /// Flag a (held) block as indexed by the prefix cache: when its last
    /// reference drops it will park on the cached-free LRU list instead
    /// of the free set. False if the block is out of range or not held.
    pub fn mark_cached(&mut self, b: u32) -> bool {
        let i = b as usize;
        if i >= self.total_blocks || self.refcount[i] == 0 {
            return false;
        }
        self.cached[i] = true;
        true
    }

    /// The prefix cache no longer indexes `b`: clear the flag and, if
    /// the block was parked cached-free, move it to the free set.
    pub fn uncache(&mut self, b: u32) {
        let i = b as usize;
        if i >= self.total_blocks || !self.cached[i] {
            return;
        }
        self.cached[i] = false;
        if self.refcount[i] == 0 {
            self.lists.cached.retain(|&x| x != b);
            self.lists.free.insert(b);
        }
    }

    /// Drain the log of cached-free blocks reclaimed for fresh
    /// allocation since the last call — the owner must evict the
    /// matching prefix-cache entries (their content is gone).
    pub fn take_reclaimed(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.reclaimed)
    }

    /// Whether a sequence of `tokens` tokens at `bytes_per_token` could
    /// fit the pool even with every block free — the "can this request
    /// EVER run" admission pre-check that separates requeue-and-wait
    /// from reject-now.
    pub fn fits_total(&self, tokens: usize, bytes_per_token: usize) -> bool {
        self.blocks_for(tokens, bytes_per_token) <= self.total_blocks
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Truly-free blocks plus reclaimable cached-free blocks — what
    /// admission can actually draw on.
    pub fn free_blocks(&self) -> usize {
        self.available()
    }

    /// Blocks parked on the cached-free LRU list (refcount 0, content
    /// still indexed by the prefix cache).
    pub fn cached_free_blocks(&self) -> usize {
        self.lists.cached.len()
    }

    /// Blocks currently flagged as prefix-cache content (held or
    /// parked).
    pub fn cached_blocks(&self) -> usize {
        self.cached.iter().filter(|&&c| c).count()
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks_in_use
    }

    /// Bytes the in-use blocks pin (block-quantized — a page pool cannot
    /// hand out fractions of a block; shared blocks count once).
    pub fn used_bytes(&self) -> usize {
        self.blocks_in_use * self.block_bytes
    }

    /// Whether a sequence is currently registered.
    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    /// Blocks a live sequence currently holds (0 for unknown ids).
    pub fn blocks_of(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.blocks.len()).unwrap_or(0)
    }

    /// The physical block ids a live sequence holds, admission order.
    pub fn block_ids(&self, seq_id: u64) -> Option<&[u32]> {
        self.seqs.get(&seq_id).map(|s| s.blocks.as_slice())
    }

    /// The byte-rate a live sequence was admitted at (`None` for unknown
    /// ids) — block↔token alignment checks key off this.
    pub fn rate_of(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|s| s.bytes_per_token)
    }

    /// Tokens a live sequence is billed for (0 for unknown ids).
    pub fn tokens_of(&self, seq_id: u64) -> usize {
        self.seqs.get(&seq_id).map(|s| s.tokens).unwrap_or(0)
    }

    /// Holders of a physical block (0 = free or cached-free).
    pub fn refcount_of(&self, b: u32) -> u32 {
        self.refcount.get(b as usize).copied().unwrap_or(0)
    }

    pub fn active_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Exhaustive ownership audit: every block id in range and in
    /// exactly one state (truly free, cached-free, or refcounted by ≥ 1
    /// sequences), every refcount equal to the number of distinct
    /// holders, no sequence holding a block twice, free/cached-free
    /// blocks unreferenced, and the pool conserved
    /// (`free + cached_free + in_use == total`). O(total²) worst case —
    /// a test/debug tool, not a hot-path check.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut holders = vec![0u32; self.total_blocks];
        let mut on_free = vec![false; self.total_blocks];
        let park = |b: u32, who: &str, seen: &mut Vec<bool>|
                    -> Result<(), String> {
            let i = b as usize;
            if i >= self.total_blocks {
                return Err(format!("{who} holds out-of-range block {b}"));
            }
            if seen[i] {
                return Err(format!("block {b} on a free list twice \
                                    (second: {who})"));
            }
            seen[i] = true;
            Ok(())
        };
        for &b in &self.lists.free {
            park(b, "free set", &mut on_free)?;
            if self.cached[b as usize] {
                return Err(format!("truly-free block {b} still flagged \
                                    cached"));
            }
        }
        for &b in &self.lists.cached {
            park(b, "cached-free list", &mut on_free)?;
            if !self.cached[b as usize] {
                return Err(format!("cached-free block {b} not flagged \
                                    cached"));
            }
        }
        for (id, s) in &self.seqs {
            let mut held: Vec<u32> = Vec::with_capacity(s.blocks.len());
            for &b in &s.blocks {
                let i = b as usize;
                if i >= self.total_blocks {
                    return Err(format!("seq {id} holds out-of-range \
                                        block {b}"));
                }
                if held.contains(&b) {
                    return Err(format!("seq {id} holds block {b} twice"));
                }
                held.push(b);
                holders[i] += 1;
            }
            let need = self.blocks_for(s.tokens, s.bytes_per_token);
            if s.blocks.len() < need {
                return Err(format!(
                    "seq {id}: {} tokens at {} B/tok need {need} blocks \
                     but only {} are held",
                    s.tokens, s.bytes_per_token, s.blocks.len()));
            }
        }
        let mut in_use = 0usize;
        for i in 0..self.total_blocks {
            if holders[i] != self.refcount[i] {
                return Err(format!(
                    "block {i}: refcount {} but {} holders",
                    self.refcount[i], holders[i]));
            }
            match (holders[i] > 0, on_free[i]) {
                (true, true) => {
                    return Err(format!("block {i} both held and free"));
                }
                (false, false) => {
                    return Err(format!("block {i} leaked: neither held \
                                        nor on a free list"));
                }
                (true, false) => in_use += 1,
                (false, true) => {}
            }
        }
        if in_use != self.blocks_in_use {
            return Err(format!("blocks_in_use {} but {} blocks held",
                               self.blocks_in_use, in_use));
        }
        let owned = self.lists.free.len() + self.lists.cached.len()
            + in_use;
        if owned != self.total_blocks {
            return Err(format!(
                "pool not conserved: {} free + {} cached-free + {} in \
                 use != {} total",
                self.lists.free.len(), self.lists.cached.len(), in_use,
                self.total_blocks));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_in_block_granularity() {
        // 8 blocks of 64 B; at 16 B/token a block holds 4 tokens
        let mut p = PageAllocator::new(512, 64);
        assert_eq!(p.total_blocks(), 8);
        assert_eq!(p.blocks_for(4, 16), 1);
        assert_eq!(p.blocks_for(5, 16), 2);
        assert!(p.admit(1, 5, 16));
        assert_eq!(p.blocks_of(1), 2);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(p.used_bytes(), 128);
        // a 7th..8th token fits the held blocks; the 9th needs a third
        assert!(p.extend(1) && p.extend(1) && p.extend(1));
        assert_eq!(p.blocks_of(1), 2);
        assert!(p.extend(1));
        assert_eq!(p.blocks_of(1), 3);
        assert_eq!(p.tokens_of(1), 9);
        p.release(1);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.used_blocks(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_and_recovery() {
        let mut p = PageAllocator::new(256, 64); // 4 blocks
        assert!(p.admit(1, 4, 16)); // 1 block
        assert!(p.admit(2, 12, 16)); // 3 blocks
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.extend(1), "no free block: extend must refuse");
        assert_eq!(p.tokens_of(1), 4, "a refused extend changes nothing");
        assert!(!p.admit(3, 1, 16), "full pool refuses admission");
        assert!(p.blocks_of(3) == 0);
        p.release(2);
        assert!(p.admit(3, 8, 16));
        p.check_invariants().unwrap();
        assert!(!p.extend(99), "unknown sequences refuse");
    }

    #[test]
    fn latent_rate_packs_more_tokens_per_block() {
        // the paper's benefit (ii) in paging terms: at 1/4 the byte-rate
        // a latent sequence needs 1/4 the blocks for the same tokens
        let p = PageAllocator::new(4096, 256);
        assert_eq!(p.blocks_for(32, 64), 8); // dense-ish rate
        assert_eq!(p.blocks_for(32, 16), 2); // latent rate
        assert!(p.fits_total(64, 64));
        assert!(!p.fits_total(65, 64));
        assert!(p.fits_total(256, 16));
    }

    #[test]
    fn readmission_replaces_and_release_is_idempotent() {
        let mut p = PageAllocator::new(512, 64);
        assert!(p.admit(7, 16, 16)); // 4 blocks
        assert!(p.admit(7, 4, 16), "re-admission must release first");
        assert_eq!(p.blocks_of(7), 1);
        assert_eq!(p.used_blocks(), 1);
        p.release(7);
        p.release(7); // idempotent — no double-free
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn zero_block_pool_refuses_everything() {
        let mut p = PageAllocator::new(63, 64);
        assert_eq!(p.total_blocks(), 0);
        assert!(!p.admit(1, 1, 1));
        assert!(!p.fits_total(1, 1));
        assert!(p.admit(2, 0, 16), "an empty reservation needs no blocks");
        p.check_invariants().unwrap();
    }

    #[test]
    fn shared_admission_refcounts_and_bills_once() {
        let mut p = PageAllocator::new(512, 64); // 8 blocks, 4 tok/blk @16
        assert!(p.admit(1, 8, 16)); // 2 blocks, fully packed
        let shared: Vec<u32> = p.block_ids(1).unwrap().to_vec();
        // seq 2 shares both prefix blocks and adds 1 private block
        assert!(p.admit_shared(2, 12, 16, &shared));
        assert_eq!(p.blocks_of(2), 3);
        assert_eq!(p.used_blocks(), 3, "shared blocks count once");
        assert_eq!(p.refcount_of(shared[0]), 2);
        p.check_invariants().unwrap();
        // releasing one holder keeps the shared blocks alive
        p.release(1);
        assert_eq!(p.refcount_of(shared[0]), 1);
        assert_eq!(p.used_blocks(), 3);
        p.release(2);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn cow_unshares_before_the_write() {
        let mut p = PageAllocator::new(512, 64);
        assert!(p.admit(1, 6, 16)); // 2 blocks, second half-full
        let shared: Vec<u32> = p.block_ids(1).unwrap().to_vec();
        // seq 2 shares both blocks at the same token count: its next
        // token must land in the (shared, half-full) second block
        assert!(p.admit_shared(2, 6, 16, &shared));
        assert_eq!(p.refcount_of(shared[1]), 2);
        assert!(p.extend(2));
        assert_eq!(p.cow_clones, 1, "write into a shared block must COW");
        assert_eq!(p.refcount_of(shared[1]), 1, "old block back to one \
                                                 holder");
        let b2 = p.block_ids(2).unwrap().to_vec();
        assert_ne!(b2[1], shared[1], "writer got a private copy");
        assert_eq!(p.refcount_of(b2[1]), 1);
        p.check_invariants().unwrap();
        // a writer never aliases: growing past the boundary allocates
        // fresh private blocks, no COW needed
        assert!(p.extend(2) && p.extend(2));
        assert_eq!(p.cow_clones, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn cached_free_parks_resurrects_and_reclaims_lru() {
        let mut p = PageAllocator::new(256, 64); // 4 blocks
        assert!(p.admit(1, 8, 16)); // blocks 0,1 (full at 4 tok/blk)
        let blocks: Vec<u32> = p.block_ids(1).unwrap().to_vec();
        assert!(p.mark_cached(blocks[0]) && p.mark_cached(blocks[1]));
        p.release(1);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.cached_free_blocks(), 2);
        assert_eq!(p.free_blocks(), 4, "cached-free is still available");
        p.check_invariants().unwrap();
        // resurrect: a shared admission pulls them off the LRU list
        assert!(p.admit_shared(2, 8, 16, &blocks));
        assert_eq!(p.cached_free_blocks(), 0);
        assert_eq!(p.used_blocks(), 2);
        assert!(p.take_reclaimed().is_empty(), "resurrection is not \
                                                reclaim");
        p.release(2);
        // reclaim: a big exclusive admission must eat the cached-free
        // list oldest-first and log it
        assert!(p.admit(3, 16, 16)); // all 4 blocks
        let mut reclaimed = p.take_reclaimed();
        reclaimed.sort_unstable();
        assert_eq!(reclaimed, blocks, "cached-free content was \
                                       reclaimed");
        assert_eq!(p.cached_blocks(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn uncache_moves_parked_blocks_to_the_free_set() {
        let mut p = PageAllocator::new(256, 64);
        assert!(p.admit(1, 4, 16));
        let b = p.block_ids(1).unwrap()[0];
        assert!(p.mark_cached(b));
        p.release(1);
        assert_eq!(p.cached_free_blocks(), 1);
        p.uncache(b);
        assert_eq!(p.cached_free_blocks(), 0);
        assert_eq!(p.cached_blocks(), 0);
        assert!(p.take_reclaimed().is_empty(), "uncache is an owner \
                                                eviction, not a reclaim");
        p.check_invariants().unwrap();
        assert!(!p.mark_cached(b), "free blocks cannot be marked cached");
        assert!(!p.mark_cached(999));
    }

    #[test]
    fn shared_admission_is_atomic_on_failure() {
        let mut p = PageAllocator::new(256, 64); // 4 blocks
        assert!(p.admit(1, 8, 16));
        let shared: Vec<u32> = p.block_ids(1).unwrap().to_vec();
        assert!(p.admit(2, 8, 16)); // pool now full
        // needs 2 shared + 2 private but 0 are available
        assert!(!p.admit_shared(3, 16, 16, &shared));
        assert_eq!(p.refcount_of(shared[0]), 1, "failed shared admission \
                                                 must not leak refs");
        assert!(!p.contains(3));
        p.check_invariants().unwrap();
        // invalid shared lists are refused outright
        assert!(!p.admit_shared(3, 16, 16, &[99]));
        assert!(!p.admit_shared(3, 16, 16,
                                &[shared[0], shared[0], shared[1]]));
        assert!(!p.admit_shared(3, 4, 16, &shared),
                "more shared blocks than the request needs");
        p.check_invariants().unwrap();
    }
}
