//! Content-addressed prefix cache over the paged KV allocator.
//!
//! Identical prompt prefixes (system prompts, few-shot templates) are the
//! dominant sharing pattern at serving scale, and the paper's latent
//! cache makes each shared block r_k+r_v-sized instead of 2·d — so
//! sharing multiplies the compression win rather than sitting beside it.
//! This module addresses *full* KV blocks by a chain hash of their token
//! ids: block i's key folds block i-1's key over block i's tokens, so a
//! prefix of `n` full blocks is `n` chained entries and lookup walks the
//! chain until the first miss. Keying is per-variant by construction
//! (each [`crate::coordinator::kvcache::KvCacheManager`] owns one
//! `PrefixCache`), so dense and latent pools never alias.
//!
//! The cache stores two things per entry: the *physical block id* in the
//! owning [`crate::coordinator::pages::PageAllocator`] (for refcounted
//! billing) and an immutable [`PrefixSnapshot`] of the block's actual
//! cache rows (for seeding fresh sessions without a forward pass). Hash
//! collisions are survivable: entries keep their token ids and lookup
//! verifies them block-for-block.
//!
//! Lifecycle: a donated block is flagged "cached" in the allocator.
//! While any session still references it, hits simply bump its refcount.
//! When the last reference drops, the allocator parks it on the LRU
//! cached-free list — still servable, zero reserved capacity. If the
//! allocator later reclaims it under pressure, the owner calls
//! [`PrefixCache::forget_block`], which cascades to every descendant
//! entry (a child whose parent is gone could never be reached by a
//! lookup walk anyway).

use std::collections::HashMap;
use std::sync::Arc;

use crate::runtime::decode::PrefixSnapshot;

/// FNV-1a offset basis — the chain key of the empty prefix.
const ROOT_KEY: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one block of token ids into its parent's chain key (FNV-1a over
/// the parent key's bytes then each token's little-endian bytes).
pub fn chain_key(parent: u64, block: &[i32]) -> u64 {
    let mut h = ROOT_KEY;
    for b in parent.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for t in block {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// One cached full block: its position in the chain, the tokens it
/// covers (collision guard), its physical allocator block, and the
/// actual cache rows sessions adopt.
struct Entry {
    parent: u64,
    tokens: Vec<i32>,
    block: u32,
    data: Arc<PrefixSnapshot>,
}

/// A successful lookup: the longest cached prefix of the probed tokens,
/// as whole blocks. `blocks` bill against the allocator (shared,
/// refcounted); `snaps` seed the session's cache tensors.
pub struct PrefixHit {
    /// tokens covered (`blocks.len() × block_tokens`)
    pub tokens: usize,
    pub blocks: Vec<u32>,
    pub snaps: Vec<Arc<PrefixSnapshot>>,
}

/// Aggregate effectiveness counters, sampled into the metrics registry
/// by the server (see `sample_cache_peaks`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub inserts: u64,
    pub saved_tokens: u64,
    pub cached_blocks: u64,
}

impl PrefixStats {
    /// Reconcile these counters into the registry as one labeled series
    /// per cache (`latentllm_prefix_hits_total{variant="dense"}`, ...).
    /// The caches are the source of truth, so each value is raised
    /// monotonically — re-publishing an older snapshot is a no-op and
    /// periodic sampling never double-counts.
    pub fn publish(&self, variant: &str,
                   metrics: &crate::coordinator::metrics::Metrics) {
        let l: &[(&str, &str)] = &[("variant", variant)];
        metrics.counter_max_with("prefix_hits", l, self.hits);
        metrics.counter_max_with("prefix_misses", l, self.misses);
        metrics.counter_max_with("prefix_evictions", l, self.evictions);
        metrics.counter_max_with("prefix_inserts", l, self.inserts);
        metrics.counter_max_with("prefix_saved_tokens", l,
                                 self.saved_tokens);
    }
}

pub struct PrefixCache {
    block_tokens: usize,
    entries: HashMap<u64, Entry>,
    /// physical block → chain key (reclaim notifications arrive by block)
    by_block: HashMap<u32, u64>,
    /// chain key → child keys (cascade eviction walks down)
    children: HashMap<u64, Vec<u64>>,
    /// admissions that reused ≥ 1 cached block
    pub hits: u64,
    /// prefix-enabled admissions that reused nothing
    pub misses: u64,
    /// entries dropped because the allocator reclaimed their block
    pub evictions: u64,
    /// entries created by donation
    pub inserts: u64,
    /// prefill tokens skipped via adoption
    pub saved_tokens: u64,
}

impl std::fmt::Debug for PrefixCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixCache")
            .field("block_tokens", &self.block_tokens)
            .field("entries", &self.entries.len())
            .field("hits", &self.hits)
            .field("misses", &self.misses)
            .field("evictions", &self.evictions)
            .finish()
    }
}

impl PrefixCache {
    pub fn new(block_tokens: usize) -> PrefixCache {
        PrefixCache {
            block_tokens: block_tokens.max(1),
            entries: HashMap::new(),
            by_block: HashMap::new(),
            children: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
            inserts: 0,
            saved_tokens: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Live cached entries (== physical blocks carrying prefix content).
    pub fn cached_blocks(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            inserts: self.inserts,
            saved_tokens: self.saved_tokens,
            cached_blocks: self.entries.len() as u64,
        }
    }

    /// Walk the chain from the root: how many *full blocks* of `tokens`
    /// are cached, stopping at the first miss and at `max_blocks`.
    fn matched(&self, tokens: &[i32], max_blocks: usize) -> Vec<u64> {
        let bt = self.block_tokens;
        let mut keys = Vec::new();
        let mut parent = ROOT_KEY;
        while keys.len() < max_blocks {
            let lo = keys.len() * bt;
            if lo + bt > tokens.len() {
                break;
            }
            let block = &tokens[lo..lo + bt];
            let key = chain_key(parent, block);
            match self.entries.get(&key) {
                // collision guard: the key must describe these tokens
                Some(e) if e.parent == parent && e.tokens == block => {
                    keys.push(key);
                    parent = key;
                }
                _ => break,
            }
        }
        keys
    }

    /// Longest cached prefix of `tokens`, capped at `cap_tokens` (the
    /// caller passes `feed_len - 1` so at least one token always runs
    /// forward to produce logits). Pure — effectiveness counters are
    /// bumped by the owner once the admission actually succeeds.
    pub fn lookup(&self, tokens: &[i32], cap_tokens: usize) -> Option<PrefixHit> {
        let keys = self.matched(tokens, cap_tokens / self.block_tokens);
        if keys.is_empty() {
            return None;
        }
        let mut blocks = Vec::with_capacity(keys.len());
        let mut snaps = Vec::with_capacity(keys.len());
        for k in &keys {
            let e = &self.entries[k];
            blocks.push(e.block);
            snaps.push(e.data.clone());
        }
        Some(PrefixHit { tokens: keys.len() * self.block_tokens, blocks, snaps })
    }

    /// Full blocks of `tokens` already cached (donation skip probe —
    /// no point re-exporting rows the cache already holds).
    pub fn matched_tokens(&self, tokens: &[i32]) -> usize {
        self.matched(tokens, usize::MAX).len() * self.block_tokens
    }

    /// Donate: create entries for every *full* block of `tokens` not
    /// already cached, backing block i with physical block `blocks[i]`
    /// and rows `snap[i·bt, (i+1)·bt)`. Existing entries are skipped
    /// (donation is idempotent; concurrent donors converge). Returns the
    /// physical blocks newly carrying cache content — the caller flags
    /// them in the allocator.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[u32],
                  snap: &PrefixSnapshot) -> Vec<u32> {
        let bt = self.block_tokens;
        let n = (tokens.len() / bt)
            .min(blocks.len())
            .min(snap.tokens / bt);
        let mut newly = Vec::new();
        let mut parent = ROOT_KEY;
        for i in 0..n {
            let chunk = &tokens[i * bt..(i + 1) * bt];
            let key = chain_key(parent, chunk);
            match self.entries.get(&key) {
                Some(e) if e.parent == parent && e.tokens == chunk => {}
                Some(_) => break, // hash collision: stop, don't overwrite
                None => {
                    // one physical block can't back two entries
                    if self.by_block.contains_key(&blocks[i]) {
                        break;
                    }
                    self.entries.insert(key, Entry {
                        parent,
                        tokens: chunk.to_vec(),
                        block: blocks[i],
                        data: Arc::new(snap.slice_tokens(i * bt, (i + 1) * bt)),
                    });
                    self.by_block.insert(blocks[i], key);
                    self.children.entry(parent).or_default().push(key);
                    self.inserts += 1;
                    newly.push(blocks[i]);
                }
            }
            parent = key;
        }
        newly
    }

    /// The allocator reclaimed physical block `b`: drop its entry and
    /// every descendant (they are unreachable once their ancestor is
    /// gone). Returns the *other* physical blocks whose entries died, so
    /// the caller can clear their cached flag.
    pub fn forget_block(&mut self, b: u32) -> Vec<u32> {
        let Some(root) = self.by_block.remove(&b) else {
            return Vec::new();
        };
        let mut stack = vec![root];
        let mut orphaned = Vec::new();
        while let Some(key) = stack.pop() {
            if let Some(e) = self.entries.remove(&key) {
                self.evictions += 1;
                if e.block != b {
                    self.by_block.remove(&e.block);
                    orphaned.push(e.block);
                }
            }
            if let Some(kids) = self.children.remove(&key) {
                stack.extend(kids);
            }
        }
        // the root's parent still lists it as a child; leave the stale
        // key — cascade walks tolerate missing entries (see above)
        orphaned
    }

    /// Every physical block currently backing an entry (used when the
    /// cache is switched off mid-flight, to unflag them all).
    pub fn all_blocks(&self) -> Vec<u32> {
        self.by_block.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::decode::LayerCache;
    use crate::Matrix;

    /// Snapshot whose single dense layer encodes each token's position —
    /// block slices stay distinguishable after round-trips.
    fn snap_for(tokens: &[i32]) -> PrefixSnapshot {
        let n = tokens.len();
        PrefixSnapshot {
            tokens: n,
            layers: vec![LayerCache::Dense {
                k: Matrix::from_fn(n, 2, |r, c| tokens[r] as f64 * 10.0
                                                + c as f64),
                v: Matrix::from_fn(n, 2, |r, _| r as f64),
            }],
        }
    }

    #[test]
    fn stats_publish_as_labeled_monotone_counters() {
        let m = crate::coordinator::metrics::Metrics::new();
        let st = PrefixStats { hits: 3, misses: 1, evictions: 0,
                               inserts: 2, saved_tokens: 8,
                               cached_blocks: 2 };
        st.publish("dense", &m);
        // a stale (smaller) snapshot never regresses the series
        PrefixStats { hits: 2, ..st }.publish("dense", &m);
        let l: &[(&str, &str)] = &[("variant", "dense")];
        assert_eq!(m.counter_with("prefix_hits", l), 3);
        assert_eq!(m.counter_with("prefix_saved_tokens", l), 8);
        // other variants are independent series
        assert_eq!(m.counter_with("prefix_hits",
                                  &[("variant", "latent30")]), 0);
    }

    #[test]
    fn chain_keys_separate_prefixes_and_positions() {
        let a = chain_key(ROOT_KEY, &[1, 2]);
        let b = chain_key(ROOT_KEY, &[2, 1]);
        assert_ne!(a, b, "order must matter");
        // the same block under different parents gets different keys
        assert_ne!(chain_key(a, &[5, 6]), chain_key(b, &[5, 6]));
    }

    #[test]
    fn lookup_walks_the_chain_and_respects_the_cap() {
        let mut c = PrefixCache::new(2);
        let toks = [10, 11, 12, 13, 14, 15];
        let newly = c.insert(&toks, &[7, 8, 9], &snap_for(&toks));
        assert_eq!(newly, vec![7, 8, 9]);
        assert_eq!(c.cached_blocks(), 3);

        // full hit capped at feed_len-1 = 5 tokens → 2 blocks
        let hit = c.lookup(&toks, 5).unwrap();
        assert_eq!(hit.tokens, 4);
        assert_eq!(hit.blocks, vec![7, 8]);
        assert_eq!(hit.snaps[1].tokens, 2);

        // diverging third block stops the walk after two
        let div = [10, 11, 12, 13, 99, 15];
        let hit = c.lookup(&div, 6).unwrap();
        assert_eq!(hit.blocks, vec![7, 8]);

        // diverging first block is a clean miss
        assert!(c.lookup(&[99, 11, 12, 13], 4).is_none());
        // shorter than one block: nothing to match
        assert!(c.lookup(&[10], 1).is_none());
        assert_eq!(c.matched_tokens(&toks), 6);
        assert_eq!(c.matched_tokens(&div), 4);
    }

    #[test]
    fn insert_is_idempotent_and_partial_overlap_extends() {
        let mut c = PrefixCache::new(2);
        let toks = [1, 2, 3, 4];
        assert_eq!(c.insert(&toks, &[0, 1], &snap_for(&toks)).len(), 2);
        // same donation again: nothing new
        assert!(c.insert(&toks, &[0, 1], &snap_for(&toks)).is_empty());
        // a longer prompt sharing the prefix adds only the tail block
        let longer = [1, 2, 3, 4, 5, 6];
        let newly = c.insert(&longer, &[0, 1, 5], &snap_for(&longer));
        assert_eq!(newly, vec![5]);
        assert_eq!(c.cached_blocks(), 3);
        assert_eq!(c.stats().inserts, 3);
        // trailing partial block is never cached
        let odd = [1, 2, 3, 4, 5, 6, 7];
        assert!(c.insert(&odd, &[0, 1, 5, 6], &snap_for(&odd)).is_empty());
    }

    #[test]
    fn forget_block_cascades_to_descendants() {
        let mut c = PrefixCache::new(2);
        let toks = [1, 2, 3, 4, 5, 6];
        c.insert(&toks, &[10, 11, 12], &snap_for(&toks));
        // a sibling branch off the first block survives the cascade
        let branch = [1, 2, 7, 8];
        c.insert(&branch, &[10, 13], &snap_for(&branch));
        assert_eq!(c.cached_blocks(), 4);

        // reclaiming the *second* block orphans only its descendant
        let mut orphans = c.forget_block(11);
        orphans.sort_unstable();
        assert_eq!(orphans, vec![12]);
        assert_eq!(c.cached_blocks(), 2);
        assert_eq!(c.stats().evictions, 2);
        assert!(c.lookup(&toks, 6).unwrap().blocks == vec![10],
                "first block still serves");
        assert_eq!(c.lookup(&branch, 4).unwrap().blocks, vec![10, 13]);

        // reclaiming the root takes the whole tree
        let mut orphans = c.forget_block(10);
        orphans.sort_unstable();
        assert_eq!(orphans, vec![13]);
        assert_eq!(c.cached_blocks(), 0);
        assert!(c.lookup(&branch, 4).is_none());
        // unknown block is a no-op
        assert!(c.forget_block(99).is_empty());
    }

    #[test]
    fn snapshots_survive_the_cache_bit_identical() {
        let mut c = PrefixCache::new(2);
        let toks = [3, 1, 4, 1];
        let snap = snap_for(&toks);
        c.insert(&toks, &[0, 1], &snap);
        let hit = c.lookup(&toks, 4).unwrap();
        let whole = PrefixSnapshot::concat(&hit.snaps).unwrap();
        assert_eq!(whole.tokens, 4);
        match (&whole.layers[0], &snap.layers[0]) {
            (LayerCache::Dense { k: a, v: b },
             LayerCache::Dense { k: c2, v: d }) => {
                assert_eq!(a, c2);
                assert_eq!(b, d);
            }
            _ => unreachable!(),
        }
    }
}
