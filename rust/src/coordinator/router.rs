//! Request router over model variants (dense MHA vs compressed MLA).
//!
//! The paper's serving payoff: the latent variant's KV cache is a fraction
//! of the dense one's, so under memory pressure the cache-aware policy
//! keeps admitting requests to the latent variant long after dense is
//! saturated. Policies are deterministic and unit-tested.

use std::sync::Arc;

use super::kvcache::KvCacheManager;

/// One deployable model variant. Weights are `Arc`-shared so every server
/// worker executes against the same read-only tensor set without holding
/// the router lock across an execution.
pub struct ModelVariant {
    pub name: String,
    /// program name for scoring (e.g. "score_opt-mini-m")
    pub score_program: String,
    /// program name for incremental decode sessions
    /// (e.g. "step_opt-mini-m" / "latent_step_<tag>")
    pub step_program: String,
    pub weights: Arc<crate::model::Weights>,
    pub cache: KvCacheManager,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    /// prefer the latent variant while it has cache headroom
    PreferLatent,
    /// pick the variant with the most free cache tokens
    CacheAware,
}

pub struct Router {
    pub variants: Vec<ModelVariant>,
    policy: Policy,
    rr_next: usize,
}

impl Router {
    pub fn new(variants: Vec<ModelVariant>, policy: Policy) -> Self {
        Router { variants, policy, rr_next: 0 }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Choose a variant index for a request of `tokens` length; accounts
    /// the admission in the chosen variant's cache. None = all saturated.
    pub fn route(&mut self, seq_id: u64, tokens: usize) -> Option<usize> {
        self.route_excluding(seq_id, tokens, &[])
    }

    /// [`Router::route`] skipping `excluded` variant indices — the
    /// scheduler uses it to re-route a request whose *real* session
    /// footprint proved too large for a pool it was previously placed
    /// on, instead of bouncing against that pool forever.
    pub fn route_excluding(&mut self, seq_id: u64, tokens: usize,
                           excluded: &[usize]) -> Option<usize> {
        let n = self.variants.len();
        if n == 0 {
            return None;
        }
        let order: Vec<usize> = match self.policy {
            Policy::RoundRobin => {
                let s = self.rr_next;
                self.rr_next = (self.rr_next + 1) % n;
                (0..n).map(|i| (s + i) % n).collect()
            }
            Policy::PreferLatent => {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by_key(|&i| {
                    // latent variants have smaller bytes/token: first
                    self.variants[i].cache.bytes_per_token()
                });
                idx
            }
            Policy::CacheAware => {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by_key(|&i| {
                    // free-list headroom in nominal tokens: the paged
                    // equivalent of capacity minus used
                    std::cmp::Reverse(self.variants[i].cache.free_tokens())
                });
                idx
            }
        };
        for i in order {
            if excluded.contains(&i) {
                continue;
            }
            if self.variants[i].cache.admit(seq_id, tokens) {
                return Some(i);
            }
        }
        None
    }

    pub fn release(&mut self, variant: usize, seq_id: u64) {
        self.variants[variant].cache.release(seq_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kvcache::CacheKind;
    use crate::model::io::TensorMap;
    use crate::model::Weights;

    fn variant(name: &str, kind: CacheKind, budget: usize) -> ModelVariant {
        ModelVariant {
            name: name.into(),
            score_program: format!("score_{name}"),
            step_program: format!("step_{name}"),
            weights: Arc::new(Weights::new(TensorMap::new())),
            cache: KvCacheManager::new(kind, 4, 2, budget),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let vs = vec![
            variant("a", CacheKind::Dense { d: 64 }, 1 << 22),
            variant("b", CacheKind::Dense { d: 64 }, 1 << 22),
        ];
        let mut r = Router::new(vs, Policy::RoundRobin);
        let picks: Vec<usize> =
            (0..4).map(|i| r.route(i, 16).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn prefer_latent_routes_to_smaller_cache_cost() {
        let vs = vec![
            variant("dense", CacheKind::Dense { d: 128 }, 1 << 22),
            variant("latent", CacheKind::Latent { rk: 32, rv: 32 }, 1 << 22),
        ];
        let mut r = Router::new(vs, Policy::PreferLatent);
        let idx = r.route(0, 16).unwrap();
        assert_eq!(r.variants[idx].name, "latent");
    }

    #[test]
    fn cache_aware_spreads_and_saturates() {
        // two variants with capacity for 2×16-token requests each:
        // cache-aware admission must spread 4 requests across both, then
        // reject the 5th.
        let cap2 = |kind: CacheKind| {
            let m = KvCacheManager::new(kind, 4, 2, 0);
            let bpt = m.bytes_per_token();
            bpt * 16 * 2
        };
        let vs = vec![
            variant_with_budget("a", CacheKind::Dense { d: 64 },
                                cap2(CacheKind::Dense { d: 64 })),
            variant_with_budget("b", CacheKind::Latent { rk: 8, rv: 8 },
                                cap2(CacheKind::Latent { rk: 8, rv: 8 })),
        ];
        let mut r = Router::new(vs, Policy::CacheAware);
        let mut hits = std::collections::BTreeMap::new();
        for i in 0..4u64 {
            let idx = r.route(i, 16).expect("capacity remains");
            *hits.entry(r.variants[idx].name.clone()).or_insert(0) += 1;
        }
        assert_eq!(hits.get("a"), Some(&2));
        assert_eq!(hits.get("b"), Some(&2));
        assert!(r.route(99, 16).is_none(), "all saturated");
    }

    fn variant_with_budget(name: &str, kind: CacheKind, budget: usize)
                           -> ModelVariant {
        variant(name, kind, budget)
    }

    #[test]
    fn route_excluding_skips_named_variants() {
        let vs = vec![
            variant("a", CacheKind::Dense { d: 64 }, 1 << 22),
            variant("b", CacheKind::Dense { d: 64 }, 1 << 22),
        ];
        let mut r = Router::new(vs, Policy::RoundRobin);
        // round-robin would pick 0 first; exclusion forces 1
        assert_eq!(r.route_excluding(0, 16, &[0]), Some(1));
        assert_eq!(r.route_excluding(1, 16, &[1]), Some(0));
        assert_eq!(r.route_excluding(2, 16, &[0, 1]), None,
                   "everything excluded routes nowhere");
    }

    #[test]
    fn all_saturated_returns_none() {
        let vs = vec![variant("tiny", CacheKind::Dense { d: 64 }, 64)];
        let mut r = Router::new(vs, Policy::RoundRobin);
        assert!(r.route(0, 1000).is_none());
    }
}
