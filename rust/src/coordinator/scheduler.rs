//! Step-level continuous-batching scheduler: Orca-style iteration
//! scheduling over the paged latent KV cache.
//!
//! The sequential decode path (PR 4) runs one generate request to
//! completion per worker — a long decode monopolizes its worker and
//! mixed traffic queues behind it. Here each worker instead keeps a
//! *live session set* and pulls **scheduler iterations**: every
//! iteration admits waiting requests from the shared [`SchedQueue`]
//! (pages reserved on the routed variant's paged
//! [`super::kvcache::KvCacheManager`]), feeds at most one prefill chunk
//! per not-yet-ready sequence, forms one mixed batch of single-token
//! decode steps for every ready sequence, and runs it through the
//! worker's [`BatchedDecodeState`]. Score batches keep flowing between
//! iterations on the same worker.
//!
//! **Preemption-by-eviction.** When a decode step cannot reserve its
//! next cache block, the newest live sequence *on the refusing
//! variant's pool* is preempted (releasing another variant's pages
//! would free nothing in the pool that refused): its
//! session (and the cache tensors inside) is dropped, its pages return
//! to the free list, and its request — with the tokens generated so far
//! and its sampling RNG state — is requeued at the queue head to resume
//! later by re-prefilling `prompt ++ generated`. Nothing errors unless
//! a request could never fit the pool even when empty. Because cached
//! decode is bit-identical to recompute (`runtime::refbackend`), and
//! each request samples from its own seeded RNG, the token stream is
//! **identical to the sequential path** regardless of batch composition
//! or how many preempt→requeue→resume cycles a request survives
//! (pinned by `tests/decode.rs`).
//!
//! Each sampled token is pushed to the task's optional stream sender at
//! the single sampling site — exactly once per token, because resume
//! re-prefills the already-generated suffix without re-sampling it.
//!
//! **Prefix cache.** Admission probes the variant's content-addressed
//! prefix cache (`super::prefixcache`): the longest cached full-block
//! prefix of `prompt ++ generated` is billed as *shared* pages and its
//! rows are adopted into the fresh session, so the prefill feed starts
//! at the cache boundary. When a feed completes, the prompt's full
//! blocks are donated back (idempotently). Token streams stay identical
//! to the sequential path because adopted rows are bit-identical to what
//! a cold prefill would compute — the same cached-decode identity the
//! preemption story rests on — and shared pages are copy-on-write
//! underneath (`super::pages`), so one request's decode can never
//! scribble on another's prefix.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::kvcache::DEFAULT_BLOCK_TOKENS;
use super::metrics::Metrics;
use super::router::Router;
use super::server::{sample_cache_peaks, GenerateOutput, GenerateParams,
                    Output, Response, ServeError};
use super::trace::{RequestTrace, TraceRing};
use crate::eval::generate::pick_token;
use crate::runtime::decode::{BatchedDecodeState, PrefixSnapshot};
use crate::runtime::Engine;
use crate::util::lock_unpoisoned;
use crate::util::rng::Rng;

/// Continuous-batching knobs (`latentllm serve --sched-*`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedulerConfig {
    /// live decode sessions per worker — the iteration's batch width
    pub max_live: usize,
    /// page size in tokens at each variant's nominal byte-rate. NOTE:
    /// this is a *pool-construction* parameter — pass it to
    /// [`super::kvcache::KvCacheManager::with_block_tokens`] when
    /// building the variants (as `latentllm serve` does); the scheduler
    /// loop itself reads only `max_live` and `prefill_chunk`, so a
    /// value that disagrees with the caches silently does nothing
    pub block_tokens: usize,
    /// max prompt/resume tokens fed per sequence per iteration, so one
    /// giant prefill cannot starve its batch-mates' decode steps
    pub prefill_chunk: usize,
    /// fuse the per-iteration step batch into one shared-weight forward
    /// when every live slot runs the same model (`--no-fused-step`
    /// falls back to the per-session loop; token streams are
    /// bit-identical either way)
    pub fused: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_live: 8,
            block_tokens: DEFAULT_BLOCK_TOKENS,
            prefill_chunk: 16,
            fused: true,
        }
    }
}

/// One generate request's scheduler state — everything that must
/// survive a preempt→requeue→resume cycle. The session itself is
/// deliberately absent: preemption drops it and resume re-prefills
/// `prompt ++ generated`, which reproduces the dropped cache (and its
/// next-token logits) exactly.
pub struct GenTask {
    /// server-minted request id — also the cache-accounting key
    pub id: u64,
    pub params: GenerateParams,
    pub reply: std::sync::mpsc::Sender<Response<Output>>,
    /// per-token stream: sampled tokens are sent as they are picked
    pub stream: Option<std::sync::mpsc::Sender<i32>>,
    pub t_submit: Instant,
    /// continuation decoded so far, across preemptions
    pub generated: Vec<i32>,
    /// per-request sampling stream — what makes sampled decode
    /// batch-composition-independent
    pub rng: Rng,
    pub preemptions: u32,
    /// set at first admission (queue-wait metric observes once)
    pub t_first_admit: Option<Instant>,
    /// variants whose pool can never hold this request at the *real*
    /// session footprint (learned by opening a session there); routing
    /// excludes them so the request lands elsewhere instead of bouncing
    /// against the same pool forever
    pub no_fit: Vec<usize>,
    /// lifecycle span recorder — rides the task through every
    /// preempt→requeue→resume cycle; `None` when tracing is off
    pub trace: Option<RequestTrace>,
}

impl GenTask {
    pub fn new(id: u64, params: GenerateParams,
               reply: std::sync::mpsc::Sender<Response<Output>>,
               stream: Option<std::sync::mpsc::Sender<i32>>) -> GenTask {
        let rng = Rng::new(params.seed);
        GenTask {
            id,
            params,
            reply,
            stream,
            t_submit: Instant::now(),
            generated: Vec::new(),
            rng,
            preemptions: 0,
            t_first_admit: None,
            no_fit: Vec::new(),
            trace: None,
        }
    }

    /// Tokens a (re)admitted session must hold: the prompt plus the
    /// continuation so far.
    fn total_feed(&self) -> usize {
        self.params.prompt.len() + self.generated.len()
    }
}

/// Shared admission queue feeding every worker's scheduler: new requests
/// arrive at the back, preempted (resumable) requests re-enter at the
/// front — they hold queue seniority, vLLM-style.
#[derive(Default)]
pub struct SchedQueue {
    q: Mutex<VecDeque<GenTask>>,
}

impl SchedQueue {
    pub fn new() -> SchedQueue {
        SchedQueue::default()
    }

    pub fn push_back(&self, t: GenTask) {
        lock_unpoisoned(&self.q).push_back(t);
    }

    pub fn push_front(&self, t: GenTask) {
        lock_unpoisoned(&self.q).push_front(t);
    }

    pub fn pop(&self) -> Option<GenTask> {
        lock_unpoisoned(&self.q).pop_front()
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.q).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One live sequence on a worker.
struct LiveSeq {
    task: GenTask,
    /// slot in the worker's [`BatchedDecodeState`]
    slot: usize,
    vidx: usize,
    vname: String,
    /// tokens of `prompt ++ generated` fed to the session so far
    fed: usize,
    /// next-token logits, present once the feed is complete
    logits: Option<Vec<f32>>,
}

enum Admitted {
    /// admitted into the live set
    Live,
    /// a response was sent (validation error, can-never-fit, ...)
    Replied,
    /// no room right now but possible later — put it back
    Requeue(GenTask),
}

/// Per-worker continuous-batching engine. Owns the worker's live
/// session set (sessions are not `Send`, so they never cross threads —
/// preemption and resume move only the [`GenTask`]).
pub struct WorkerScheduler {
    widx: usize,
    cfg: SchedulerConfig,
    batch: BatchedDecodeState,
    /// admission order, oldest first — the preemption victim is always
    /// the newest, so the oldest always progresses and the set drains
    live: Vec<LiveSeq>,
}

impl WorkerScheduler {
    pub fn new(widx: usize, cfg: SchedulerConfig) -> WorkerScheduler {
        let mut batch = BatchedDecodeState::new();
        batch.set_fused(cfg.fused);
        WorkerScheduler {
            widx,
            cfg,
            batch,
            live: Vec::new(),
        }
    }

    pub fn is_idle(&self) -> bool {
        self.live.is_empty()
    }

    pub fn live_sessions(&self) -> usize {
        self.live.len()
    }

    /// One scheduler iteration: admit → prefill chunks → sample/extend →
    /// one mixed step batch → retire. Returns whether any work was done
    /// (the worker loop uses it to pace its queue polling).
    pub fn iteration(&mut self, engine: &Engine, router: &Mutex<Router>,
                     queue: &SchedQueue, metrics: &Arc<Metrics>,
                     traces: &TraceRing) -> bool {
        let mut progress = false;
        // --- admission: fill free slots from the shared queue (FCFS —
        // a head that doesn't fit parks rather than being overtaken) ---
        while self.live.len() < self.cfg.max_live.max(1) {
            let Some(task) = queue.pop() else { break };
            metrics.gauge_add("gen_queue_depth", -1);
            match self.admit(engine, router, task, metrics, traces) {
                Admitted::Live | Admitted::Replied => progress = true,
                Admitted::Requeue(task) => {
                    metrics.gauge_add("gen_queue_depth", 1);
                    queue.push_front(task);
                    break;
                }
            }
        }
        if self.live.is_empty() {
            return progress;
        }
        metrics.incr("sched_slots", self.cfg.max_live.max(1) as u64);

        // --- per-sequence scheduling, admission order ---
        let mut steps: Vec<(usize, i32)> = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].logits.is_none() {
                // prefill (or resume re-prefill), one chunk per iteration
                progress = true;
                let t_chunk = Instant::now();
                match self.feed_chunk(i) {
                    Ok(n) => {
                        if let Some(tr) =
                            self.live[i].task.trace.as_mut() {
                            tr.prefill_chunk(n as u64,
                                             t_chunk.elapsed());
                        }
                        metrics.incr("sched_prefill_chunks", 1);
                        if self.live[i].logits.is_some() {
                            // feed complete: offer the prompt's full
                            // blocks to the variant's prefix cache
                            self.donate_prefix(i, router);
                        }
                        i += 1;
                    }
                    Err(e) => {
                        metrics.incr("gen_errors", 1);
                        self.fail(i, router, metrics, ServeError::Internal {
                            reason: format!("{e:#}"),
                        }, traces);
                        // the next sequence shifted into index i
                    }
                }
                continue;
            }
            // decode: the final sampled token is never fed back (its
            // logits would go unused and its row was never reserved) —
            // exactly the sequential path's loop shape
            if self.live[i].task.generated.len()
                >= self.live[i].task.params.max_new {
                progress = true;
                self.finish(i, router, metrics, traces);
                continue;
            }
            let (next, done) = {
                let l = &mut self.live[i];
                let next = pick_token(l.logits.as_ref().expect("ready"),
                                      l.task.params.temperature,
                                      &mut l.task.rng) as i32;
                l.task.generated.push(next);
                if let Some(s) = &l.task.stream {
                    let _ = s.send(next);
                    if let Some(tr) = l.task.trace.as_mut() {
                        tr.stream_emit();
                    }
                }
                (next, l.task.generated.len() >= l.task.params.max_new)
            };
            progress = true;
            if done {
                // the final sampled token is never fed back; its logits
                // came from an already-attributed batch, so it adds no
                // decode time — record it so `timings.tokens` equals
                // the tokens the caller receives
                if let Some(tr) = self.live[i].task.trace.as_mut() {
                    tr.step(Duration::ZERO);
                }
                self.finish(i, router, metrics, traces);
                continue;
            }
            // reserve the next cache row; on refusal preempt the newest
            // live sequence ON THE SAME VARIANT (only its pages feed the
            // pool that refused us) and retry — preempting ourselves
            // parks the request (tokens + RNG intact) instead of
            // erroring it
            let (vidx, key) = (self.live[i].vidx, self.live[i].task.id);
            loop {
                let ok = {
                    let mut r = lock_unpoisoned(router);
                    r.variants[vidx].cache.try_extend(key)
                };
                if ok {
                    steps.push((i, next));
                    i += 1;
                    break;
                }
                // newest same-variant victim; falls back to `i` itself
                // (we share our own variant), never below — indices < i
                // may hold pending steps and already-sampled state
                let victim = (i..self.live.len()).rev()
                    .find(|&j| self.live[j].vidx == vidx)
                    .unwrap_or(i);
                self.preempt(victim, router, queue, metrics);
                if victim == i {
                    break; // we preempted ourselves; i now points past
                }
            }
        }

        // --- one mixed batch of single-token steps ---
        if !steps.is_empty() {
            metrics.incr("sched_steps", steps.len() as u64);
            let batch_steps: Vec<(usize, i32)> = steps.iter()
                .map(|&(idx, tok)| (self.live[idx].slot, tok))
                .collect();
            // recycle each sequence's previous logits buffer — the step
            // writes into it in place, so steady-state decode stops
            // paying one Vec allocation per sequence per token
            let mut outs: Vec<Vec<f32>> = steps.iter()
                .map(|&(idx, _)| self.live[idx].logits.take()
                    .unwrap_or_default())
                .collect();
            let (fb0, fr0) = self.batch.fused_stats();
            let t0 = Instant::now();
            let results = self.batch.step_many_into(&batch_steps,
                                                    &mut outs);
            let step_d = t0.elapsed();
            metrics.observe("step_us", step_d);
            let (fb1, fr1) = self.batch.fused_stats();
            metrics.incr("fused_batches", fb1 - fb0);
            metrics.incr("fused_step_rows", fr1 - fr0);
            let mut dead: Vec<(usize, String)> = Vec::new();
            for ((&(idx, _), res), out) in
                steps.iter().zip(results).zip(outs) {
                match res {
                    Ok(()) => {
                        // the batch's wall time is attributed to every
                        // sequence it stepped (Timings docs this)
                        if let Some(tr) =
                            self.live[idx].task.trace.as_mut() {
                            tr.step(step_d);
                        }
                        self.live[idx].logits = Some(out);
                    }
                    Err(e) => dead.push((idx, format!("{e:#}"))),
                }
            }
            // remove highest-index first so earlier indices stay valid
            for (idx, msg) in dead.into_iter().rev() {
                metrics.incr("gen_errors", 1);
                self.fail(idx, router, metrics,
                          ServeError::Internal { reason: msg }, traces);
            }
        }
        progress
    }

    /// Route + page-admit + open a session for a waiting task. Mirrors
    /// the sequential path's admission ladder (nominal route, session
    /// capacity check, re-admission at the session's *real* footprint)
    /// with one difference: a request that doesn't fit *right now* but
    /// could ever fit is requeued, not rejected.
    fn admit(&mut self, engine: &Engine, router: &Mutex<Router>,
             mut task: GenTask, metrics: &Arc<Metrics>,
             traces: &TraceRing) -> Admitted {
        if task.params.prompt.is_empty() {
            metrics.incr("request_errors", 1);
            send_response(task, String::new(), Err(ServeError::Empty),
                          Some(traces));
            return Admitted::Replied;
        }
        let feed_len = task.total_feed();
        let total_need = task.params.prompt.len()
            + task.params.max_new.saturating_sub(1);
        let routed = {
            let mut r = lock_unpoisoned(router);
            match r.route_excluding(task.id, feed_len, &task.no_fit) {
                Some(vidx) => {
                    let v = &r.variants[vidx];
                    Some((vidx, v.step_program.clone(), v.name.clone(),
                          v.weights.clone()))
                }
                None => None,
            }
        };
        let Some((vidx, program, vname, weights)) = routed else {
            // not routable right now: requeue if some still-eligible
            // variant could EVER hold it (best-effort nominal-rate
            // estimate — the real rate is only knowable after opening a
            // session there, and a too-optimistic guess just means one
            // more bounce that lands that variant in `no_fit`)
            if any_pool_could_ever_fit(router, &task.no_fit, total_need) {
                return Admitted::Requeue(task);
            }
            // can-never-fit anywhere, same contract as the post-route
            // check below: an Evicted response so callers can tell
            // "shrink/retry won't help at this budget" from hard
            // failures
            metrics.incr("gen_evictions", 1);
            metrics.incr(&format!("worker_{}_evictions", self.widx), 1);
            send_response(task, String::new(), Err(ServeError::Evicted {
                reason: format!("{total_need}-token request can never \
                                 fit any variant's paged KV budget"),
            }), Some(traces));
            return Admitted::Replied;
        };
        let mut session = match engine.program(&program)
            .and_then(|p| p.decode_session(&weights)) {
            Ok(s) => s,
            Err(e) => {
                lock_unpoisoned(router).release(vidx, task.id);
                metrics.incr("gen_errors", 1);
                send_response(task, vname, Err(ServeError::Internal {
                    reason: format!("{e:#}"),
                }), Some(traces));
                return Admitted::Replied;
            }
        };
        // sessions are windowless but bounded by the positional table —
        // reject an overshooting request before paying any prefill
        if total_need > session.max_tokens() {
            lock_unpoisoned(router).release(vidx, task.id);
            metrics.incr("gen_errors", 1);
            send_response(task, vname, Err(ServeError::TooLong {
                need: total_need,
                max: session.max_tokens(),
            }), Some(traces));
            return Admitted::Replied;
        }
        // re-admit at the session's REAL footprint (a latent-accounted
        // variant may run dense-layout weights) — and decide now whether
        // the whole request could ever fit THIS pool at that rate.
        // Admission goes through the prefix cache: the longest cached
        // prefix of `prompt ++ generated` is billed as shared blocks and
        // its rows are adopted into the fresh session, so the feed below
        // starts at the cache boundary instead of position 0. All under
        // one router lock, so a hit's blocks cannot be reclaimed between
        // lookup and admission.
        let (admitted, never_fits_here, fed) = {
            let mut r = lock_unpoisoned(router);
            let actual_bpt = r.variants[vidx].cache.bytes_per_token_for(
                session.cache_kind(), session.n_layers());
            if !r.variants[vidx].cache.fits_total(total_need, actual_bpt) {
                r.variants[vidx].cache.release(task.id);
                (false, true, 0)
            } else {
                let feed: Vec<i32> = task.params.prompt.iter()
                    .chain(task.generated.iter()).copied().collect();
                let (ok, hit) = r.variants[vidx].cache
                    .admit_prefixed(task.id, &feed, actual_bpt);
                let mut fed = 0usize;
                let mut lost = false;
                if ok {
                    if let Some(h) = hit {
                        match PrefixSnapshot::concat(&h.snaps)
                            .and_then(|snap| {
                                session.adopt_prefix(&snap)?;
                                Ok(snap.tokens)
                            }) {
                            Ok(n) => fed = n,
                            Err(_) => {
                                // backend can't adopt cached rows: fall
                                // back to a cold full prefill, billed
                                // plain (release-then-reserve drops the
                                // shared refs)
                                lost = !r.variants[vidx].cache.admit_with(
                                    task.id, feed.len(), actual_bpt);
                            }
                        }
                    }
                    sample_cache_peaks(&r, metrics);
                }
                (ok && !lost, false, fed)
            }
        };
        if never_fits_here {
            // this pool can never hold the request — exclude it from
            // future routing; only when EVERY variant is excluded (or
            // could never fit even nominally) is the request terminally
            // rejected, since another variant's pool may still hold it
            if !task.no_fit.contains(&vidx) {
                task.no_fit.push(vidx);
            }
            if any_pool_could_ever_fit(router, &task.no_fit, total_need) {
                return Admitted::Requeue(task);
            }
            metrics.incr("gen_evictions", 1);
            metrics.incr(&format!("worker_{}_evictions", self.widx), 1);
            send_response(task, vname, Err(ServeError::Evicted {
                reason: format!("{total_need}-token request can never \
                                 fit any variant's paged KV budget at \
                                 its real session footprint"),
            }), Some(traces));
            return Admitted::Replied;
        }
        if !admitted {
            // pages are held elsewhere right now — resume later
            return Admitted::Requeue(task);
        }
        if task.t_first_admit.is_none() {
            task.t_first_admit = Some(Instant::now());
            metrics.observe("gen_queue_us", task.t_submit.elapsed());
        }
        if let Some(tr) = task.trace.as_mut() {
            tr.admitted(); // records Resumed after a preemption
            if fed > 0 {
                tr.prefix_adopted(fed as u64);
            }
        }
        let slot = self.batch.insert(task.id, session);
        metrics.gauge_add("live_sessions", 1);
        self.live.push(LiveSeq {
            task,
            slot,
            vidx,
            vname,
            fed,
            logits: None,
        });
        Admitted::Live
    }

    /// Feed the next `prefill_chunk` tokens of `prompt ++ generated` to
    /// sequence `i`'s session; the final chunk's last row becomes the
    /// sequence's next-token logits. Chunking is bit-transparent: rows
    /// depend only on cache contents before them, so any chunk split
    /// yields the same logits as one whole-prompt prefill. Returns the
    /// number of tokens fed.
    fn feed_chunk(&mut self, i: usize) -> Result<usize> {
        let l = &mut self.live[i];
        let prompt = &l.task.params.prompt;
        let gen = &l.task.generated;
        let total = prompt.len() + gen.len();
        let start = l.fed;
        let end = total.min(start + self.cfg.prefill_chunk.max(1));
        let mut chunk: Vec<i32> = Vec::with_capacity(end - start);
        for pos in start..end {
            chunk.push(if pos < prompt.len() {
                prompt[pos]
            } else {
                gen[pos - prompt.len()]
            });
        }
        let slot = l.slot;
        let sess = self.batch.session_mut(slot)
            .ok_or_else(|| anyhow!("live sequence lost slot {slot}"))?;
        let mut rows = if start == 0 {
            vec![sess.prefill(&chunk)?]
        } else {
            sess.step_many(&chunk)?
        };
        l.fed = end;
        if l.fed == total {
            l.logits = Some(rows.pop()
                .ok_or_else(|| anyhow!("empty feed chunk"))?);
        }
        Ok(end - start)
    }

    /// Offer sequence `i`'s *prompt* blocks to its variant's prefix
    /// cache: export the leading full-block cache rows from the live
    /// session and insert them keyed by the prompt's token chain.
    /// Prompt-only (generated tokens diverge per request), nominal-rate
    /// only (the cache's block↔token alignment), and skipped when the
    /// cache already serves this prefix — so resume-after-preempt and
    /// sibling requests donate nothing twice.
    fn donate_prefix(&mut self, i: usize, router: &Mutex<Router>) {
        let (vidx, key, slot) = {
            let l = &self.live[i];
            (l.vidx, l.task.id, l.slot)
        };
        let prompt = self.live[i].task.params.prompt.clone();
        let export = {
            let r = lock_unpoisoned(router);
            let cache = &r.variants[vidx].cache;
            if !cache.prefix_enabled()
                || cache.pages().rate_of(key)
                    != Some(cache.bytes_per_token()) {
                return;
            }
            let bt = cache.block_tokens().max(1);
            let full = (prompt.len() / bt) * bt;
            if full == 0 || cache.prefix_matched_tokens(&prompt) >= full {
                return;
            }
            full
        };
        let Some(sess) = self.batch.session_mut(slot) else {
            return;
        };
        // backends without row export simply never donate
        let Ok(snap) = sess.export_prefix(export) else {
            return;
        };
        let mut r = lock_unpoisoned(router);
        r.variants[vidx].cache.donate_prefix(key, &prompt[..export],
                                             &snap);
    }

    /// Retire a completed sequence: reply, free pages + session.
    fn finish(&mut self, i: usize, router: &Mutex<Router>,
              metrics: &Arc<Metrics>, traces: &TraceRing) {
        let mut l = self.live.remove(i);
        self.batch.remove(l.slot);
        {
            let mut r = lock_unpoisoned(router);
            r.release(l.vidx, l.task.id);
            sample_cache_peaks(&r, metrics);
        }
        metrics.gauge_add("live_sessions", -1);
        let tokens = std::mem::take(&mut l.task.generated);
        metrics.incr("gen_tokens", tokens.len() as u64);
        metrics.incr(&format!("worker_{}_gen_tokens", self.widx),
                     tokens.len() as u64);
        metrics.observe("gen_us", l.task.t_submit.elapsed());
        if l.task.preemptions > 0 {
            metrics.incr("gen_resumed_ok", 1);
        }
        send_response(l.task, l.vname, Ok(tokens), Some(traces));
    }

    /// Preempt a live sequence: drop its session (the cache tensors go
    /// with it), return its pages, park the task at the queue head.
    fn preempt(&mut self, i: usize, router: &Mutex<Router>,
               queue: &SchedQueue, metrics: &Arc<Metrics>) {
        let mut l = self.live.remove(i);
        self.batch.remove(l.slot);
        lock_unpoisoned(router).release(l.vidx, l.task.id);
        l.task.preemptions += 1;
        if let Some(tr) = l.task.trace.as_mut() {
            tr.preempted(); // records Preempted + Requeued
        }
        metrics.incr("gen_preemptions", 1);
        metrics.gauge_add("live_sessions", -1);
        metrics.gauge_add("gen_queue_depth", 1);
        queue.push_front(l.task);
    }

    /// Hard per-sequence failure: reply with the error, free everything.
    fn fail(&mut self, i: usize, router: &Mutex<Router>,
            metrics: &Arc<Metrics>, err: ServeError,
            traces: &TraceRing) {
        let l = self.live.remove(i);
        self.batch.remove(l.slot);
        {
            let mut r = lock_unpoisoned(router);
            r.release(l.vidx, l.task.id);
            sample_cache_peaks(&r, metrics);
        }
        metrics.gauge_add("live_sessions", -1);
        send_response(l.task, l.vname, Err(err), Some(traces));
    }

    /// `Drain::Now`: abort every live sequence with a Rejected reply —
    /// pages released, sessions dropped, callers unblocked.
    pub fn abort_all(&mut self, router: &Mutex<Router>,
                     metrics: &Arc<Metrics>, traces: &TraceRing) {
        while !self.live.is_empty() {
            self.fail(0, router, metrics, ServeError::Rejected {
                reason: "server shut down mid-decode".to_string(),
            }, traces);
        }
    }
}

/// Could any variant NOT in `no_fit` ever hold `total_need` tokens,
/// estimated at each pool's nominal byte-rate? The shared
/// requeue-vs-terminal-reject predicate for both admission failure
/// paths (unroutable, and real-footprint misfit on the routed pool).
fn any_pool_could_ever_fit(router: &Mutex<Router>, no_fit: &[usize],
                           total_need: usize) -> bool {
    let r = lock_unpoisoned(router);
    r.variants.iter().enumerate().any(|(i, v)| {
        !no_fit.contains(&i)
            && v.cache.fits_total(total_need, v.cache.bytes_per_token())
    })
}

/// Send the terminal [`Response`] for a task (the receiver may have
/// hung up — that's its problem, not the worker's). Retires the task's
/// trace: the timings summary rides the response, the full span chain
/// lands in the completed-trace ring (when one is given).
fn send_response(mut task: GenTask, variant: String,
                 result: std::result::Result<Vec<i32>, ServeError>,
                 traces: Option<&TraceRing>) {
    let latency = task.t_submit.elapsed();
    let failed = result.is_err();
    let timings = task.trace.take().map(|mut tr| {
        let t = tr.retire(failed);
        if let Some(ring) = traces {
            ring.push(tr.completed(&variant, failed));
        }
        t
    });
    let _ = task.reply.send(Response {
        id: task.id,
        variant,
        latency,
        timings,
        result: result.map(|tokens| {
            Output::Generate(GenerateOutput { tokens })
        }),
    });
}

/// Reply Rejected to a task that never reached a worker (queue drained
/// at `Drain::Now` shutdown) so its caller does not block forever.
pub(crate) fn abandon(task: GenTask, traces: Option<&TraceRing>) {
    send_response(task, String::new(), Err(ServeError::Rejected {
        reason: "server shut down before the request ran".to_string(),
    }), traces);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = SchedulerConfig::default();
        assert!(c.max_live >= 1);
        assert_eq!(c.block_tokens, DEFAULT_BLOCK_TOKENS);
        assert!(c.prefill_chunk >= 1);
        assert!(c.fused, "fused stepping is the default");
    }

    #[test]
    fn queue_is_fifo_with_front_resume() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let mk = |id: u64| GenTask::new(id, GenerateParams {
            prompt: vec![1],
            max_new: 1,
            temperature: 0.0,
            seed: id,
        }, tx.clone(), None);
        let q = SchedQueue::new();
        assert!(q.is_empty());
        q.push_back(mk(1));
        q.push_back(mk(2));
        q.push_front(mk(3)); // a preempted task resumes first
        assert_eq!(q.len(), 3);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop())
            .map(|t| t.id)
            .collect();
        assert_eq!(order, vec![3, 1, 2]);
    }

    #[test]
    fn task_state_survives_requeue_shape() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let mut t = GenTask::new(9, GenerateParams {
            prompt: vec![1, 2, 3],
            max_new: 8,
            temperature: 0.7,
            seed: 42,
        }, tx, None);
        assert_eq!(t.total_feed(), 3);
        let r1 = t.rng.uniform();
        t.generated.push(7);
        t.preemptions += 1;
        assert_eq!(t.total_feed(), 4);
        // the RNG stream continues — it is NOT reseeded on resume
        let r2 = t.rng.uniform();
        assert_ne!(r1, r2);
        let mut fresh = Rng::new(42);
        assert_eq!(fresh.uniform(), r1, "stream starts at the seed");
        assert_eq!(fresh.uniform(), r2, "and continues across preemption");
    }

    #[test]
    fn streamed_tokens_arrive_per_sample_site() {
        // the stream sender rides the task: what a worker pushes at the
        // sampling site is what a receiver drains, in order, and the
        // channel disconnects when the task (and its sender) drops
        let (rtx, _rrx) = std::sync::mpsc::channel();
        let (stx, srx) = std::sync::mpsc::channel();
        let t = GenTask::new(1, GenerateParams {
            prompt: vec![1],
            max_new: 3,
            temperature: 0.0,
            seed: 0,
        }, rtx, Some(stx));
        for tok in [10, 11, 12] {
            if let Some(s) = &t.stream {
                let _ = s.send(tok);
            }
        }
        drop(t);
        let got: Vec<i32> = srx.iter().collect();
        assert_eq!(got, vec![10, 11, 12]);
    }
}
