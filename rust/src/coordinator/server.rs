//! The serving loop: a worker thread drains the dynamic batcher, routes
//! each flush to a model variant, pads to the program's fixed batch shape,
//! executes on the engine's backend, and replies per request. std::thread +
//! mpsc (tokio is unavailable offline; the control flow is identical).
//!
//! Backends need not be Send (the PJRT client is `Rc`-based), so the
//! worker thread builds and owns its own [`Engine`] — requests/responses
//! cross the channel, executables never do.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::runtime::{Engine, ParamValue};

#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub id: u64,
    pub nll: f32,
    pub variant: String,
    pub latency: Duration,
}

pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// fixed program batch (manifest score_batch)
    pub program_batch: usize,
    pub seq_len: usize,
}

enum Msg {
    Req(ScoreRequest, mpsc::Sender<ScoreResponse>),
    Shutdown,
}

pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start the worker thread; it constructs its own PJRT engine from the
    /// artifacts directory (the client is not Send).
    pub fn start(artifacts: PathBuf, router: Router, cfg: ServerConfig)
                 -> Server {
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || {
            let engine = match Engine::new(&artifacts) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[server] engine init failed: {e:#}");
                    return;
                }
            };
            serve_loop(engine, router, cfg, rx, m);
        });
        Server { tx, handle: Some(handle), metrics }
    }

    pub fn submit(&self, req: ScoreRequest)
                  -> mpsc::Receiver<ScoreResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Req(req, rtx)).expect("server alive");
        rrx
    }

    pub fn shutdown(mut self) -> Arc<Metrics> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Entry {
    req: ScoreRequest,
    reply: mpsc::Sender<ScoreResponse>,
    t_submit: Instant,
}

fn serve_loop(engine: Engine, mut router: Router, cfg: ServerConfig,
              rx: mpsc::Receiver<Msg>, metrics: Arc<Metrics>) {
    let mut batcher: Batcher<Entry> = Batcher::new(cfg.batcher);
    let mut running = true;
    while running || !batcher.is_empty() {
        // Collect messages until flush condition or shutdown.
        let now = Instant::now();
        let timeout = if batcher.is_empty() {
            Duration::from_millis(50)
        } else {
            batcher.deadline()
                .map(|d| d.saturating_duration_since(now))
                .unwrap_or(Duration::ZERO)
        };
        if running {
            match rx.recv_timeout(timeout) {
                Ok(Msg::Req(req, reply)) => {
                    metrics.incr("requests", 1);
                    batcher.push(Entry { req, reply, t_submit: Instant::now() },
                                 Instant::now());
                }
                Ok(Msg::Shutdown) => running = false,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
            }
        }
        let now = Instant::now();
        if batcher.ready(now) || (!running && !batcher.is_empty()) {
            let entries = batcher.flush(now);
            if let Err(e) = execute_batch(&engine, &mut router, &cfg,
                                          entries, &metrics) {
                metrics.incr("batch_errors", 1);
                eprintln!("[server] batch error: {e:#}");
            }
        }
    }
}

fn execute_batch(engine: &Engine, router: &mut Router, cfg: &ServerConfig,
                 entries: Vec<super::batcher::Pending<Entry>>,
                 metrics: &Arc<Metrics>) -> Result<()> {
    if entries.is_empty() {
        return Ok(());
    }
    // route the whole flush to one variant (vLLM-style per-batch placement)
    let seq_id = entries[0].item.req.id;
    let vidx = router.route(seq_id, cfg.seq_len).unwrap_or(0);
    let (program, vname) = {
        let v = &router.variants[vidx];
        (v.score_program.clone(), v.name.clone())
    };
    let prog = engine.program(&program)?;

    let b = cfg.program_batch;
    let t = cfg.seq_len;
    let mut flat = vec![0i32; b * t];
    for (i, e) in entries.iter().enumerate().take(b) {
        let toks = &e.item.req.tokens;
        let n = toks.len().min(t);
        flat[i * t..i * t + n].copy_from_slice(&toks[..n]);
        // left-fill short requests by repeating (keeps shapes static)
        for j in n..t {
            flat[i * t + j] = toks[j % n.max(1)];
        }
    }
    let tokens = ParamValue::I32 { shape: vec![b, t], data: flat };
    let t_exec = Instant::now();
    let nll = prog.run_f32(&[tokens], &router.variants[vidx].weights)?;
    metrics.observe("exec_us", t_exec.elapsed());
    metrics.incr("batches", 1);
    metrics.incr(&format!("variant_{vname}"), entries.len() as u64);

    for (i, e) in entries.into_iter().enumerate() {
        let resp = ScoreResponse {
            id: e.item.req.id,
            nll: nll.get(i).copied().unwrap_or(f32::NAN),
            variant: vname.clone(),
            latency: e.item.t_submit.elapsed(),
        };
        metrics.observe("request_us", resp.latency);
        let _ = e.item.reply.send(resp);
    }
    router.release(vidx, seq_id);
    Ok(())
}
