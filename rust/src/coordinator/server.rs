//! The serving loop: N worker threads drain a shared request queue, each
//! with its own dynamic batcher; every flush is routed to a model variant,
//! padded to the program's fixed batch shape, executed on that worker's
//! backend, and replied per request. std::thread + Mutex/Condvar (tokio is
//! unavailable offline; the control flow is identical).
//!
//! **The typed surface.** Callers build a [`Request`] (or use the typed
//! [`Server::submit_score`]/[`Server::submit_generate`] shortcuts) and get
//! back a [`Handle`] carrying the *server-minted* request id; the terminal
//! [`Response`] arrives on the handle exactly once, with
//! `result: Result<_, ServeError>` instead of stringly `error`/`evicted`
//! flags. The same [`ServeError`] enum is what `coordinator::http` maps to
//! HTTP status codes, so in-process and network callers see one error
//! taxonomy.
//!
//! Two request kinds share the queue: score requests batch through the
//! scoring programs, and generate requests decode through incremental
//! sessions ([`crate::runtime::DecodeSession`]) in one of two modes
//! selected by [`ServerConfig::sched`]:
//!
//! * **Continuous batching (default)** — requests land in a shared
//!   [`super::scheduler::SchedQueue`]; each worker keeps a live session
//!   set and pulls *scheduler iterations* (admit → prefill chunk → one
//!   mixed batch of single-token steps) between its score flushes, with
//!   paged admission and preemption-by-requeue
//!   (`coordinator::scheduler`).
//! * **Sequential (`sched: None`)** — the popping worker runs one
//!   session to completion: prompt admitted up front, every decoded
//!   token `extend`ed against the paged budget, and an eviction verdict
//!   mid-decode drops the live session and errors that request alone.
//!
//! Generate submissions may carry a per-token stream sender
//! ([`Server::submit_generate_streaming`]): each sampled token is sent
//! the moment it is picked — exactly once per token even across
//! preempt→resume cycles, because resume re-prefills without
//! re-sampling.
//!
//! Cache pages, decode tokens, preemptions, and evictions are
//! aggregated per worker in [`Metrics`].
//!
//! Backends need not be Send (the PJRT client is `Rc`-based), so each
//! worker thread builds and owns its own [`Engine`] — requests/responses
//! cross the queue, executables never do. Variant weights are shared
//! read-only (`Arc`) through the router; router admission state is the
//! only cross-worker lock on the hot path and is held for routing
//! decisions only, never across an execution.
//!
//! Failure containment: engine-init failures surface from
//! [`Server::start`]; malformed requests (empty or over-long token lists)
//! get an error-carrying response instead of killing the worker; flushes
//! larger than the program batch split into multiple executions
//! (`batch_overflow` metric) instead of silently NaN-ing the overflow.
//!
//! Shutdown is explicit: [`Server::shutdown`] takes a [`Drain`] mode.
//! `Drain::Graceful` finishes every queued request and live session
//! before returning; `Drain::Now` aborts live decodes and answers
//! everything still queued with [`ServeError::Rejected`] — no caller is
//! ever left blocking on a reply that will never come.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use super::scheduler::{self, GenTask, SchedQueue, SchedulerConfig,
                       WorkerScheduler};
use super::trace::{RequestTrace, Timings, TraceRing};
use crate::runtime::{Engine, ParamValue};
use crate::util::lock_unpoisoned;

// ---------------------------------------------------------------------------
// The typed request/response surface
// ---------------------------------------------------------------------------

/// Why a request failed — one taxonomy shared by the in-process API and
/// the HTTP listener (which maps each variant to a status code). The
/// old `error: Option<String>` + `evicted: bool` flags are these
/// variants now.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Refused before running: admission rejected, or the server shut
    /// down while the request was still queued.
    Rejected { reason: String },
    /// KV-budget eviction — retrying later (or shorter) may succeed;
    /// a "can never fit" reason means it will not at this budget.
    Evicted { reason: String },
    /// The request needs more positions than the program/model holds.
    TooLong { need: usize, max: usize },
    /// Empty prompt / token list.
    Empty,
    /// No worker engine is serving (failed init or all workers died).
    EngineInit { reason: String },
    /// Execution failure (batch run, session open/step).
    Internal { reason: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected { reason } => {
                write!(f, "rejected: {reason}")
            }
            ServeError::Evicted { reason } => {
                write!(f, "evicted: {reason}")
            }
            ServeError::TooLong { need, max } => {
                write!(f, "request needs {need} positions but the \
                           context holds {max}")
            }
            ServeError::Empty => write!(f, "empty request"),
            ServeError::EngineInit { reason } => {
                write!(f, "engine init: {reason}")
            }
            ServeError::Internal { reason } => write!(f, "{reason}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Score a token list through the routed variant's scoring program.
#[derive(Clone, Debug)]
pub struct ScoreParams {
    pub tokens: Vec<i32>,
}

/// Autoregressive decode: prefill `prompt`, emit `max_new` tokens
/// through a cached decode session on the routed variant.
#[derive(Clone, Debug)]
pub struct GenerateParams {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling
    pub temperature: f64,
    pub seed: u64,
}

/// One unit of work. Ids are server-minted (returned in the submit
/// [`Handle`]), never caller-chosen.
#[derive(Clone, Debug)]
pub enum Request {
    Score(ScoreParams),
    Generate(GenerateParams),
}

#[derive(Clone, Debug)]
pub struct ScoreOutput {
    pub nll: f32,
}

#[derive(Clone, Debug)]
pub struct GenerateOutput {
    /// generated continuation (prompt excluded)
    pub tokens: Vec<i32>,
}

#[derive(Clone, Debug)]
pub enum Output {
    Score(ScoreOutput),
    Generate(GenerateOutput),
}

/// Terminal reply for one request. `T` is [`Output`] for the unified
/// [`Server::submit`] entry and the concrete output type for the typed
/// shortcuts.
#[derive(Clone, Debug)]
pub struct Response<T = Output> {
    /// the server-minted request id (same value as `Handle::id`)
    pub id: u64,
    /// variant that served the request (empty when it never routed)
    pub variant: String,
    pub latency: Duration,
    /// per-request timing breakdown from the lifecycle trace; `None`
    /// when tracing is off ([`ServerConfig::trace`])
    pub timings: Option<Timings>,
    pub result: std::result::Result<T, ServeError>,
}

impl<T> Response<T> {
    /// Render the failure, if any (the old `error: Option<String>`).
    pub fn error(&self) -> Option<String> {
        self.result.as_ref().err().map(|e| e.to_string())
    }

    /// Was this a KV-budget eviction (the old `evicted: bool`)?
    pub fn is_evicted(&self) -> bool {
        matches!(self.result, Err(ServeError::Evicted { .. }))
    }
}

impl Response<ScoreOutput> {
    /// NaN on failure — the scoring convention callers already expect.
    pub fn nll(&self) -> f32 {
        self.result.as_ref().map(|o| o.nll).unwrap_or(f32::NAN)
    }
}

impl Response<GenerateOutput> {
    /// Empty on failure.
    pub fn tokens(&self) -> &[i32] {
        self.result.as_ref().map(|o| o.tokens.as_slice()).unwrap_or(&[])
    }

    pub fn into_tokens(self) -> Vec<i32> {
        self.result.map(|o| o.tokens).unwrap_or_default()
    }
}

/// Narrow an [`Output`] to a concrete kind — only implemented for types
/// a submit path can actually produce, so the conversion is total by
/// construction.
pub trait FromOutput: Sized {
    fn from_output(out: Output) -> Self;
}

impl FromOutput for Output {
    fn from_output(out: Output) -> Output {
        out
    }
}

impl FromOutput for ScoreOutput {
    fn from_output(out: Output) -> ScoreOutput {
        match out {
            Output::Score(s) => s,
            Output::Generate(_) => {
                unreachable!("score handle received a generate output")
            }
        }
    }
}

impl FromOutput for GenerateOutput {
    fn from_output(out: Output) -> GenerateOutput {
        match out {
            Output::Generate(g) => g,
            Output::Score(_) => {
                unreachable!("generate handle received a score output")
            }
        }
    }
}

impl Response<Output> {
    fn narrow<T: FromOutput>(self) -> Response<T> {
        Response {
            id: self.id,
            variant: self.variant,
            latency: self.latency,
            timings: self.timings,
            result: self.result.map(T::from_output),
        }
    }
}

/// The submit receipt: carries the server-minted id and receives the
/// terminal [`Response`] exactly once.
pub struct Handle<T = Output> {
    id: u64,
    rx: mpsc::Receiver<Response<Output>>,
    _kind: PhantomData<fn() -> T>,
}

impl<T: FromOutput> Handle<T> {
    fn new(id: u64, rx: mpsc::Receiver<Response<Output>>) -> Handle<T> {
        Handle { id, rx, _kind: PhantomData }
    }

    /// The server-assigned request id (also what the response carries).
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn recv(&self)
                -> std::result::Result<Response<T>, mpsc::RecvError> {
        self.rx.recv().map(Response::narrow)
    }

    pub fn recv_timeout(&self, timeout: Duration)
                        -> std::result::Result<Response<T>,
                                               mpsc::RecvTimeoutError> {
        self.rx.recv_timeout(timeout).map(Response::narrow)
    }
}

/// How [`Server::shutdown`] treats in-flight work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Drain {
    /// Stop accepting, then finish every queued request and live decode
    /// session before returning — no request is lost.
    Graceful,
    /// Stop accepting and abort: live decodes and everything still
    /// queued get [`ServeError::Rejected`] replies instead of running.
    Now,
}

// ---------------------------------------------------------------------------
// Server internals
// ---------------------------------------------------------------------------

pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// fixed program batch (manifest score_batch)
    pub program_batch: usize,
    pub seq_len: usize,
    /// worker threads, each owning its own Engine (min 1)
    pub workers: usize,
    /// continuous-batching scheduler for generate traffic; `None` runs
    /// the sequential one-session-per-worker path (the PR 4 behavior,
    /// kept as the equivalence oracle and bench baseline)
    pub sched: Option<SchedulerConfig>,
    /// record a lifecycle trace per request: timings ride each
    /// [`Response`], completed span chains land in [`Server::traces`]
    /// (`GET /debug/requests`). Cheap enough to default on; `--no-trace`
    /// turns it off
    pub trace: bool,
}

pub(crate) struct Entry {
    /// server-minted id — doubles as the group's cache-accounting key
    /// (ids are unique across both request kinds, so no key collision)
    id: u64,
    tokens: Vec<i32>,
    reply: mpsc::Sender<Response<Output>>,
    t_submit: Instant,
    trace: Option<RequestTrace>,
}

struct GenEntry {
    id: u64,
    params: GenerateParams,
    reply: mpsc::Sender<Response<Output>>,
    /// per-token stream: each sampled token is sent as it is picked
    stream: Option<mpsc::Sender<i32>>,
    t_submit: Instant,
    trace: Option<RequestTrace>,
}

/// One queued unit of work.
enum Job {
    Score(Entry),
    Generate(GenEntry),
}

/// State shared between submitters and workers: the request queue plus
/// lifecycle flags.
struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// `Drain::Now`: workers abort live work instead of draining
    hard: AtomicBool,
    /// workers that finished engine init and are serving
    live: AtomicUsize,
    /// server-minted request ids; also the cache-accounting key, so one
    /// counter guarantees no submitted request can ever collide with
    /// (and release) another's live reservation
    next_id: AtomicU64,
    /// scheduler-mode generate admissions (new at the back, preempted
    /// resumes at the front); unused when `ServerConfig::sched` is None
    gen_queue: SchedQueue,
}

/// Decrements `Shared::live` on drop — including a worker panic (e.g. a
/// poisoned lock), so `submit` starts refusing once no thread can serve
/// instead of queueing requests nobody will answer.
struct LiveGuard(Arc<Shared>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.live.fetch_sub(1, Ordering::SeqCst);
    }
}

enum Pop {
    Job(Box<Job>),
    Timeout,
    Shutdown,
}

fn pop(shared: &Shared, timeout: Duration) -> Pop {
    let mut q = shared.queue.lock().unwrap();
    if let Some(e) = q.pop_front() {
        return Pop::Job(Box::new(e));
    }
    if shared.shutdown.load(Ordering::SeqCst) {
        return Pop::Shutdown;
    }
    if timeout.is_zero() {
        return Pop::Timeout;
    }
    let (mut q, _res) = shared.cv.wait_timeout(q, timeout).unwrap();
    if let Some(e) = q.pop_front() {
        return Pop::Job(Box::new(e));
    }
    if shared.shutdown.load(Ordering::SeqCst) {
        Pop::Shutdown
    } else {
        Pop::Timeout
    }
}

pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// completed request traces, bounded ring (`/debug/requests`)
    pub traces: Arc<TraceRing>,
    cfg: Arc<ServerConfig>,
}

impl Server {
    /// Start `cfg.workers` worker threads; each constructs its own engine
    /// from the artifacts directory (the backend client is not Send).
    /// Fails — instead of leaving a dead server behind — when any worker's
    /// engine init fails.
    pub fn start(artifacts: PathBuf, router: Router, cfg: ServerConfig)
                 -> Result<Server> {
        // sanitize once; every downstream use relies on these minimums
        let mut cfg = cfg;
        cfg.workers = cfg.workers.max(1);
        cfg.program_batch = cfg.program_batch.max(1);
        let workers = cfg.workers;
        // the sched.block_tokens knob only takes effect through the
        // variants' pool construction (KvCacheManager::with_block_tokens)
        // — surface a disagreement instead of silently paging at a
        // different granularity than the operator configured
        if let Some(sc) = cfg.sched {
            for v in &router.variants {
                let want = (sc.block_tokens.max(1)
                            * v.cache.bytes_per_token().max(1)).max(1);
                if v.cache.block_bytes() != want {
                    eprintln!("[server] warning: variant {:?} pages are \
                               {} B but sched.block_tokens={} implies \
                               {} B — build the variant's KvCacheManager \
                               with with_block_tokens(sched.block_tokens)",
                              v.name, v.cache.block_bytes(),
                              sc.block_tokens, want);
                }
            }
        }
        let metrics = Arc::new(Metrics::new());
        let traces = Arc::new(TraceRing::default());
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            hard: AtomicBool::new(false),
            live: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            gen_queue: SchedQueue::new(),
        });
        let router = Arc::new(Mutex::new(router));
        let cfg = Arc::new(cfg);
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = shared.clone();
            let router = router.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let traces = traces.clone();
            let artifacts = artifacts.clone();
            let init_tx = init_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("latentllm-serve-{w}"))
                .spawn(move || {
                    let engine = match Engine::new(&artifacts) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = init_tx.send(Err(e.context(format!(
                                "worker {w} engine init"))));
                            return;
                        }
                    };
                    // count live *before* reporting Ok so a submit racing
                    // with start() never sees zero workers spuriously
                    shared.live.fetch_add(1, Ordering::SeqCst);
                    let _live = LiveGuard(shared.clone());
                    let _ = init_tx.send(Ok(()));
                    drop(init_tx);
                    worker_loop(w, &engine, &shared, &router, &cfg,
                                &metrics, &traces);
                })
                .expect("spawn server worker");
            handles.push(handle);
        }
        drop(init_tx);
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match init_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!(
                        "server worker exited before engine init"));
                }
            }
        }
        if let Some(e) = first_err {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            for h in handles {
                let _ = h.join();
            }
            return Err(e.context("server start"));
        }
        Ok(Server { shared, handles, metrics, traces, cfg })
    }

    fn mint_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Enqueue any request; the terminal [`Response`] arrives on the
    /// returned handle. Errors when the server is shutting down or no
    /// worker survived — callers keep their own thread alive either way.
    pub fn submit(&self, req: Request)
                  -> std::result::Result<Handle<Output>, ServeError> {
        match req {
            Request::Score(p) => self.enqueue_score(p),
            Request::Generate(p) => self.enqueue_generate(p, None),
        }
        .map(|(id, rx)| Handle::new(id, rx))
    }

    /// Typed score submit.
    pub fn submit_score(&self, params: ScoreParams)
                        -> std::result::Result<Handle<ScoreOutput>,
                                               ServeError> {
        self.enqueue_score(params).map(|(id, rx)| Handle::new(id, rx))
    }

    /// Typed generate submit. With the scheduler enabled the request
    /// joins the shared admission queue and decodes step-interleaved
    /// with other live sessions; without it, the popping worker runs
    /// the whole prefill+step session to completion.
    pub fn submit_generate(&self, params: GenerateParams)
                           -> std::result::Result<Handle<GenerateOutput>,
                                                  ServeError> {
        self.enqueue_generate(params, None)
            .map(|(id, rx)| Handle::new(id, rx))
    }

    /// Like [`Server::submit_generate`], but every sampled token is also
    /// sent on `stream` the moment the decode step retires — exactly
    /// once per token, even across preempt→resume cycles (resume
    /// re-prefills without re-sampling). The sender is dropped when the
    /// request finishes, so a receiver loop terminates on disconnect;
    /// the terminal [`Response`] still arrives on the handle.
    pub fn submit_generate_streaming(&self, params: GenerateParams,
                                     stream: mpsc::Sender<i32>)
                                     -> std::result::Result<
                                         Handle<GenerateOutput>,
                                         ServeError> {
        self.enqueue_generate(params, Some(stream))
            .map(|(id, rx)| Handle::new(id, rx))
    }

    fn enqueue_score(&self, params: ScoreParams)
                     -> std::result::Result<
                         (u64, mpsc::Receiver<Response<Output>>),
                         ServeError> {
        self.check_accepting()?;
        let id = self.mint_id();
        let (rtx, rrx) = mpsc::channel();
        self.shared.queue.lock().unwrap().push_back(Job::Score(Entry {
            id,
            tokens: params.tokens,
            reply: rtx,
            t_submit: Instant::now(),
            trace: self.cfg.trace
                .then(|| RequestTrace::new(id, "score")),
        }));
        self.shared.cv.notify_one();
        Ok((id, rrx))
    }

    fn enqueue_generate(&self, params: GenerateParams,
                        stream: Option<mpsc::Sender<i32>>)
                        -> std::result::Result<
                            (u64, mpsc::Receiver<Response<Output>>),
                            ServeError> {
        self.check_accepting()?;
        let id = self.mint_id();
        let (rtx, rrx) = mpsc::channel();
        // both decode modes account identically at submit, so the
        // gen_queue_depth level gauge is a meaningful backpressure
        // signal (the HTTP 429 knob) either way
        self.metrics.incr("gen_requests", 1);
        self.metrics.gauge_add("gen_queue_depth", 1);
        let trace = self.cfg.trace
            .then(|| RequestTrace::new(id, "generate"));
        if self.cfg.sched.is_some() {
            let mut task = GenTask::new(id, params, rtx, stream);
            task.trace = trace;
            self.shared.gen_queue.push_back(task);
        } else {
            self.shared.queue.lock().unwrap().push_back(
                Job::Generate(GenEntry {
                    id,
                    params,
                    reply: rtx,
                    stream,
                    t_submit: Instant::now(),
                    trace,
                }));
        }
        self.shared.cv.notify_one();
        Ok((id, rrx))
    }

    fn check_accepting(&self) -> std::result::Result<(), ServeError> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(ServeError::Rejected {
                reason: "server is shutting down".to_string(),
            });
        }
        if self.shared.live.load(Ordering::SeqCst) == 0 {
            return Err(ServeError::EngineInit {
                reason: "no live server workers".to_string(),
            });
        }
        Ok(())
    }

    /// Number of workers currently serving.
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    /// Stop the server. `Drain::Graceful` finishes all queued and live
    /// work first; `Drain::Now` aborts and answers the remainder with
    /// [`ServeError::Rejected`].
    pub fn shutdown(mut self, mode: Drain) -> Arc<Metrics> {
        self.stop(mode);
        self.metrics.clone()
    }

    fn stop(&mut self, mode: Drain) {
        if mode == Drain::Now {
            // order matters: workers re-check `hard` after seeing
            // `shutdown`, so setting it first makes Now take effect on
            // the first wakeup
            self.shared.hard.store(true, Ordering::SeqCst);
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // after a graceful drain both queues are empty; after Now the
        // leftovers get terminal replies so no caller blocks forever
        let leftover: Vec<Job> =
            self.shared.queue.lock().unwrap().drain(..).collect();
        for job in leftover {
            let rejected = ServeError::Rejected {
                reason: "server shut down before the request ran"
                    .to_string(),
            };
            match job {
                Job::Score(mut e) => {
                    let timings = finish_trace(&mut e.trace, "", true,
                                               Some(&self.traces));
                    let _ = e.reply.send(Response {
                        id: e.id,
                        variant: String::new(),
                        latency: e.t_submit.elapsed(),
                        timings,
                        result: Err(rejected),
                    });
                }
                Job::Generate(mut g) => {
                    self.metrics.gauge_add("gen_queue_depth", -1);
                    let timings = finish_trace(&mut g.trace, "", true,
                                               Some(&self.traces));
                    let _ = g.reply.send(Response {
                        id: g.id,
                        variant: String::new(),
                        latency: g.t_submit.elapsed(),
                        timings,
                        result: Err(rejected),
                    });
                }
            }
        }
        while let Some(task) = self.shared.gen_queue.pop() {
            self.metrics.gauge_add("gen_queue_depth", -1);
            scheduler::abandon(task, Some(&self.traces));
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop(Drain::Graceful);
    }
}

fn worker_loop(widx: usize, engine: &Engine, shared: &Shared,
               router: &Mutex<Router>, cfg: &ServerConfig,
               metrics: &Arc<Metrics>, traces: &TraceRing) {
    if cfg.workers.max(1) > 1 {
        // parallelism comes from the workers themselves; keep each
        // worker's tensor kernels serial instead of workers×pool-width
        // threads contending for the same cores
        crate::util::pool::Pool::mark_worker_thread();
    }
    let mut batcher: Batcher<Entry> = Batcher::new(cfg.batcher);
    let mut sched = cfg.sched.map(|sc| WorkerScheduler::new(widx, sc));
    let mut draining = false;
    // did the previous scheduler iteration do work? Then don't sleep at
    // all — drain any queued jobs and go straight to the next iteration
    // (decode throughput must not be clocked by the poll interval).
    let mut sched_active = false;
    loop {
        if shared.hard.load(Ordering::SeqCst) {
            // Drain::Now — abort instead of draining: everything this
            // worker holds gets a Rejected reply; what is still queued
            // is answered by `Server::stop` after the join
            abort_batcher(&mut batcher, traces);
            if let Some(s) = sched.as_mut() {
                s.abort_all(router, metrics, traces);
            }
            break;
        }
        // with live sessions (or admittable work) the worker must keep
        // iterating the scheduler — poll the job queue with a short
        // timeout instead of parking on the condvar
        let sched_busy = sched.as_ref().is_some_and(|s| !s.is_idle())
            || (sched.is_some() && !shared.gen_queue.is_empty());
        let timeout = if draining || sched_active {
            Duration::ZERO
        } else if sched_busy {
            Duration::from_millis(1)
        } else if batcher.is_empty() {
            Duration::from_millis(50)
        } else {
            batcher.deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::ZERO)
        };
        match pop(shared, timeout) {
            Pop::Job(job) => match *job {
                Job::Score(e) => {
                    metrics.incr("requests", 1);
                    batcher.push(e, Instant::now());
                }
                Job::Generate(g) => {
                    // sequential mode only (the scheduler path enqueues
                    // GenTasks on gen_queue instead): the decode session
                    // runs on the popping worker, between that worker's
                    // score flushes. A session can run for many steps,
                    // so flush any score batch whose deadline already
                    // passed *first* — its replies must not wait behind
                    // the whole decode.
                    metrics.gauge_add("gen_queue_depth", -1);
                    flush_due(widx, engine, router, cfg, metrics,
                              &mut batcher, false, traces);
                    run_generate(widx, engine, router, g, metrics,
                                 traces);
                }
            },
            Pop::Timeout => {}
            Pop::Shutdown => draining = true,
        }
        flush_due(widx, engine, router, cfg, metrics, &mut batcher,
                  draining, traces);
        // one scheduler iteration between score flushes: admit, feed a
        // prefill chunk per pending sequence, run one mixed step batch
        if let Some(s) = sched.as_mut() {
            sched_active = s.iteration(engine, router, &shared.gen_queue,
                                       metrics, traces);
        }
        if draining && batcher.is_empty()
            && shared.queue.lock().unwrap().is_empty()
            && shared.gen_queue.is_empty()
            && sched.as_ref().is_none_or(|s| s.is_idle()) {
            break;
        }
    }
}

/// `Drain::Now`: answer everything still sitting in this worker's
/// batcher with a Rejected reply instead of executing it.
fn abort_batcher(batcher: &mut Batcher<Entry>, traces: &TraceRing) {
    while !batcher.is_empty() {
        for mut e in batcher.flush(Instant::now()) {
            let timings = finish_trace(&mut e.item.trace, "", true,
                                       Some(traces));
            let _ = e.item.reply.send(Response {
                id: e.item.id,
                variant: String::new(),
                latency: e.item.t_submit.elapsed(),
                timings,
                result: Err(ServeError::Rejected {
                    reason: "server shut down before the request ran"
                        .to_string(),
                }),
            });
        }
    }
}

/// Flush the worker's batcher when its deadline/size trigger has fired
/// (or unconditionally while draining) and execute the batch.
fn flush_due(widx: usize, engine: &Engine, router: &Mutex<Router>,
             cfg: &ServerConfig, metrics: &Arc<Metrics>,
             batcher: &mut Batcher<Entry>, draining: bool,
             traces: &TraceRing) {
    let now = Instant::now();
    if batcher.ready(now) || (draining && !batcher.is_empty()) {
        let entries = batcher.flush(now);
        if let Err(e) = execute_batch(engine, router, cfg, entries,
                                      metrics, traces) {
            metrics.incr("batch_errors", 1);
            eprintln!("[server worker {widx}] batch error: {e:#}");
        } else {
            metrics.incr(&format!("worker_{widx}_batches"), 1);
        }
    }
}

/// Run one decode request end to end on this worker: route + admit the
/// prompt, open a cached decode session on the variant's step program,
/// then sample/extend token by token with every cache-growing step
/// charged to the variant's [`super::kvcache::KvCacheManager`]. A false
/// `extend` verdict means the manager evicted this sequence: the live
/// session is dropped (its tensors go with it) and the request gets an
/// eviction error — other requests are untouched.
fn run_generate(widx: usize, engine: &Engine, router: &Mutex<Router>,
                mut g: GenEntry, metrics: &Arc<Metrics>,
                traces: &TraceRing) {
    use crate::eval::generate::pick_token;
    use crate::util::rng::Rng;

    // queue wait = submit → a worker actually starting the decode (the
    // scheduler path observes the same metric at first admission)
    metrics.observe("gen_queue_us", g.t_submit.elapsed());
    let mut trace = g.trace.take();
    if let Some(tr) = trace.as_mut() {
        tr.admitted();
    }
    // decode sessions are windowless — cfg.seq_len is the *score*
    // program's window and does not bound them. The real capacity check
    // (prompt + max_new - 1 vs session.max_tokens()) runs right after
    // the session opens, before any prefill cost.
    if g.params.prompt.is_empty() {
        metrics.incr("request_errors", 1);
        let timings = finish_trace(&mut trace, "", true, Some(traces));
        let _ = g.reply.send(Response {
            id: g.id,
            variant: String::new(),
            latency: g.t_submit.elapsed(),
            timings,
            result: Err(ServeError::Empty),
        });
        return;
    }
    // admission: reserve the prompt's cache footprint on a variant (the
    // router lock is held for the routing decision only, never across
    // the decode). The server-minted id is the accounting key.
    let routed = {
        let mut r = lock_unpoisoned(router);
        match r.route(g.id, g.params.prompt.len()) {
            Some(vidx) => {
                let v = &r.variants[vidx];
                (Some(vidx), v.step_program.clone(), v.name.clone(),
                 Some(v.weights.clone()))
            }
            None => (None, String::new(), String::new(), None),
        }
    };
    let (Some(vidx), program, vname, Some(weights)) = routed else {
        metrics.incr("gen_rejected", 1);
        let timings = finish_trace(&mut trace, "", true, Some(traces));
        let _ = g.reply.send(Response {
            id: g.id,
            variant: String::new(),
            latency: g.t_submit.elapsed(),
            timings,
            result: Err(ServeError::Rejected {
                reason: format!(
                    "no variant has KV budget for {} prompt tokens",
                    g.params.prompt.len()),
            }),
        });
        return;
    };
    let internal = |e: anyhow::Error| ServeError::Internal {
        reason: format!("{e:#}"),
    };
    let mut rng = Rng::new(g.params.seed);
    let mut tokens: Vec<i32> = Vec::with_capacity(g.params.max_new);
    let result: std::result::Result<(), ServeError> = (|| {
        let mut session = engine.program(&program)
            .and_then(|p| p.decode_session(&weights))
            .map_err(internal)?;
        // sessions are windowless but bounded by the model's positional
        // table: reject an overshooting request before paying the
        // prefill it would waste (the final sampled token is never fed
        // back, hence the -1)
        let need = g.params.prompt.len()
            + g.params.max_new.saturating_sub(1);
        if need > session.max_tokens() {
            return Err(ServeError::TooLong {
                need,
                max: session.max_tokens(),
            });
        }
        // re-admit at the session's REAL footprint: the variant's
        // nominal CacheKind routed the request, but what the budget
        // must cover is the DecodeState this session actually holds
        // (serve's latent-accounted variant may run dense-layout
        // compressed weights, 2d/token instead of rk+rv)
        let admitted = {
            let mut r = lock_unpoisoned(router);
            let cache = &mut r.variants[vidx].cache;
            let actual_bpt = cache.bytes_per_token_for(
                session.cache_kind(), session.n_layers());
            cache.admit_with(g.id, g.params.prompt.len(), actual_bpt)
        };
        if !admitted {
            // admit_with released the nominal reservation before
            // failing, so there is nothing left to return
            return Err(ServeError::Evicted {
                reason: format!(
                    "{}-token prompt does not fit the KV budget at the \
                     session's real footprint", g.params.prompt.len()),
            });
        }
        let t_pre = Instant::now();
        let mut logits = session.prefill(&g.params.prompt)
            .map_err(internal)?;
        if let Some(tr) = trace.as_mut() {
            tr.prefill_chunk(g.params.prompt.len() as u64,
                             t_pre.elapsed());
        }
        for step in 0..g.params.max_new {
            let next =
                pick_token(&logits, g.params.temperature, &mut rng) as i32;
            tokens.push(next);
            if let Some(s) = &g.stream {
                let _ = s.send(next);
                if let Some(tr) = trace.as_mut() {
                    tr.stream_emit();
                }
            }
            if step + 1 == g.params.max_new {
                // the final token is never fed back: its logits would go
                // unused and its cache row was never reserved
                if let Some(tr) = trace.as_mut() {
                    tr.step(Duration::ZERO);
                }
                break;
            }
            let alive = {
                let mut r = lock_unpoisoned(router);
                r.variants[vidx].cache.extend(g.id)
            };
            if !alive {
                return Err(ServeError::Evicted {
                    reason: format!(
                        "KV cache budget exhausted after {} of {} tokens",
                        tokens.len(), g.params.max_new),
                });
            }
            let t_step = Instant::now();
            logits = session.step(next).map_err(internal)?;
            if let Some(tr) = trace.as_mut() {
                tr.step(t_step.elapsed());
            }
        }
        Ok(())
    })();
    let evicted = matches!(result, Err(ServeError::Evicted { .. }));
    // a failed extend (and a failed admit_with) already removed the
    // sequence and returned its bytes; every other exit releases the
    // admission here. The manager's peak_bytes is exact and monotone, so
    // one gauge sample per request captures every admit/extend that
    // preceded it — no per-token metrics traffic, no sampling site to
    // forget.
    {
        let mut r = lock_unpoisoned(router);
        if !evicted {
            r.release(vidx, g.id);
        }
        sample_cache_peaks(&r, metrics);
    }
    let latency = g.t_submit.elapsed();
    match result {
        Ok(()) => {
            metrics.incr("gen_tokens", tokens.len() as u64);
            metrics.incr(&format!("worker_{widx}_gen_tokens"),
                         tokens.len() as u64);
            metrics.observe("gen_us", latency);
            let timings = finish_trace(&mut trace, &vname, false,
                                       Some(traces));
            let _ = g.reply.send(Response {
                id: g.id,
                variant: vname,
                latency,
                timings,
                result: Ok(Output::Generate(GenerateOutput { tokens })),
            });
        }
        Err(err) => {
            if evicted {
                metrics.incr("gen_evictions", 1);
                metrics.incr(&format!("worker_{widx}_evictions"), 1);
            } else {
                metrics.incr("gen_errors", 1);
            }
            let timings = finish_trace(&mut trace, &vname, true,
                                       Some(traces));
            let _ = g.reply.send(Response {
                id: g.id,
                variant: vname,
                latency,
                timings,
                result: Err(err),
            });
        }
    }
}

/// Retire a request's trace (when one rides it): the completed span
/// chain goes to the ring, the timing summary to the caller's response.
/// One retirement site shape for every reply path, so a trace can never
/// be finalized twice or leak un-retired.
fn finish_trace(trace: &mut Option<RequestTrace>, variant: &str,
                failed: bool, traces: Option<&TraceRing>)
                -> Option<Timings> {
    trace.take().map(|mut tr| {
        let t = tr.retire(failed);
        if let Some(ring) = traces {
            ring.push(tr.completed(variant, failed));
        }
        t
    })
}

/// Publish each variant's exact, monotone `peak_bytes` plus their sum
/// as the fleet gauge — one sample per completed request captures every
/// admit/extend that preceded it, with no per-token metrics traffic and
/// no sampling site to forget. (The sum of per-variant peaks is the
/// budget-relevant capacity number: each variant holds its own budget.)
pub(crate) fn sample_cache_peaks(r: &Router, metrics: &Arc<Metrics>) {
    let mut fleet = 0usize;
    let mut prefix = crate::coordinator::prefixcache::PrefixStats::default();
    for v in &r.variants {
        let peak = v.cache.peak_bytes;
        fleet += peak;
        metrics.set_max(&format!("cache_bytes_peak_{}", v.name),
                        peak as u64);
        let st = v.cache.prefix_stats();
        // per-variant labeled series alongside the fleet aggregates —
        // the dense/latent split is where the paper's benefit shows
        st.publish(&v.name, metrics);
        prefix.hits += st.hits;
        prefix.misses += st.misses;
        prefix.evictions += st.evictions;
        prefix.saved_tokens += st.saved_tokens;
        prefix.cached_blocks += st.cached_blocks;
    }
    metrics.set_max("cache_bytes_peak", fleet as u64);
    // prefix counters live in the per-variant caches (single source of
    // truth, bumped under the router lock); reconcile them into the
    // registry monotonically — re-sampling is idempotent
    metrics.counter_max("prefix_hits", prefix.hits);
    metrics.counter_max("prefix_misses", prefix.misses);
    metrics.counter_max("prefix_evictions", prefix.evictions);
    metrics.counter_max("prefix_saved_tokens", prefix.saved_tokens);
    metrics.gauge_set("prefix_blocks_cached", prefix.cached_blocks);
}

/// Reject a request the program can never score; the caller gets a
/// typed error response rather than a silently-NaN score or a dead
/// worker thread.
fn validate(tokens: &[i32], seq_len: usize) -> Option<ServeError> {
    if tokens.is_empty() {
        return Some(ServeError::Empty);
    }
    if tokens.len() > seq_len {
        return Some(ServeError::TooLong {
            need: tokens.len(),
            max: seq_len,
        });
    }
    None
}

fn execute_batch(engine: &Engine, router: &Mutex<Router>,
                 cfg: &ServerConfig,
                 entries: Vec<super::batcher::Pending<Entry>>,
                 metrics: &Arc<Metrics>, traces: &TraceRing)
                 -> Result<()> {
    if entries.is_empty() {
        return Ok(());
    }
    let mut valid = Vec::with_capacity(entries.len());
    for mut e in entries {
        match validate(&e.item.tokens, cfg.seq_len) {
            Some(err) => {
                metrics.incr("request_errors", 1);
                let timings = finish_trace(&mut e.item.trace, "", true,
                                           Some(traces));
                let _ = e.item.reply.send(Response {
                    id: e.item.id,
                    variant: String::new(),
                    latency: e.item.t_submit.elapsed(),
                    timings,
                    result: Err(err),
                });
            }
            None => valid.push(e),
        }
    }
    let b = cfg.program_batch;
    if valid.len() > b {
        // batcher misconfigured beyond the program shape: split rather
        // than silently NaN the overflow
        metrics.incr("batch_overflow", 1);
    }
    // groups are independent requests: one group's failure must not drop
    // the later groups (nor their replies) on the floor
    let mut first_err: Option<anyhow::Error> = None;
    let mut rest = valid;
    while !rest.is_empty() {
        let take = rest.len().min(b);
        let group: Vec<_> = rest.drain(..take).collect();
        if let Err(e) = execute_group(engine, router, cfg, group, metrics,
                                      traces) {
            first_err.get_or_insert(e);
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Execute one program-shaped group (≤ program_batch entries, all
/// validated non-empty). Every entry gets a response — error-carrying
/// when the execution itself fails — so callers never block on a dropped
/// reply sender.
fn execute_group(engine: &Engine, router: &Mutex<Router>,
                 cfg: &ServerConfig,
                 mut entries: Vec<super::batcher::Pending<Entry>>,
                 metrics: &Arc<Metrics>, traces: &TraceRing)
                 -> Result<()> {
    // the group leaves the batcher and hits the execution path now —
    // that is a score request's admission moment
    for e in entries.iter_mut() {
        if let Some(tr) = e.item.trace.as_mut() {
            tr.admitted();
        }
    }
    match score_group(engine, router, cfg, &entries, metrics) {
        Ok((nll, vname)) => {
            metrics.incr("batches", 1);
            metrics.incr(&format!("variant_{vname}"),
                         entries.len() as u64);
            for (i, mut e) in entries.into_iter().enumerate() {
                let latency = e.item.t_submit.elapsed();
                metrics.observe("request_us", latency);
                let timings = finish_trace(&mut e.item.trace, &vname,
                                           false, Some(traces));
                let _ = e.item.reply.send(Response {
                    id: e.item.id,
                    variant: vname.clone(),
                    latency,
                    timings,
                    result: Ok(Output::Score(ScoreOutput {
                        nll: nll.get(i).copied().unwrap_or(f32::NAN),
                    })),
                });
            }
            Ok(())
        }
        Err(err) => {
            let msg = format!("batch execution failed: {err:#}");
            for mut e in entries {
                let timings = finish_trace(&mut e.item.trace, "", true,
                                           Some(traces));
                let _ = e.item.reply.send(Response {
                    id: e.item.id,
                    variant: String::new(),
                    latency: e.item.t_submit.elapsed(),
                    timings,
                    result: Err(ServeError::Internal {
                        reason: msg.clone(),
                    }),
                });
            }
            Err(err)
        }
    }
}

/// Route + pad + execute one group; returns the per-slot nll vector and
/// the chosen variant name. Cache admission is released on every path
/// (the pre-split code leaked the admission when execution failed).
fn score_group(engine: &Engine, router: &Mutex<Router>,
               cfg: &ServerConfig,
               entries: &[super::batcher::Pending<Entry>],
               metrics: &Arc<Metrics>) -> Result<(Vec<f32>, String)> {
    // route the whole group to one variant (vLLM-style per-batch
    // placement); weights are Arc-shared so the router lock is not held
    // across the execution. The first entry's server-minted id is the
    // group's admission key: ids are unique across both request kinds,
    // so no decode session can ever share (and release) it.
    let admit_key = entries[0].item.id;
    let (vidx, program, vname, weights) = {
        let mut r = lock_unpoisoned(router);
        let vidx = r.route(admit_key, cfg.seq_len).unwrap_or(0);
        let v = &r.variants[vidx];
        (vidx, v.score_program.clone(), v.name.clone(), v.weights.clone())
    };
    let result: Result<Vec<f32>> = (|| {
        let prog = engine.program(&program)?;
        let b = cfg.program_batch;
        let t = cfg.seq_len;
        let mut flat = vec![0i32; b * t];
        for (i, e) in entries.iter().enumerate().take(b) {
            let toks = &e.item.tokens;
            let n = toks.len().min(t);
            flat[i * t..i * t + n].copy_from_slice(&toks[..n]);
            // left-fill short requests by repeating (keeps shapes static)
            for j in n..t {
                flat[i * t + j] = toks[j % n.max(1)];
            }
        }
        let tokens = ParamValue::I32 { shape: vec![b, t], data: flat };
        let t_exec = Instant::now();
        let nll = prog.run_f32(&[tokens], &weights)?;
        metrics.observe("exec_us", t_exec.elapsed());
        Ok(nll)
    })();
    {
        let mut r = lock_unpoisoned(router);
        r.release(vidx, admit_key);
        sample_cache_peaks(&r, metrics);
    }
    result.map(|nll| (nll, vname))
}
