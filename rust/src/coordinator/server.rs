//! The serving loop: N worker threads drain a shared request queue, each
//! with its own dynamic batcher; every flush is routed to a model variant,
//! padded to the program's fixed batch shape, executed on that worker's
//! backend, and replied per request. std::thread + Mutex/Condvar (tokio is
//! unavailable offline; the control flow is identical).
//!
//! Backends need not be Send (the PJRT client is `Rc`-based), so each
//! worker thread builds and owns its own [`Engine`] — requests/responses
//! cross the queue, executables never do. Variant weights are shared
//! read-only (`Arc`) through the router; router admission state is the
//! only cross-worker lock on the hot path and is held for routing
//! decisions only, never across an execution.
//!
//! Failure containment: engine-init failures surface from
//! [`Server::start`]; malformed requests (empty or over-long token lists)
//! get an error-carrying response instead of killing the worker; flushes
//! larger than the program batch split into multiple executions
//! (`batch_overflow` metric) instead of silently NaN-ing the overflow.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Policy, Router};
use crate::runtime::{Engine, ParamValue};

#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub id: u64,
    pub nll: f32,
    pub variant: String,
    pub latency: Duration,
    /// Per-request failure (empty token list, over-long request, …);
    /// `nll` is NaN when set.
    pub error: Option<String>,
}

pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// fixed program batch (manifest score_batch)
    pub program_batch: usize,
    pub seq_len: usize,
    /// worker threads, each owning its own Engine (min 1)
    pub workers: usize,
}

struct Entry {
    req: ScoreRequest,
    reply: mpsc::Sender<ScoreResponse>,
    t_submit: Instant,
}

/// State shared between submitters and workers: the request queue plus
/// lifecycle flags.
struct Shared {
    queue: Mutex<VecDeque<Entry>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// workers that finished engine init and are serving
    live: AtomicUsize,
}

/// Decrements `Shared::live` on drop — including a worker panic (e.g. a
/// poisoned lock), so `submit` starts refusing once no thread can serve
/// instead of queueing requests nobody will answer.
struct LiveGuard(Arc<Shared>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.live.fetch_sub(1, Ordering::SeqCst);
    }
}

enum Pop {
    Job(Box<Entry>),
    Timeout,
    Shutdown,
}

fn pop(shared: &Shared, timeout: Duration) -> Pop {
    let mut q = shared.queue.lock().unwrap();
    if let Some(e) = q.pop_front() {
        return Pop::Job(Box::new(e));
    }
    if shared.shutdown.load(Ordering::SeqCst) {
        return Pop::Shutdown;
    }
    if timeout.is_zero() {
        return Pop::Timeout;
    }
    let (mut q, _res) = shared.cv.wait_timeout(q, timeout).unwrap();
    if let Some(e) = q.pop_front() {
        return Pop::Job(Box::new(e));
    }
    if shared.shutdown.load(Ordering::SeqCst) {
        Pop::Shutdown
    } else {
        Pop::Timeout
    }
}

pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start `cfg.workers` worker threads; each constructs its own engine
    /// from the artifacts directory (the backend client is not Send).
    /// Fails — instead of leaving a dead server behind — when any worker's
    /// engine init fails.
    pub fn start(artifacts: PathBuf, router: Router, cfg: ServerConfig)
                 -> Result<Server> {
        // sanitize once; every downstream use relies on these minimums
        let mut cfg = cfg;
        cfg.workers = cfg.workers.max(1);
        cfg.program_batch = cfg.program_batch.max(1);
        let workers = cfg.workers;
        let metrics = Arc::new(Metrics::new());
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            live: AtomicUsize::new(0),
        });
        let router = Arc::new(Mutex::new(router));
        let cfg = Arc::new(cfg);
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = shared.clone();
            let router = router.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let artifacts = artifacts.clone();
            let init_tx = init_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("latentllm-serve-{w}"))
                .spawn(move || {
                    let engine = match Engine::new(&artifacts) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = init_tx.send(Err(e.context(format!(
                                "worker {w} engine init"))));
                            return;
                        }
                    };
                    // count live *before* reporting Ok so a submit racing
                    // with start() never sees zero workers spuriously
                    shared.live.fetch_add(1, Ordering::SeqCst);
                    let _live = LiveGuard(shared.clone());
                    let _ = init_tx.send(Ok(()));
                    drop(init_tx);
                    worker_loop(w, &engine, &shared, &router, &cfg,
                                &metrics);
                })
                .expect("spawn server worker");
            handles.push(handle);
        }
        drop(init_tx);
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match init_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!(
                        "server worker exited before engine init"));
                }
            }
        }
        if let Some(e) = first_err {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            for h in handles {
                let _ = h.join();
            }
            return Err(e.context("server start"));
        }
        Ok(Server { shared, handles, metrics })
    }

    /// Enqueue a request; the response arrives on the returned channel.
    /// Errors when the server is shutting down or no worker survived —
    /// callers keep their own thread alive either way.
    pub fn submit(&self, req: ScoreRequest)
                  -> Result<mpsc::Receiver<ScoreResponse>> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            bail!("server is shutting down");
        }
        if self.shared.live.load(Ordering::SeqCst) == 0 {
            bail!("no live server workers");
        }
        let (rtx, rrx) = mpsc::channel();
        self.shared.queue.lock().unwrap().push_back(Entry {
            req,
            reply: rtx,
            t_submit: Instant::now(),
        });
        self.shared.cv.notify_one();
        Ok(rrx)
    }

    /// Number of workers currently serving.
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.stop();
        self.metrics.clone()
    }

    fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(widx: usize, engine: &Engine, shared: &Shared,
               router: &Mutex<Router>, cfg: &ServerConfig,
               metrics: &Arc<Metrics>) {
    if cfg.workers.max(1) > 1 {
        // parallelism comes from the workers themselves; keep each
        // worker's tensor kernels serial instead of workers×pool-width
        // threads contending for the same cores
        crate::util::pool::Pool::mark_worker_thread();
    }
    let mut batcher: Batcher<Entry> = Batcher::new(cfg.batcher);
    let mut draining = false;
    loop {
        let timeout = if draining {
            Duration::ZERO
        } else if batcher.is_empty() {
            Duration::from_millis(50)
        } else {
            batcher.deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::ZERO)
        };
        match pop(shared, timeout) {
            Pop::Job(e) => {
                metrics.incr("requests", 1);
                batcher.push(*e, Instant::now());
            }
            Pop::Timeout => {}
            Pop::Shutdown => draining = true,
        }
        let now = Instant::now();
        if batcher.ready(now) || (draining && !batcher.is_empty()) {
            let entries = batcher.flush(now);
            if let Err(e) = execute_batch(engine, router, cfg, entries,
                                          metrics) {
                metrics.incr("batch_errors", 1);
                eprintln!("[server worker {widx}] batch error: {e:#}");
            } else {
                metrics.incr(&format!("worker_{widx}_batches"), 1);
            }
        }
        if draining && batcher.is_empty()
            && shared.queue.lock().unwrap().is_empty() {
            break;
        }
    }
}

/// Reject a request the program can never score; the caller gets a
/// response (with `error` set) rather than a silently-NaN score or a dead
/// worker thread.
fn validate(req: &ScoreRequest, seq_len: usize) -> Option<String> {
    if req.tokens.is_empty() {
        return Some("empty token list".to_string());
    }
    if req.tokens.len() > seq_len {
        return Some(format!("request length {} exceeds program seq_len \
                             {seq_len}", req.tokens.len()));
    }
    None
}

fn execute_batch(engine: &Engine, router: &Mutex<Router>,
                 cfg: &ServerConfig,
                 entries: Vec<super::batcher::Pending<Entry>>,
                 metrics: &Arc<Metrics>) -> Result<()> {
    if entries.is_empty() {
        return Ok(());
    }
    let mut valid = Vec::with_capacity(entries.len());
    for e in entries {
        match validate(&e.item.req, cfg.seq_len) {
            Some(reason) => {
                metrics.incr("request_errors", 1);
                let resp = ScoreResponse {
                    id: e.item.req.id,
                    nll: f32::NAN,
                    variant: String::new(),
                    latency: e.item.t_submit.elapsed(),
                    error: Some(reason),
                };
                let _ = e.item.reply.send(resp);
            }
            None => valid.push(e),
        }
    }
    let b = cfg.program_batch;
    if valid.len() > b {
        // batcher misconfigured beyond the program shape: split rather
        // than silently NaN the overflow
        metrics.incr("batch_overflow", 1);
    }
    // groups are independent requests: one group's failure must not drop
    // the later groups (nor their replies) on the floor
    let mut first_err: Option<anyhow::Error> = None;
    let mut rest = valid;
    while !rest.is_empty() {
        let take = rest.len().min(b);
        let group: Vec<_> = rest.drain(..take).collect();
        if let Err(e) = execute_group(engine, router, cfg, group, metrics) {
            first_err.get_or_insert(e);
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Execute one program-shaped group (≤ program_batch entries, all
/// validated non-empty). Every entry gets a response — error-carrying
/// when the execution itself fails — so callers never block on a dropped
/// reply sender.
fn execute_group(engine: &Engine, router: &Mutex<Router>,
                 cfg: &ServerConfig,
                 entries: Vec<super::batcher::Pending<Entry>>,
                 metrics: &Arc<Metrics>) -> Result<()> {
    let seq_id = entries[0].item.req.id;
    match score_group(engine, router, cfg, &entries, seq_id, metrics) {
        Ok((nll, vname)) => {
            metrics.incr("batches", 1);
            metrics.incr(&format!("variant_{vname}"),
                         entries.len() as u64);
            for (i, e) in entries.into_iter().enumerate() {
                let resp = ScoreResponse {
                    id: e.item.req.id,
                    nll: nll.get(i).copied().unwrap_or(f32::NAN),
                    variant: vname.clone(),
                    latency: e.item.t_submit.elapsed(),
                    error: None,
                };
                metrics.observe("request_us", resp.latency);
                let _ = e.item.reply.send(resp);
            }
            Ok(())
        }
        Err(err) => {
            let msg = format!("batch execution failed: {err:#}");
            for e in entries {
                let _ = e.item.reply.send(ScoreResponse {
                    id: e.item.req.id,
                    nll: f32::NAN,
                    variant: String::new(),
                    latency: e.item.t_submit.elapsed(),
                    error: Some(msg.clone()),
                });
            }
            Err(err)
        }
    }
}

/// Route + pad + execute one group; returns the per-slot nll vector and
/// the chosen variant name. Cache admission is released on every path
/// (the pre-split code leaked the admission when execution failed).
fn score_group(engine: &Engine, router: &Mutex<Router>,
               cfg: &ServerConfig,
               entries: &[super::batcher::Pending<Entry>], seq_id: u64,
               metrics: &Arc<Metrics>) -> Result<(Vec<f32>, String)> {
    // route the whole group to one variant (vLLM-style per-batch
    // placement); weights are Arc-shared so the router lock is not held
    // across the execution
    let (vidx, program, vname, weights) = {
        let mut r = router.lock().unwrap();
        let vidx = r.route(seq_id, cfg.seq_len).unwrap_or(0);
        let v = &r.variants[vidx];
        (vidx, v.score_program.clone(), v.name.clone(), v.weights.clone())
    };
    let result: Result<Vec<f32>> = (|| {
        let prog = engine.program(&program)?;
        let b = cfg.program_batch;
        let t = cfg.seq_len;
        let mut flat = vec![0i32; b * t];
        for (i, e) in entries.iter().enumerate().take(b) {
            let toks = &e.item.req.tokens;
            let n = toks.len().min(t);
            flat[i * t..i * t + n].copy_from_slice(&toks[..n]);
            // left-fill short requests by repeating (keeps shapes static)
            for j in n..t {
                flat[i * t + j] = toks[j % n.max(1)];
            }
        }
        let tokens = ParamValue::I32 { shape: vec![b, t], data: flat };
        let t_exec = Instant::now();
        let nll = prog.run_f32(&[tokens], &weights)?;
        metrics.observe("exec_us", t_exec.elapsed());
        Ok(nll)
    })();
    router.lock().unwrap().release(vidx, seq_id);
    result.map(|nll| (nll, vname))
}
