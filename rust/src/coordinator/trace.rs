//! Request-scoped tracing: a [`RequestTrace`] rides each score/generate
//! task through the coordinator, recording timestamped lifecycle events
//! (queued, admitted, prefix-adopted, prefill-chunk, step, stream-emit,
//! preempted, requeued, resumed, retired) and accumulating phase
//! durations. Completed traces land in a bounded [`TraceRing`] served
//! by `GET /debug/requests?n=K`, and every response carries a compact
//! [`Timings`] summary. Recording an event costs two `Instant::now()`
//! reads and a bounded vec push — cheap enough to default on — and
//! never touches the decode math, so traced runs stay token-identical.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Value;
use crate::util::lock_unpoisoned;

/// Per-trace event cap: a million-token stream must not balloon its
/// trace, so repeatable events (step, stream-emit, prefill-chunk) past
/// the cap are counted in `events_dropped` instead of stored. Terminal
/// events (retired) always record so span chains stay complete.
pub const MAX_TRACE_EVENTS: usize = 256;

/// Default capacity of the completed-trace ring.
pub const DEFAULT_RING_CAP: usize = 512;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EventKind {
    Queued,
    Admitted,
    PrefixAdopted,
    PrefillChunk,
    Step,
    StreamEmit,
    Preempted,
    Requeued,
    Resumed,
    Retired,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Admitted => "admitted",
            EventKind::PrefixAdopted => "prefix_adopted",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::Step => "step",
            EventKind::StreamEmit => "stream_emit",
            EventKind::Preempted => "preempted",
            EventKind::Requeued => "requeued",
            EventKind::Resumed => "resumed",
            EventKind::Retired => "retired",
        }
    }
}

/// One recorded event: offset from submission plus an event-specific
/// value (tokens for prefill chunks and prefix adoption, 0 otherwise).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub at_us: u64,
    pub kind: EventKind,
    pub value: u64,
}

/// The per-response timing summary (also embedded in HTTP replies).
/// `decode_us` is wall time of the step batches the request took part
/// in; under continuous batching a batch's duration is attributed to
/// every sequence it stepped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Timings {
    pub queue_us: u64,
    pub prefill_us: u64,
    pub decode_us: u64,
    pub total_us: u64,
    pub tokens: u64,
    pub preemptions: u32,
    pub prefix_hit: bool,
}

impl Timings {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("queue_us", (self.queue_us as usize).into()),
            ("prefill_us", (self.prefill_us as usize).into()),
            ("decode_us", (self.decode_us as usize).into()),
            ("total_us", (self.total_us as usize).into()),
            ("tokens", (self.tokens as usize).into()),
            ("preemptions", (self.preemptions as usize).into()),
            ("prefix_hit", self.prefix_hit.into()),
        ])
    }
}

/// A live trace carried by a task. Survives preempt→requeue→resume
/// because it is owned by the task that travels through the queue.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    /// "generate" or "score"
    pub kind: &'static str,
    t0: Instant,
    events: Vec<TraceEvent>,
    events_dropped: u64,
    queue_us: u64,
    prefill_us: u64,
    decode_us: u64,
    tokens: u64,
    preemptions: u32,
    prefix_hit: bool,
    prefix_saved_tokens: u64,
    /// set while queued (at submit and again at requeue), drained into
    /// `queue_us` on admit/resume
    queue_since: Option<Instant>,
}

impl RequestTrace {
    /// Start a trace at submission time; records the `queued` event.
    pub fn new(id: u64, kind: &'static str) -> Self {
        let t0 = Instant::now();
        let mut t = RequestTrace {
            id, kind, t0,
            events: Vec::new(),
            events_dropped: 0,
            queue_us: 0, prefill_us: 0, decode_us: 0,
            tokens: 0, preemptions: 0,
            prefix_hit: false, prefix_saved_tokens: 0,
            queue_since: Some(t0),
        };
        t.push(EventKind::Queued, 0);
        t
    }

    fn push(&mut self, kind: EventKind, value: u64) {
        if self.events.len() < MAX_TRACE_EVENTS
            || kind == EventKind::Retired {
            let at_us = self.t0.elapsed().as_micros() as u64;
            self.events.push(TraceEvent { at_us, kind, value });
        } else {
            self.events_dropped += 1;
        }
    }

    /// First admission (or re-admission after preemption): closes the
    /// open queue phase.
    pub fn admitted(&mut self) {
        if let Some(since) = self.queue_since.take() {
            self.queue_us += since.elapsed().as_micros() as u64;
        }
        let kind = if self.preemptions > 0 {
            EventKind::Resumed
        } else {
            EventKind::Admitted
        };
        self.push(kind, 0);
    }

    /// A prefix-cache hit adopted `saved` already-computed tokens.
    pub fn prefix_adopted(&mut self, saved: u64) {
        self.prefix_hit = true;
        self.prefix_saved_tokens += saved;
        self.push(EventKind::PrefixAdopted, saved);
    }

    /// One prefill chunk of `tokens` ran for `d`.
    pub fn prefill_chunk(&mut self, tokens: u64, d: Duration) {
        self.prefill_us += d.as_micros() as u64;
        self.push(EventKind::PrefillChunk, tokens);
    }

    /// One decode step retired a token; `d` is the wall time of the
    /// step batch this sequence was part of.
    pub fn step(&mut self, d: Duration) {
        self.tokens += 1;
        self.decode_us += d.as_micros() as u64;
        self.push(EventKind::Step, 0);
    }

    /// A sampled token went out on the streaming channel.
    pub fn stream_emit(&mut self) {
        self.push(EventKind::StreamEmit, 0);
    }

    /// Preemption: session dropped, task requeued at the queue head.
    /// Records both events and reopens the queue phase.
    pub fn preempted(&mut self) {
        self.preemptions += 1;
        self.push(EventKind::Preempted, 0);
        self.push(EventKind::Requeued, 0);
        self.queue_since = Some(Instant::now());
    }

    pub fn preemptions(&self) -> u32 {
        self.preemptions
    }

    /// Terminal transition; returns the response-facing summary.
    pub fn retire(&mut self, failed: bool) -> Timings {
        // a task that dies while queued still closes its queue phase
        if let Some(since) = self.queue_since.take() {
            self.queue_us += since.elapsed().as_micros() as u64;
        }
        self.push(EventKind::Retired, u64::from(failed));
        self.timings()
    }

    pub fn timings(&self) -> Timings {
        Timings {
            queue_us: self.queue_us,
            prefill_us: self.prefill_us,
            decode_us: self.decode_us,
            total_us: self.t0.elapsed().as_micros() as u64,
            tokens: self.tokens,
            preemptions: self.preemptions,
            prefix_hit: self.prefix_hit,
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Seal into the ring-buffer form (call after `retire`).
    pub fn completed(self, variant: &str, failed: bool)
                     -> CompletedTrace {
        let timings = self.timings();
        CompletedTrace {
            id: self.id,
            kind: self.kind,
            variant: variant.to_string(),
            failed,
            prefix_saved_tokens: self.prefix_saved_tokens,
            timings,
            events: self.events,
            events_dropped: self.events_dropped,
        }
    }
}

/// A finished request's span chain, as served by `/debug/requests`.
#[derive(Clone, Debug)]
pub struct CompletedTrace {
    pub id: u64,
    pub kind: &'static str,
    pub variant: String,
    pub failed: bool,
    pub prefix_saved_tokens: u64,
    pub timings: Timings,
    pub events: Vec<TraceEvent>,
    pub events_dropped: u64,
}

impl CompletedTrace {
    pub fn to_json(&self) -> Value {
        let events: Vec<Value> = self.events
            .iter()
            .map(|e| Value::obj(vec![
                ("t_us", (e.at_us as usize).into()),
                ("event", e.kind.name().into()),
                ("value", (e.value as usize).into()),
            ]))
            .collect();
        Value::obj(vec![
            ("id", (self.id as usize).into()),
            ("kind", self.kind.into()),
            ("variant", self.variant.as_str().into()),
            ("failed", self.failed.into()),
            ("prefix_saved_tokens",
             (self.prefix_saved_tokens as usize).into()),
            ("timings", self.timings.to_json()),
            ("events", Value::Arr(events)),
            ("events_dropped", (self.events_dropped as usize).into()),
        ])
    }
}

/// Bounded ring of completed traces: pushes past capacity evict the
/// oldest entry, so trace memory is O(capacity) however long the
/// server runs.
pub struct TraceRing {
    cap: usize,
    inner: Mutex<VecDeque<CompletedTrace>>,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing { cap: cap.max(1), inner: Mutex::new(VecDeque::new()) }
    }

    pub fn push(&self, t: CompletedTrace) {
        let mut g = lock_unpoisoned(&self.inner);
        if g.len() == self.cap {
            g.pop_front();
        }
        g.push_back(t);
    }

    /// Most recent `n` traces, newest first.
    pub fn recent(&self, n: usize) -> Vec<CompletedTrace> {
        let g = lock_unpoisoned(&self.inner);
        g.iter().rev().take(n).cloned().collect()
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new(DEFAULT_RING_CAP)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(t: &RequestTrace) -> Vec<EventKind> {
        t.events().iter().map(|e| e.kind).collect()
    }

    #[test]
    fn lifecycle_without_preemption() {
        let mut t = RequestTrace::new(7, "generate");
        t.admitted();
        t.prefix_adopted(6);
        t.prefill_chunk(2, Duration::from_micros(40));
        for _ in 0..3 {
            t.step(Duration::from_micros(10));
            t.stream_emit();
        }
        let timings = t.retire(false);
        assert_eq!(kinds(&t), vec![
            EventKind::Queued, EventKind::Admitted,
            EventKind::PrefixAdopted, EventKind::PrefillChunk,
            EventKind::Step, EventKind::StreamEmit,
            EventKind::Step, EventKind::StreamEmit,
            EventKind::Step, EventKind::StreamEmit,
            EventKind::Retired,
        ]);
        assert_eq!(timings.tokens, 3);
        assert_eq!(timings.preemptions, 0);
        assert!(timings.prefix_hit);
        assert_eq!(timings.prefill_us, 40);
        assert_eq!(timings.decode_us, 30);
        assert!(timings.total_us >= timings.prefill_us);
        let c = t.clone().completed("dense", false);
        assert_eq!(c.prefix_saved_tokens, 6);
        assert!(!c.failed);
        // offsets are monotone within the span chain
        let offs: Vec<u64> =
            c.events.iter().map(|e| e.at_us).collect();
        assert!(offs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn preempt_requeue_resume_emits_the_right_sequence() {
        let mut t = RequestTrace::new(1, "generate");
        t.admitted();
        t.prefill_chunk(4, Duration::from_micros(20));
        t.step(Duration::from_micros(5));
        t.preempted();
        // back through the queue: the second admission is a resume
        t.admitted();
        t.prefill_chunk(5, Duration::from_micros(25));
        t.step(Duration::from_micros(5));
        t.retire(false);
        assert_eq!(kinds(&t), vec![
            EventKind::Queued, EventKind::Admitted,
            EventKind::PrefillChunk, EventKind::Step,
            EventKind::Preempted, EventKind::Requeued,
            EventKind::Resumed, EventKind::PrefillChunk,
            EventKind::Step, EventKind::Retired,
        ]);
        let timings = t.timings();
        assert_eq!(timings.preemptions, 1);
        assert_eq!(timings.prefill_us, 45,
                   "re-prefill after resume accumulates");
        assert_eq!(timings.tokens, 2);
    }

    #[test]
    fn event_list_is_capped_but_aggregates_keep_counting() {
        let mut t = RequestTrace::new(2, "generate");
        t.admitted();
        for _ in 0..(2 * MAX_TRACE_EVENTS) {
            t.step(Duration::from_micros(1));
        }
        let timings = t.retire(false);
        // cap + the always-recorded terminal event
        assert_eq!(t.events().len(), MAX_TRACE_EVENTS + 1);
        assert_eq!(t.events().last().unwrap().kind, EventKind::Retired);
        assert_eq!(timings.tokens, 2 * MAX_TRACE_EVENTS as u64,
                   "dropping events must not drop token accounting");
        let c = t.completed("dense", false);
        assert!(c.events_dropped > 0);
    }

    #[test]
    fn ring_is_bounded_and_newest_first() {
        let ring = TraceRing::new(4);
        assert!(ring.is_empty());
        for id in 0..10u64 {
            let mut t = RequestTrace::new(id, "generate");
            t.retire(false);
            ring.push(t.completed("dense", false));
        }
        assert_eq!(ring.len(), 4);
        let ids: Vec<u64> =
            ring.recent(16).iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![9, 8, 7, 6]);
        assert_eq!(ring.recent(2).len(), 2);
    }

    #[test]
    fn json_shape_has_the_span_chain() {
        let mut t = RequestTrace::new(3, "score");
        t.admitted();
        let timings = t.retire(true);
        assert_eq!(timings.tokens, 0);
        let v = t.completed("latent30", true).to_json();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("score"));
        assert_eq!(v.get("variant").unwrap().as_str(), Some("latent30"));
        assert_eq!(v.get("failed"),
                   Some(&crate::util::json::Value::Bool(true)));
        let events = v.get("events").unwrap().as_arr().unwrap();
        let names: Vec<&str> = events.iter()
            .map(|e| e.get("event").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["queued", "admitted", "retired"]);
        assert!(v.get("timings").unwrap().get("queue_us").is_some());
    }
}
