//! Token-stream corpora (from artifacts/corpora.ltw) and calibration
//! activation sets (from artifacts/calib_<model>.ltw).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::model::io::read_ltw;
use crate::Matrix;

/// A named token stream with sequential batching (the eval protocol:
/// non-overlapping seq_len windows, batch-major).
#[derive(Clone, Debug)]
pub struct Corpus {
    pub name: String,
    pub tokens: Vec<i32>,
}

impl Corpus {
    /// Load `{name}.{split}` from corpora.ltw.
    pub fn load(path: impl AsRef<Path>, name: &str, split: &str)
                -> Result<Self> {
        let map = read_ltw(path)?;
        let key = format!("{name}.{split}");
        let t = map.get(&key).ok_or_else(|| anyhow!("no stream {key:?}"))?;
        Ok(Corpus { name: key, tokens: t.as_i32()?.to_vec() })
    }

    /// Non-overlapping [batch × seq_len] windows; the tail that doesn't
    /// fill a complete batch is dropped (matches the python evaluator).
    pub fn batches(&self, batch: usize, seq_len: usize) -> Vec<Vec<i32>> {
        let max_start = self.tokens.len().saturating_sub(seq_len + 1);
        let mut windows = Vec::new();
        let mut s = 0;
        while s < max_start {
            windows.push(self.tokens[s..s + seq_len].to_vec());
            s += seq_len;
        }
        let n_full = windows.len() / batch;
        (0..n_full)
            .map(|b| {
                let mut flat = Vec::with_capacity(batch * seq_len);
                for w in &windows[b * batch..(b + 1) * batch] {
                    flat.extend_from_slice(w);
                }
                flat
            })
            .collect()
    }

    /// The paper's calibration sampling: n random seq_len windows (seeded).
    pub fn calibration(&self, n: usize, seq_len: usize, seed: u64)
                       -> Vec<Vec<i32>> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let max_start = self.tokens.len() - seq_len - 1;
        (0..n)
            .map(|_| {
                let s = rng.below(max_start);
                self.tokens[s..s + seq_len].to_vec()
            })
            .collect()
    }
}

/// Per-layer calibration activations: `layers.{i}.{attn_x|o_x|mlp_x}`
/// as [d × l] column-token matrices (paper §5 protocol, collected by
/// python/compile/train.py::collect_calibration).
#[derive(Clone, Debug)]
pub struct CalibSet {
    layers: Vec<BTreeMap<String, Matrix>>,
}

impl CalibSet {
    pub fn load(path: impl AsRef<Path>, n_layers: usize) -> Result<Self> {
        let map = read_ltw(path)?;
        Self::from_map(&map, "", n_layers)
    }

    /// Build from a tensor map with key prefix (e.g. "lm." for llava-mini).
    pub fn from_map(map: &crate::model::io::TensorMap, prefix: &str,
                    n_layers: usize) -> Result<Self> {
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let mut layer = BTreeMap::new();
            for kind in ["attn_x", "o_x", "mlp_x"] {
                let key = format!("{prefix}layers.{i}.{kind}");
                let t = map.get(&key)
                    .ok_or_else(|| anyhow!("missing calibration {key:?}"))?;
                layer.insert(kind.to_string(), t.to_matrix()?);
            }
            layers.push(layer);
        }
        Ok(CalibSet { layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn x(&self, layer: usize, kind: &str) -> &Matrix {
        &self.layers[layer][kind]
    }

    /// Build directly from per-layer matrices (used by ablation resampling).
    pub fn from_layers(layers: Vec<BTreeMap<String, Matrix>>) -> Self {
        CalibSet { layers }
    }

    /// Synthetic calibration for tests: correlated Gaussian activations.
    pub fn synthetic(n_layers: usize, d: usize, l: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let sigma = crate::util::rng::decaying_covariance(d, 0.8);
        let chol = crate::tensor::cholesky(&sigma).unwrap();
        let layers = (0..n_layers)
            .map(|_| {
                let mut layer = BTreeMap::new();
                for kind in ["attn_x", "o_x", "mlp_x"] {
                    let g = rng.normal_matrix(d, l);
                    layer.insert(kind.to_string(), chol.matmul(&g));
                }
                layer
            })
            .collect();
        CalibSet { layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_disjoint_and_full() {
        let c = Corpus { name: "t".into(), tokens: (0..1000).collect() };
        let b = c.batches(2, 64);
        assert!(!b.is_empty());
        for flat in &b {
            assert_eq!(flat.len(), 2 * 64);
        }
        // windows don't overlap: first elements stride by seq_len
        assert_eq!(b[0][0], 0);
        assert_eq!(b[0][64], 64);
    }

    #[test]
    fn calibration_seeded() {
        let c = Corpus { name: "t".into(), tokens: (0..5000).collect() };
        let a = c.calibration(4, 32, 7);
        let b = c.calibration(4, 32, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].len(), 32);
    }

    #[test]
    fn synthetic_calib_shapes() {
        let cal = CalibSet::synthetic(2, 8, 40, 3);
        assert_eq!(cal.n_layers(), 2);
        assert_eq!(cal.x(0, "attn_x").rows(), 8);
        assert_eq!(cal.x(1, "mlp_x").cols(), 40);
    }
}
