//! Corpus loading, batching, and a rust-side synthetic generator used by
//! tests and benches (deterministic, independent of the python artifacts).

pub mod corpus;
pub mod synth;

pub use corpus::{CalibSet, Corpus};
