//! Rust-side synthetic corpus generator — the same topic-switching bigram
//! family as python/compile/data.py (different seeds; used by unit tests,
//! benches, and the serving example's request generator so they don't
//! depend on artifacts being present) — plus a full offline artifacts
//! synthesizer ([`write_test_artifacts`]): manifest + random dense and
//! latent weight sets + corpora + calibration in a directory, so the CLI
//! (`latentllm synth-artifacts`), bench_decode, and CI smoke runs drive
//! the real Engine/serving stack with zero python in the loop.

use std::path::Path;

use anyhow::Result;

use crate::model::config::MiniConfig;
use crate::model::io::{write_ltw, Tensor, TensorMap};
use crate::util::json::Value;
use crate::util::rng::Rng;

pub struct SynthCorpus {
    pub vocab: usize,
    tables: Vec<Vec<Vec<u32>>>, // [topic][token][branch]
    cum: Vec<f64>,
    switch: f64,
}

impl SynthCorpus {
    pub fn new(vocab: usize, n_topics: usize, branch: usize, zipf_a: f64,
               switch: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tables = (0..n_topics)
            .map(|_| {
                (0..vocab)
                    .map(|_| (0..branch)
                        .map(|_| rng.below(vocab) as u32)
                        .collect())
                    .collect()
            })
            .collect();
        let probs: Vec<f64> =
            (1..=branch).map(|i| 1.0 / (i as f64).powf(zipf_a)).collect();
        let total: f64 = probs.iter().sum();
        let mut cum = Vec::with_capacity(branch);
        let mut acc = 0.0;
        for p in probs {
            acc += p / total;
            cum.push(acc);
        }
        SynthCorpus { vocab, tables, cum, switch }
    }

    pub fn generate(&self, n: usize, walk_seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(walk_seed);
        let mut tok = rng.below(self.vocab);
        let mut topic = 0usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.uniform() < self.switch {
                topic = rng.below(self.tables.len());
            }
            let u = rng.uniform();
            let slot = self.cum.iter().position(|&c| u < c)
                .unwrap_or(self.cum.len() - 1);
            tok = self.tables[topic][tok][slot] as usize;
            out.push(tok as i32);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Offline artifacts synthesizer
// ---------------------------------------------------------------------------

fn num(v: usize) -> Value {
    Value::Num(v as f64)
}

fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

fn str_arr(names: &[&str]) -> Value {
    Value::Arr(names.iter().map(|n| s(n)).collect())
}

fn lm_config_json(cfg: &MiniConfig) -> Value {
    Value::obj(vec![
        ("name", s(cfg.name)),
        ("vocab", num(cfg.vocab)),
        ("d", num(cfg.d)),
        ("n_layers", num(cfg.n_layers)),
        ("n_heads", num(cfg.n_heads)),
        ("d_i", num(cfg.d_i)),
        ("max_len", num(cfg.max_len)),
    ])
}

/// Random latent/MLA weight set in the python `latent_shapes` layout
/// (compression planes `a*`, per-head decompressors `b*_heads`, low-rank
/// output/MLP factors). Ranks scale with the model width.
pub fn random_latent_weights(cfg: &MiniConfig, seed: u64) -> crate::model::Weights {
    let (d, h, di) = (cfg.d, cfg.n_heads, cfg.d_i);
    let dh = d / h.max(1);
    // the single source for the latent ranks — admission accounting
    // reads the same function, so weights and CacheKind cannot drift
    let (r_qkv, _) = latent_demo_ranks(d);
    let r_low = (d / 6).max(2);
    let mut rng = Rng::new(seed);
    let sc = 0.5 / (d as f64).sqrt();
    let mut map = TensorMap::new();
    let rand_t = |rng: &mut Rng, shape: &[usize], scale: f64| {
        let n: usize = shape.iter().product();
        Tensor::F32 {
            shape: shape.to_vec(),
            data: (0..n).map(|_| (rng.normal() * scale) as f32).collect(),
        }
    };
    let const_t = |shape: &[usize], v: f32| {
        let n: usize = shape.iter().product();
        Tensor::F32 { shape: shape.to_vec(), data: vec![v; n] }
    };
    map.insert("tok_emb".to_string(),
               rand_t(&mut rng, &[cfg.vocab, d], sc));
    map.insert("pos_emb".to_string(),
               rand_t(&mut rng, &[cfg.max_len, d], sc));
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        map.insert(format!("{p}ln1.g"), const_t(&[d], 1.0));
        map.insert(format!("{p}ln1.b"), const_t(&[d], 0.0));
        for (a, b, bias) in [("aq", "bq_heads", "bq"),
                             ("ak", "bk_heads", "bk"),
                             ("av", "bv_heads", "bv")] {
            map.insert(format!("{p}attn.{a}"),
                       rand_t(&mut rng, &[r_qkv, d], sc));
            map.insert(format!("{p}attn.{b}"),
                       rand_t(&mut rng, &[h, dh, r_qkv], sc));
            map.insert(format!("{p}attn.{bias}"), const_t(&[d], 0.01));
        }
        map.insert(format!("{p}attn.ao_heads"),
                   rand_t(&mut rng, &[r_low, h * dh], sc));
        map.insert(format!("{p}attn.bo_mat"),
                   rand_t(&mut rng, &[d, r_low], sc));
        map.insert(format!("{p}attn.bo"), const_t(&[d], 0.0));
        map.insert(format!("{p}ln2.g"), const_t(&[d], 1.0));
        map.insert(format!("{p}ln2.b"), const_t(&[d], 0.0));
        map.insert(format!("{p}mlp.au"), rand_t(&mut rng, &[r_low, d], sc));
        map.insert(format!("{p}mlp.bu_mat"),
                   rand_t(&mut rng, &[di, r_low], sc));
        map.insert(format!("{p}mlp.bu"), const_t(&[di], 0.01));
        map.insert(format!("{p}mlp.ad"), rand_t(&mut rng, &[r_low, di], sc));
        map.insert(format!("{p}mlp.bd_mat"),
                   rand_t(&mut rng, &[d, r_low], sc));
        map.insert(format!("{p}mlp.bd"), const_t(&[d], 0.0));
    }
    map.insert("lnf.g".to_string(), const_t(&[d], 1.0));
    map.insert("lnf.b".to_string(), const_t(&[d], 0.0));
    crate::model::Weights::new(map)
}

/// Latent ranks [`random_latent_weights`] bakes into a width-`d` model —
/// what a `CacheKind::Latent` admission for its decode sessions should
/// use.
pub fn latent_demo_ranks(d: usize) -> (usize, usize) {
    let r = (d / 8).max(2);
    (r, r)
}

/// Write a complete synthetic artifacts directory for `cfg`:
/// `manifest.json` (score/step + latent score/step program table, model
/// config, `latent_demo` record), `model_<name>.ltw` (random dense
/// weights), `latent_model_<tag>.ltw`, `corpora.ltw`
/// (`synthwiki.{train,test}` streams), and `calib_<name>.ltw`. Returns
/// the latent demo tag. Everything downstream of `make artifacts` that
/// the rust stack needs, generated offline in milliseconds.
pub fn write_test_artifacts(dir: impl AsRef<Path>, cfg: &MiniConfig,
                            seed: u64) -> Result<String> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let name = cfg.name;
    let tag = format!("{name}-demo");

    let as_arr = |v: &[String]| {
        Value::Arr(v.iter().map(|n| s(n)).collect())
    };
    let mut score_order = vec!["tokens".to_string()];
    score_order.extend(cfg.param_names());
    let mut step_order = vec!["tokens".to_string(), "lens".to_string()];
    step_order.extend(cfg.param_names());
    let mut programs = std::collections::BTreeMap::new();
    programs.insert(format!("score_{name}"), as_arr(&score_order));
    programs.insert(format!("step_{name}"), as_arr(&step_order));
    programs.insert(format!("latent_score_{tag}"), str_arr(&["tokens"]));
    programs.insert(format!("latent_step_{tag}"),
                    str_arr(&["tokens", "lens"]));
    let programs = Value::Obj(programs);
    let manifest = Value::obj(vec![
        ("seq_len", num(cfg.max_len)),
        ("score_batch", num(8)),
        ("vocab", num(cfg.vocab)),
        ("programs", programs),
        ("models", Value::obj(vec![(
            name, Value::obj(vec![("config", lm_config_json(cfg))]),
        )])),
        ("latent_demo", Value::obj(vec![
            ("tag", s(&tag)),
            ("model", s(name)),
        ])),
        ("synthesized", Value::Bool(true)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty())?;

    let dense = crate::compress::pipeline::tests_support::random_weights(
        cfg, seed);
    write_ltw(dir.join(format!("model_{name}.ltw")), dense.map())?;
    let latent = random_latent_weights(cfg, seed + 1);
    write_ltw(dir.join(format!("latent_model_{tag}.ltw")), latent.map())?;

    // topic-switching bigram corpus, train + test splits
    let gen = SynthCorpus::new(cfg.vocab, 4, 8, 1.2, 0.02, seed + 2);
    let mut corpora = TensorMap::new();
    for (split, n, walk) in [("train", 20_000usize, 1u64), ("test", 8_000, 2)]
    {
        corpora.insert(format!("synthwiki.{split}"), Tensor::I32 {
            shape: vec![n],
            data: gen.generate(n, walk),
        });
    }
    write_ltw(dir.join("corpora.ltw"), &corpora)?;

    // calibration activations: correlated Gaussians, [d × l] per module
    let mut rng = Rng::new(seed + 3);
    let mut calib = TensorMap::new();
    let l = 64usize;
    for i in 0..cfg.n_layers {
        for kind in ["attn_x", "o_x", "mlp_x"] {
            let m = rng.normal_matrix(cfg.d, l);
            calib.insert(format!("layers.{i}.{kind}"), Tensor::F32 {
                shape: vec![cfg.d, l],
                data: m.to_f32(),
            });
        }
    }
    write_ltw(dir.join(format!("calib_{name}.ltw")), &calib)?;
    Ok(tag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_deterministic() {
        let c = SynthCorpus::new(128, 3, 6, 1.3, 0.02, 42);
        let a = c.generate(500, 1);
        let b = c.generate(500, 1);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..128).contains(&t)));
        // structure: bigram successors are limited -> repeated pairs common
        let mut pairs = std::collections::HashSet::new();
        for w in a.windows(2) {
            pairs.insert((w[0], w[1]));
        }
        assert!(pairs.len() < 450, "should be far from iid ({})", pairs.len());
    }
}
