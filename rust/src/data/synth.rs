//! Rust-side synthetic corpus generator — the same topic-switching bigram
//! family as python/compile/data.py (different seeds; used by unit tests,
//! benches, and the serving example's request generator so they don't
//! depend on artifacts being present).

use crate::util::rng::Rng;

pub struct SynthCorpus {
    pub vocab: usize,
    tables: Vec<Vec<Vec<u32>>>, // [topic][token][branch]
    cum: Vec<f64>,
    switch: f64,
}

impl SynthCorpus {
    pub fn new(vocab: usize, n_topics: usize, branch: usize, zipf_a: f64,
               switch: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tables = (0..n_topics)
            .map(|_| {
                (0..vocab)
                    .map(|_| (0..branch)
                        .map(|_| rng.below(vocab) as u32)
                        .collect())
                    .collect()
            })
            .collect();
        let probs: Vec<f64> =
            (1..=branch).map(|i| 1.0 / (i as f64).powf(zipf_a)).collect();
        let total: f64 = probs.iter().sum();
        let mut cum = Vec::with_capacity(branch);
        let mut acc = 0.0;
        for p in probs {
            acc += p / total;
            cum.push(acc);
        }
        SynthCorpus { vocab, tables, cum, switch }
    }

    pub fn generate(&self, n: usize, walk_seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(walk_seed);
        let mut tok = rng.below(self.vocab);
        let mut topic = 0usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.uniform() < self.switch {
                topic = rng.below(self.tables.len());
            }
            let u = rng.uniform();
            let slot = self.cum.iter().position(|&c| u < c)
                .unwrap_or(self.cum.len() - 1);
            tok = self.tables[topic][tok][slot] as usize;
            out.push(tok as i32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_deterministic() {
        let c = SynthCorpus::new(128, 3, 6, 1.3, 0.02, 42);
        let a = c.generate(500, 1);
        let b = c.generate(500, 1);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..128).contains(&t)));
        // structure: bigram successors are limited -> repeated pairs common
        let mut pairs = std::collections::HashSet::new();
        for w in a.windows(2) {
            pairs.insert((w[0], w[1]));
        }
        assert!(pairs.len() < 450, "should be far from iid ({})", pairs.len());
    }
}
