//! Multimodal answer-reasoning accuracy with the paper's Table 4 breakdown:
//! subjects NAT/SOC/LAN, context modalities TXT/IMG/NO, grades G1-6/G7-12.

use anyhow::Result;

use crate::model::io::TensorMap;
use crate::model::Weights;
use crate::runtime::{Engine, ParamValue};

pub const SUBJECTS: [&str; 3] = ["NAT", "SOC", "LAN"];
pub const MODALITIES: [&str; 3] = ["TXT", "IMG", "NO"];
pub const GRADES: [&str; 2] = ["G1-6", "G7-12"];

#[derive(Clone, Debug, Default)]
pub struct MmBreakdown {
    pub avg: f64,
    pub by_subject: [f64; 3],
    pub by_modality: [f64; 3],
    pub by_grade: [f64; 2],
    pub n: usize,
}

impl MmBreakdown {
    /// Table 4 column order: NAT SOC LAN | TXT IMG NO | G1-6 G7-12 | Avg.
    pub fn row(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(9);
        v.extend_from_slice(&self.by_subject);
        v.extend_from_slice(&self.by_modality);
        v.extend_from_slice(&self.by_grade);
        v.push(self.avg);
        v
    }
}

/// Evaluate llava-mini answer accuracy via the `mm_score_llava-mini`
/// program. `data` is the mm_data.ltw map (images/tokens/labels/cats).
pub fn evaluate_mm(engine: &Engine, program: &str, weights: &Weights,
                   data: &TensorMap, batch: usize) -> Result<MmBreakdown> {
    let images = data["images"].as_f32()?;
    let tokens = data["tokens"].as_i32()?;
    let labels = data["labels"].as_i32()?;
    let cats = data["cats"].as_i32()?;
    let n = data["labels"].shape()[0];
    let text_len = data["tokens"].shape()[1];
    let img_hw = 16 * 16;

    let prog = engine.program(program)?;
    let mut correct = vec![false; n];
    let mut s = 0usize;
    while s < n {
        let e = (s + batch).min(n);
        // pad the final batch to the fixed program batch size
        let mut im = vec![0.0f32; batch * img_hw];
        let mut tk = vec![0i32; batch * text_len];
        im[..(e - s) * img_hw]
            .copy_from_slice(&images[s * img_hw..e * img_hw]);
        tk[..(e - s) * text_len]
            .copy_from_slice(&tokens[s * text_len..e * text_len]);
        let logits = prog.run_f32(
            &[ParamValue::F32 { shape: vec![batch, 16, 16], data: im },
              ParamValue::I32 { shape: vec![batch, text_len], data: tk }],
            weights)?;
        let n_ans = logits.len() / batch;
        for (bi, item) in (s..e).enumerate() {
            let row = &logits[bi * n_ans..(bi + 1) * n_ans];
            let pred = row.iter().enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32).unwrap_or(-1);
            correct[item] = pred == labels[item];
        }
        s = e;
    }

    let mut out = MmBreakdown { n, ..Default::default() };
    let frac = |mask: &dyn Fn(usize) -> bool| -> f64 {
        let (mut num, mut den) = (0usize, 0usize);
        for i in 0..n {
            if mask(i) {
                den += 1;
                if correct[i] {
                    num += 1;
                }
            }
        }
        if den == 0 { 0.0 } else { num as f64 / den as f64 }
    };
    out.avg = frac(&|_| true);
    for s_i in 0..3 {
        out.by_subject[s_i] = frac(&|i| cats[i * 3] == s_i as i32);
    }
    for m_i in 0..3 {
        out.by_modality[m_i] = frac(&|i| cats[i * 3 + 1] == m_i as i32);
    }
    for g_i in 0..2 {
        out.by_grade[g_i] = frac(&|i| cats[i * 3 + 2] == g_i as i32);
    }
    Ok(out)
}
