//! Batched autoregressive generation — the serving decode path.
//!
//! Two modes share one entry point:
//!
//! * **Incremental (default)** — one [`crate::runtime::DecodeSession`]
//!   per lane: the prompt is prefilled once, then each new token is a
//!   single-row forward against the per-layer KV/latent caches — O(d·T)
//!   per token, O(T) total scaling (bench_decode). Context is windowless:
//!   sessions extend absolute positions up to the model's positional
//!   table.
//! * **Full-window recompute (`use_cache = false`, CLI `--no-cache`)** —
//!   the pre-session reference path through the `step_*` programs
//!   (tokens [B,T], lens [B] → next-token logits [B,V]): a sliding
//!   window of the last T tokens re-executed every step, O(T²) per
//!   emitted token. Kept as the equivalence oracle — greedy decode is
//!   pinned token-for-token identical to the cached path by
//!   tests/decode.rs — and for sequences that must slide past the
//!   positional table.
//!
//! Both modes consume the sampling RNG in the same lane-major order, so
//! temperature sampling is also reproducible across modes.

use anyhow::{bail, ensure, Context, Result};

use crate::model::Weights;
use crate::runtime::{Engine, ParamValue};
use crate::util::rng::Rng;

pub struct GenerateOpts {
    pub max_new: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling
    pub temperature: f64,
    pub seed: u64,
    /// incremental KV-cached decode (default); false = full-window
    /// recompute reference
    pub use_cache: bool,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        GenerateOpts { max_new: 32, temperature: 0.0, seed: 0,
                       use_cache: true }
    }
}

pub struct GenerateResult {
    pub sequences: Vec<Vec<i32>>,
    pub tokens_generated: usize,
    pub seconds: f64,
    pub tokens_per_sec: f64,
    /// peak cached floats across all lanes' sessions (0 on the
    /// recompute path, which holds no state)
    pub peak_cache_elements: usize,
}

/// Decode `prompts` (≤ program batch) for `opts.max_new` steps.
pub fn generate(engine: &Engine, program: &str, weights: &Weights,
                prompts: &[Vec<i32>], batch: usize, seq_len: usize,
                vocab: usize, opts: &GenerateOpts) -> Result<GenerateResult> {
    if prompts.is_empty() {
        bail!("generate: no prompts");
    }
    if prompts.len() > batch {
        bail!("generate: {} prompts exceed the program batch of {batch} \
               lanes", prompts.len());
    }
    // an empty prompt would reach the program as lens = 0 and decode
    // from padding — reject it up front with the lane index
    for (i, p) in prompts.iter().enumerate() {
        if p.is_empty() {
            bail!("generate: prompt {i} is empty");
        }
    }
    if opts.use_cache {
        generate_cached(engine, program, weights, prompts, vocab, opts)
    } else {
        generate_recompute(engine, program, weights, prompts, batch,
                           seq_len, vocab, opts)
    }
}

/// Incremental path: prefill each lane's session once, then lockstep
/// single-token steps (lane-major, matching the recompute path's RNG
/// consumption order).
fn generate_cached(engine: &Engine, program: &str, weights: &Weights,
                   prompts: &[Vec<i32>], vocab: usize, opts: &GenerateOpts)
                   -> Result<GenerateResult> {
    let prog = engine.program(program)?;
    let mut rng = Rng::new(opts.seed);
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    let t0 = std::time::Instant::now();

    let mut lanes = Vec::with_capacity(prompts.len());
    for (i, p) in prompts.iter().enumerate() {
        let mut session = prog.decode_session(weights)
            .with_context(|| format!("lane {i}"))?;
        // fail fast: an overshooting request would pay the prefill and
        // most of the decode before the positional table bails (the
        // final sampled token is never fed back, hence the -1)
        let need = p.len() + opts.max_new.saturating_sub(1);
        ensure!(need <= session.max_tokens(),
                "lane {i}: prompt {} + {} new tokens needs {need} \
                 positions but the model's context holds {} — the \
                 recompute path (use_cache = false / --no-cache) slides \
                 instead", p.len(), opts.max_new, session.max_tokens());
        let logits = session.prefill(p)
            .with_context(|| format!("lane {i}: prefill {} tokens",
                                     p.len()))?;
        ensure!(logits.len() == vocab,
                "lane {i}: prefill returned {} logits, expected vocab \
                 {vocab}", logits.len());
        lanes.push((session, logits));
    }
    let live_elements = |lanes: &[(Box<dyn crate::runtime::DecodeSession>,
                                   Vec<f32>)]| {
        lanes.iter().map(|(s, _)| s.cache_elements()).sum::<usize>()
    };
    let mut peak_cache = live_elements(&lanes);
    for step in 0..opts.max_new {
        for (i, (session, logits)) in lanes.iter_mut().enumerate() {
            let next = pick_token(logits, opts.temperature, &mut rng) as i32;
            seqs[i].push(next);
            // the final sampled token is never fed back: its logits
            // would go unused
            if step + 1 < opts.max_new {
                *logits = session.step(next)
                    .with_context(|| format!("lane {i}: step {step}"))?;
                ensure!(logits.len() == vocab,
                        "lane {i}: step returned {} logits, expected \
                         vocab {vocab}", logits.len());
            }
        }
        // all concurrently live sessions count toward the footprint
        peak_cache = peak_cache.max(live_elements(&lanes));
    }
    Ok(finish(seqs, prompts.len(), opts.max_new, t0, peak_cache))
}

/// Full-window reference path: re-feed the last `seq_len` tokens of
/// every lane through the fixed-shape step program each round.
fn generate_recompute(engine: &Engine, program: &str, weights: &Weights,
                      prompts: &[Vec<i32>], batch: usize, seq_len: usize,
                      vocab: usize, opts: &GenerateOpts)
                      -> Result<GenerateResult> {
    let prog = engine.program(program)?;
    let mut rng = Rng::new(opts.seed);
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    let t0 = std::time::Instant::now();

    for _ in 0..opts.max_new {
        let mut flat = vec![0i32; batch * seq_len];
        let mut lens = vec![1i32; batch];
        for (i, s) in seqs.iter().enumerate() {
            let window = if s.len() > seq_len {
                &s[s.len() - seq_len..]
            } else {
                &s[..]
            };
            flat[i * seq_len..i * seq_len + window.len()]
                .copy_from_slice(window);
            lens[i] = window.len() as i32;
        }
        let logits = prog.run_f32(
            &[ParamValue::I32 { shape: vec![batch, seq_len], data: flat },
              ParamValue::I32 { shape: vec![batch], data: lens }],
            weights)?;
        ensure!(logits.len() == batch * vocab,
                "step program returned {} logits for batch {batch} × \
                 vocab {vocab}", logits.len());
        for (i, s) in seqs.iter_mut().enumerate() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            s.push(pick_token(row, opts.temperature, &mut rng) as i32);
        }
    }
    Ok(finish(seqs, prompts.len(), opts.max_new, t0, 0))
}

fn finish(seqs: Vec<Vec<i32>>, active: usize, max_new: usize,
          t0: std::time::Instant, peak_cache_elements: usize)
          -> GenerateResult {
    let seconds = t0.elapsed().as_secs_f64();
    let tokens_generated = active * max_new;
    GenerateResult {
        sequences: seqs,
        tokens_generated,
        seconds,
        tokens_per_sec: tokens_generated as f64 / seconds.max(1e-9),
        peak_cache_elements,
    }
}

/// Greedy argmax at temperature ≤ 0, softmax sampling otherwise. Public
/// so the server's decode path picks tokens identically to the eval
/// loops.
pub fn pick_token(row: &[f32], temperature: f64, rng: &mut Rng) -> usize {
    if temperature <= 0.0 {
        argmax(row)
    } else {
        sample(row, temperature, rng)
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter().enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i).unwrap_or(0)
}

fn sample(row: &[f32], temp: f64, rng: &mut Rng) -> usize {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let weights: Vec<f64> = row.iter()
        .map(|&l| ((l as f64 - max) / temp).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sample_bounds() {
        let row = vec![0.1f32, 3.0, -2.0, 1.5];
        assert_eq!(argmax(&row), 1);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample(&row, 1.0, &mut rng)] += 1;
        }
        // the max-logit token dominates; impossible tokens stay rare
        assert!(counts[1] > counts[2]);
        assert!(counts[1] > 1000);
        // greedy == temperature → 0 limit
        for _ in 0..50 {
            assert_eq!(sample(&row, 1e-6, &mut rng), 1);
        }
        assert_eq!(pick_token(&row, 0.0, &mut rng), 1);
    }
}
