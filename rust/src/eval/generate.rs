//! Batched autoregressive generation through the `step_*` programs —
//! the serving decode path. The program signature is fixed
//! (tokens [B,T], lens [B], weights…) → next-token logits [B,V], so the
//! generator keeps a sliding window of the last T tokens per sequence and
//! decodes all B lanes in lockstep (static-shape continuous decode).

use anyhow::Result;

use crate::model::Weights;
use crate::runtime::{Engine, ParamValue};
use crate::util::rng::Rng;

pub struct GenerateOpts {
    pub max_new: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling
    pub temperature: f64,
    pub seed: u64,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        GenerateOpts { max_new: 32, temperature: 0.0, seed: 0 }
    }
}

pub struct GenerateResult {
    pub sequences: Vec<Vec<i32>>,
    pub tokens_generated: usize,
    pub seconds: f64,
    pub tokens_per_sec: f64,
}

/// Decode `prompts` (≤ program batch) for `opts.max_new` steps.
pub fn generate(engine: &Engine, program: &str, weights: &Weights,
                prompts: &[Vec<i32>], batch: usize, seq_len: usize,
                vocab: usize, opts: &GenerateOpts) -> Result<GenerateResult> {
    assert!(prompts.len() <= batch, "at most {batch} lanes");
    let prog = engine.program(program)?;
    let mut rng = Rng::new(opts.seed);
    let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
    let active = seqs.len();
    let t0 = std::time::Instant::now();

    for _ in 0..opts.max_new {
        let mut flat = vec![0i32; batch * seq_len];
        let mut lens = vec![1i32; batch];
        for (i, s) in seqs.iter().enumerate() {
            let window = if s.len() > seq_len {
                &s[s.len() - seq_len..]
            } else {
                &s[..]
            };
            flat[i * seq_len..i * seq_len + window.len()]
                .copy_from_slice(window);
            lens[i] = window.len() as i32;
        }
        let logits = prog.run_f32(
            &[ParamValue::I32 { shape: vec![batch, seq_len], data: flat },
              ParamValue::I32 { shape: vec![batch], data: lens }],
            weights)?;
        assert_eq!(logits.len(), batch * vocab, "logits shape");
        for (i, s) in seqs.iter_mut().enumerate() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let next = if opts.temperature <= 0.0 {
                argmax(row)
            } else {
                sample(row, opts.temperature, &mut rng)
            };
            s.push(next as i32);
        }
    }
    let seconds = t0.elapsed().as_secs_f64();
    let tokens_generated = active * opts.max_new;
    Ok(GenerateResult {
        sequences: seqs,
        tokens_generated,
        seconds,
        tokens_per_sec: tokens_generated as f64 / seconds.max(1e-9),
    })
}

fn argmax(row: &[f32]) -> usize {
    row.iter().enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i).unwrap_or(0)
}

fn sample(row: &[f32], temp: f64, rng: &mut Rng) -> usize {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let weights: Vec<f64> = row.iter()
        .map(|&l| ((l as f64 - max) / temp).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sample_bounds() {
        let row = vec![0.1f32, 3.0, -2.0, 1.5];
        assert_eq!(argmax(&row), 1);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample(&row, 1.0, &mut rng)] += 1;
        }
        // the max-logit token dominates; impossible tokens stay rare
        assert!(counts[1] > counts[2]);
        assert!(counts[1] > 1000);
        // greedy == temperature → 0 limit
        for _ in 0..50 {
            assert_eq!(sample(&row, 1e-6, &mut rng), 1);
        }
    }
}
