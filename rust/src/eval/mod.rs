//! Evaluators: perplexity over token corpora (Table 2 / Figs 4–5) and
//! multimodal accuracy with the paper's category breakdown (Table 4 /
//! Fig 6). Both drive the scoring programs through the [`crate::runtime`]
//! engine (reference interpreter by default, PJRT behind `pjrt`), so *any*
//! weight set — in particular rust-compressed ones — is evaluated through
//! the exact same program semantics.

pub mod accuracy;
pub mod generate;
pub mod perplexity;

pub use accuracy::{evaluate_mm, MmBreakdown};
pub use generate::{generate, GenerateOpts};
pub use perplexity::{perplexity, PplResult};
