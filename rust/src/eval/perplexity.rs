//! Perplexity evaluation: exp(mean per-sequence NLL) over sequential
//! non-overlapping windows — the protocol the python evaluator uses, so
//! python and rust numbers are directly comparable (goldens.json).

use anyhow::Result;

use crate::data::Corpus;
use crate::model::Weights;
use crate::runtime::{Engine, ParamValue};

#[derive(Clone, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub mean_nll: f64,
    pub n_sequences: usize,
}

/// Evaluate perplexity of `weights` on `corpus` through the scoring
/// program `score_<model>` (or a latent program name passed explicitly).
pub fn perplexity(engine: &Engine, program: &str, weights: &Weights,
                  corpus: &Corpus, batch: usize, seq_len: usize,
                  max_batches: usize) -> Result<PplResult> {
    let prog = engine.program(program)?;
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (i, flat) in corpus.batches(batch, seq_len).into_iter().enumerate() {
        if i >= max_batches {
            break;
        }
        let tokens = ParamValue::I32 { shape: vec![batch, seq_len],
                                       data: flat };
        let nll = prog.run_f32(&[tokens], weights)?;
        total += nll.iter().map(|&v| v as f64).sum::<f64>();
        n += nll.len();
    }
    let mean = total / n.max(1) as f64;
    Ok(PplResult { ppl: mean.exp(), mean_nll: mean, n_sequences: n })
}

/// Perplexity via explicit token batches (used by the serving bench and
/// tests that bypass Corpus).
pub fn perplexity_batches(engine: &Engine, program: &str, weights: &Weights,
                          batches: &[Vec<i32>], batch: usize,
                          seq_len: usize) -> Result<PplResult> {
    let prog = engine.program(program)?;
    let mut total = 0.0f64;
    let mut n = 0usize;
    for flat in batches {
        assert_eq!(flat.len(), batch * seq_len);
        let tokens = ParamValue::I32 { shape: vec![batch, seq_len],
                                       data: flat.clone() };
        let nll = prog.run_f32(&[tokens], weights)?;
        total += nll.iter().map(|&v| v as f64).sum::<f64>();
        n += nll.len();
    }
    let mean = total / n.max(1) as f64;
    Ok(PplResult { ppl: mean.exp(), mean_nll: mean, n_sequences: n })
}
