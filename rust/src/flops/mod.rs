//! Analytic FLOPs / MACs / parameter calculator (Table 3 — calflops
//! equivalent). For OPT-6.7B at token length 128 this reproduces the
//! paper's numbers exactly: 1.70T FLOPs, 851G MACs, 6.66B params at 0%,
//! falling linearly to 171G / 85.2G / 880M at 90%.

use crate::model::config::RealConfig;

#[derive(Clone, Copy, Debug)]
pub struct Complexity {
    pub flops: f64,
    pub macs: f64,
    pub params: f64,
}

/// calflops convention: linear layers dominate; FLOPs = 2 × MACs;
/// per-token MACs of a linear ≈ its parameter count.
pub fn complexity(cfg: &RealConfig, seq_len: usize, ratio: f64,
                  include_attention_quadratic: bool) -> Complexity {
    let keep = 1.0 - ratio;
    let linear = cfg.linear_params() as f64;
    let total = cfg.n_params() as f64;
    // Table 3's parameter accounting (verified against every row of the
    // paper): params(ρ>0) = keep·P_total + P_embeddings — i.e. the whole
    // non-embedding model scales with the compression factor.
    let emb = (cfg.vocab * cfg.d
        + if cfg.learned_pos { (cfg.max_pos + 2) * cfg.d } else { 0 })
        as f64;
    let params = if ratio == 0.0 { total } else { keep * total + emb };

    // per-token MACs: the paper's Table 3 scales the whole forward compute
    // linearly with the compression factor (851G × keep at T=128 exactly),
    // i.e. the LM head is counted in the compressible pool for FLOPs;
    // parameters keep the embedding tables (880M at 90% requires it).
    let head_macs = (cfg.vocab * cfg.d) as f64;
    let mut macs_per_tok = keep * (linear + head_macs);
    if include_attention_quadratic {
        // scores + weighting: 2 · T · d per token per layer
        macs_per_tok +=
            (2 * seq_len * cfg.d_h * cfg.n_heads * cfg.n_layers) as f64;
    }
    let macs = macs_per_tok * seq_len as f64;
    Complexity { flops: 2.0 * macs, macs, params }
}

/// MLA KV-cache bytes per token per layer: dense 2d vs latent r_k + r_v
/// (paper benefit (ii); the coordinator's cache accounting).
pub fn kv_cache_per_token(d: usize, rk: Option<usize>, rv: Option<usize>,
                          bytes_per_el: usize) -> usize {
    match (rk, rv) {
        (Some(rk), Some(rv)) => (rk + rv) * bytes_per_el,
        _ => 2 * d * bytes_per_el,
    }
}

pub fn human(v: f64) -> String {
    if v >= 1e12 {
        format!("{:.2}T", v / 1e12)
    } else if v >= 1e9 {
        format!("{:.2}B", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

/// As human() but with G for giga (the paper prints FLOPs/MACs with G/T).
pub fn human_g(v: f64) -> String {
    if v >= 1e12 {
        format!("{:.2}T", v / 1e12)
    } else if v >= 1e9 {
        format!("{:.0}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else {
        format!("{v:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::opt_by_name;

    /// Table 3 anchors (OPT-6.7B, 128 tokens).
    #[test]
    fn table3_anchor_rows() {
        let cfg = opt_by_name("OPT-6.7B").unwrap();
        let c0 = complexity(cfg, 128, 0.0, false);
        assert!((c0.flops / 1e12 - 1.70).abs() < 0.03, "flops {}", c0.flops);
        assert!((c0.macs / 1e9 - 851.0).abs() < 15.0, "macs {}", c0.macs);
        assert!((c0.params / 1e9 - 6.66).abs() < 0.03);
        let c50 = complexity(cfg, 128, 0.5, false);
        assert!((c50.macs / 1e9 - 425.0).abs() < 10.0, "macs {}", c50.macs);
        assert!((c50.params / 1e9 - 3.54).abs() < 0.1);
        let c90 = complexity(cfg, 128, 0.9, false);
        assert!((c90.params / 1e9 - 0.88).abs() < 0.05, "p {}", c90.params);
        assert!((c90.macs / 1e9 - 85.2).abs() < 6.0, "macs {}", c90.macs);
    }

    #[test]
    fn linear_in_ratio() {
        let cfg = opt_by_name("OPT-1.3B").unwrap();
        let a = complexity(cfg, 128, 0.2, false);
        let b = complexity(cfg, 128, 0.4, false);
        let c = complexity(cfg, 128, 0.6, false);
        let d1 = a.macs - b.macs;
        let d2 = b.macs - c.macs;
        assert!((d1 - d2).abs() < 1e-3 * a.macs);
    }

    #[test]
    fn kv_cache_latent_saves() {
        let dense = kv_cache_per_token(4096, None, None, 2);
        let latent = kv_cache_per_token(4096, Some(512), Some(512), 2);
        assert_eq!(dense, 16384);
        assert_eq!(latent, 2048);
    }

    #[test]
    fn humanize() {
        assert_eq!(human(6.66e9), "6.66B");
        assert_eq!(human_g(851e9), "851G");
        assert_eq!(human_g(1.70e12), "1.70T");
    }
}
