//! LatentLLM — attention-aware joint tensor compression (MERL 2025),
//! reproduced as a three-layer rust + JAX/Pallas stack.
//!
//! This crate is layer 3: the production coordinator. It re-implements the
//! paper's full compression suite over its own dense linear-algebra
//! substrate ([`tensor`]), loads AOT-compiled HLO programs through PJRT
//! ([`runtime`]), evaluates perplexity / multimodal accuracy ([`eval`]),
//! serves batched requests with an MLA-aware KV-cache accounting
//! ([`coordinator`]), and regenerates every table and figure of the paper
//! ([`reports`]). Python/JAX runs only at `make artifacts` time.

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod flops;
pub mod model;
pub mod reports;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::Matrix;
