//! LatentLLM — attention-aware joint tensor compression (MERL 2025),
//! reproduced as a three-layer rust + JAX/Pallas stack.
//!
//! This crate is layer 3: the production coordinator. It re-implements the
//! paper's full compression suite over its own dense linear-algebra
//! substrate ([`tensor`]), executes the artifact programs through a
//! pluggable backend ([`runtime`]) — a pure-rust reference interpreter by
//! default, PJRT/HLO behind `--features pjrt` — evaluates perplexity /
//! multimodal accuracy ([`eval`]), serves batched requests through a
//! continuous-batching scheduler over a paged, MLA-aware KV cache
//! ([`coordinator`]), and regenerates every table and figure of the
//! paper ([`reports`]). Python/JAX runs only at `make artifacts` time.
//!
//! Execution backends (`runtime::backend::Backend`):
//!
//! * `runtime::RefBackend` — interprets score / step / latent / multimodal
//!   programs directly on [`tensor`]; default, fully offline;
//! * `runtime::pjrt::PjrtBackend` — compiles the AOT HLO text through the
//!   `xla` crate (gated behind `feature = "pjrt"`; select at runtime with
//!   `LATENTLLM_BACKEND=pjrt`).

// Numeric-kernel idioms used pervasively by the hand-rolled substrate:
// index-heavy loops over `Matrix`, in-place pivot swaps, and solver entry
// points whose arity mirrors the paper's equations.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_swap)]
#![allow(clippy::too_many_arguments)]

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod flops;
pub mod model;
pub mod reports;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::{Layout, Matrix, PackedMat};
