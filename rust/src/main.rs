//! `latentllm` — CLI launcher for the LatentLLM coordinator.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!   compress  — compress a model with a method/ratio, report ppl
//!   eval      — evaluate perplexity of a (compressed) model
//!   serve     — start the serving demo (dense + latent variants)
//!   report    — regenerate paper tables/figures (all|table2|table3|...)
//!   info      — print configs, artifact manifest summary

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::exit;

use anyhow::{bail, Context, Result};

use latentllm::compress::pipeline::{self, Method};
use latentllm::compress::plan::{self, CompressionPlan, ProgressObserver,
                                Registry};
use latentllm::coordinator::{
    http::{HttpConfig, HttpServer},
    kvcache::CacheKind, kvcache::KvCacheManager,
    router::{ModelVariant, Policy, Router},
    server::{Drain, GenerateParams, ScoreParams, Server, ServerConfig},
};
use latentllm::data::{CalibSet, Corpus};
use latentllm::model::config::{mini_by_name, MINI_FAMILY, OPT_FAMILY};
use latentllm::model::Weights;
use latentllm::reports::{figs, tables};
use latentllm::runtime::Engine;
use latentllm::{eval, flops, Layout};

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }
    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

const USAGE: &str = "\
latentllm — attention-aware joint tensor compression (paper reproduction)

USAGE:
  latentllm info      [--artifacts DIR]
  latentllm compress  --model opt-mini-m --method latentllm --ratio 0.3
                      [--plan FILE.toml] [--dry-run]
                      [--layout f64|f32|int8] [--chunk N]
                      [--artifacts DIR] [--out FILE.ltw]
  latentllm eval      --model opt-mini-m [--weights FILE.ltw]
                      [--corpus synthwiki] [--artifacts DIR]
  latentllm serve     [--requests N] [--generate N] [--http ADDR]
                      [--policy cache_aware|prefer_latent|rr]
                      [--workers N] [--kv-mb N] [--no-sched]
                      [--sched-live N] [--sched-block T] [--sched-chunk T]
                      [--no-prefix-cache] [--gen-shared-prefix T]
                      [--no-fused-step] [--dense-only]
                      [--no-trace] [--profile-layers]
                      [--config FILE.toml] [--artifacts DIR]
  latentllm generate  --model opt-mini-m [--prompts 8] [--new 32]
                      [--temperature 0.8] [--latent] [--no-cache]
                      [--weights FILE.ltw] [--artifacts DIR]
  latentllm synth-artifacts [--out DIR] [--model opt-mini-s] [--seed N]
  latentllm report    all|table2|table3|table4|fig4|fig5|fig7..fig16|ablations
                      [--artifacts DIR] [--out DIR] [--max-batches N]

Decoding: generate runs incremental KV-cached decode sessions (O(d·T)
       per token) by default; --no-cache keeps the full-window recompute
       reference. synth-artifacts writes a complete offline artifacts
       dir (manifest + random dense/latent weights + corpora + calib) so
       generate/eval/serve run without the python pipeline.
Serving: generate traffic runs under a continuous-batching scheduler
       with a paged KV-cache allocator — --sched-live bounds live
       sessions per worker, --sched-block sizes the KV pages in tokens,
       --sched-chunk bounds prefill tokens per iteration, --kv-mb sets
       each variant's page-pool budget, and --no-sched falls back to
       sequential one-session-per-worker decode. Full prompt KV blocks
       are content-addressed and shared copy-on-write across sessions
       (--no-prefix-cache disables sharing); --gen-shared-prefix T
       prepends T identical tokens to every generate prompt so the
       reuse path is easy to exercise. Decode step batches whose live
       sequences share one model are fused into a single shared-weight
       forward per iteration; --no-fused-step keeps the per-session
       loop (token streams are bit-identical, the GEMMs just run N
       times). --dense-only serves just the
       dense variant — with one set of weights the emitted token
       streams are reproducible run to run (routing noise gone), which
       is what the CI digest checks rely on. Request tracing is on by
       default: every request carries a span trace (queued, admitted,
       prefill chunks, steps, preemptions, prefix adoption, retire) and
       replies include a timings object; completed traces land in a
       bounded ring served at GET /debug/requests?n=K. --no-trace turns
       it off (token streams are bit-identical either way).
       --profile-layers additionally feeds per-layer phase timings
       (attn_weight / attn_cache / finish, labeled by layer kind and
       weight layout) into /metrics histograms.
HTTP:  serve --http ADDR (or [http] addr in the config) opens the
       HTTP/1.1 front door: POST /v1/completions (\"stream\": true emits
       tokens over chunked transfer as decode steps retire), POST
       /v1/score, GET /healthz, GET /metrics (Prometheus text). serve
       then blocks until POST /admin/shutdown, drains in-flight
       requests, and exits; self-traffic defaults drop to
       --requests 0 --generate 0.

Methods (presets): plain asvd_hessian asvd_l1 asvd_l2 asvd_cov asvd_rootcov
                   latentllm latentllm_jointvo
Plans: --plan FILE.toml loads a [plan] compression plan (stages, per-layer
       ratios, rank overrides, sparse/quant post-stages; see README
       §Compression plans + examples/plan_latentllm.toml). --dry-run
       validates the plan and prints the resolved rank schedule without
       artifacts. --ratio/--qk-iters/--ud-iters override the plan's values
       (--ratio re-targets uniformly, replacing any per-layer schedule).
Layouts: compress --layout picks the execution layout persisted in the
       artifact (f64 = today's dense reference, bit-identical; f32 =
       cache-blocked panel kernels; int8 = per-chunk affine quantized
       weights with fused-dequant kernels, --chunk sets the chunk width).
       generate/serve/eval auto-pick the stored layout; ppl drift vs the
       f64 reference is printed whenever a non-default layout is chosen.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        exit(2);
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.flag("artifacts", "artifacts"));
    match cmd {
        "info" => info(&artifacts),
        "compress" => compress_cmd(args, &artifacts),
        "eval" => eval_cmd(args, &artifacts),
        "serve" => serve_cmd(args, &artifacts),
        "generate" => generate_cmd(args, &artifacts),
        "synth-artifacts" => synth_cmd(args),
        "report" => report_cmd(args, &artifacts),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn info(artifacts: &Path) -> Result<()> {
    println!("mini family:");
    for c in MINI_FAMILY {
        println!("  {:<12} d={} L={} h={} d_i={} linear={}",
                 c.name, c.d, c.n_layers, c.n_heads, c.d_i,
                 flops::human(c.linear_params() as f64));
    }
    println!("real OPT family (analytic, Table 5):");
    for c in &OPT_FAMILY {
        println!("  {:<10} d={} L={} params={}", c.name, c.d, c.n_layers,
                 flops::human(c.n_params() as f64));
    }
    if artifacts.join("manifest.json").exists() {
        let engine = Engine::new(artifacts)?;
        let man = engine.manifest();
        println!("artifacts at {}:", artifacts.display());
        if let Some(models) = man.get("models").and_then(|m| m.as_obj()) {
            for (name, info) in models {
                let ppl = info.path(&["base_ppl", "synthwiki"])
                    .and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                println!("  {name}: base ppl(synthwiki) = {ppl:.2}");
            }
        }
    } else {
        println!("(no artifacts at {} — run `make artifacts`)",
                 artifacts.display());
    }
    Ok(())
}

fn load_model(artifacts: &Path, model: &str)
              -> Result<(&'static latentllm::model::MiniConfig, Weights,
                         CalibSet)> {
    let cfg = mini_by_name(model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let w = Weights::load(artifacts.join(format!("model_{model}.ltw")))?;
    let cal = CalibSet::load(artifacts.join(format!("calib_{model}.ltw")),
                             cfg.n_layers)?;
    Ok((cfg, w, cal))
}

/// Layer-completion reporter for the CLI: the layer-parallel pool calls
/// it from worker threads as layers finish.
struct StderrProgress;

impl ProgressObserver for StderrProgress {
    fn layer_done(&self, layer: usize, n_layers: usize,
                  rep: &latentllm::compress::plan::LayerReport) {
        eprintln!("  layer {}/{} done ({} params)", layer + 1, n_layers,
                  rep.params);
    }
}

/// Resolve the plan from `--plan FILE.toml` or the `--method` preset,
/// with explicit `--ratio`/`--qk-iters`/`--ud-iters` flags overriding.
fn plan_from_args(args: &Args) -> Result<CompressionPlan> {
    let mut cplan = match args.flags.get("plan") {
        Some(p) => CompressionPlan::load(p)?,
        None => Method::from_name(&args.flag("method", "latentllm"))
            .context("unknown method")?
            .plan(),
    };
    if let Some(r) = args.flags.get("ratio")
        .and_then(|v| v.parse::<f64>().ok()) {
        // explicit re-target: also clears any per-layer schedule so the
        // flag actually takes effect
        cplan = cplan.with_ratio(r);
    }
    if let Some(n) = args.flags.get("qk-iters")
        .and_then(|v| v.parse::<usize>().ok()) {
        cplan.qk_iters = n;
    }
    if let Some(n) = args.flags.get("ud-iters")
        .and_then(|v| v.parse::<usize>().ok()) {
        cplan.ud_iters = n;
    }
    Ok(cplan)
}

/// `--dry-run`: validate the plan and print the resolved rank schedule —
/// needs only the model config, no artifacts.
fn dry_run(cplan: &CompressionPlan, registry: &Registry,
           cfg: &latentllm::model::MiniConfig) -> Result<()> {
    let layers = cplan.resolve(registry, cfg)?;
    println!("plan {} on {} ({} layers): stages {} + {}{}",
             cplan.display_label(), cfg.name, cfg.n_layers, cplan.attn,
             cplan.mlp,
             if cplan.post.is_empty() { String::new() } else {
                 format!(" + post [{}]",
                         cplan.post.iter().map(|p| p.name())
                             .collect::<Vec<_>>().join(", "))
             });
    let mut table = latentllm::reports::TextTable::new(
        &["layer", "ratio", "module", "rank", "params"]);
    let mut total = 0usize;
    for l in &layers {
        for m in &l.modules {
            table.row(vec![l.layer.to_string(),
                           format!("{:.0}%", l.ratio * 100.0),
                           m.module.clone(), m.rank.to_string(),
                           flops::human(m.params as f64)]);
        }
        total += l.params();
    }
    println!("{}", table.render());
    let orig = cfg.linear_params();
    println!("resolved linear params {} -> {} (target ratio {:.3}; \
              low-rank estimate, post-stages excluded)",
             flops::human(orig as f64), flops::human(total as f64),
             1.0 - total as f64 / orig.max(1) as f64);
    Ok(())
}

fn compress_cmd(args: &Args, artifacts: &Path) -> Result<()> {
    let model = args.flag("model", "opt-mini-m");
    let cfg = latentllm::model::config::mini_by_name(&model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let registry = Registry::builtin();
    let cplan = plan_from_args(args)?;
    if args.flags.contains_key("dry-run") {
        return dry_run(&cplan, &registry, cfg);
    }
    let layout = Layout::parse(&args.flag("layout", "f64"))?;
    let (_, w, cal) = load_model(artifacts, &model)?;
    let t0 = std::time::Instant::now();
    let (nw, rep) = plan::compress_plan_on(
        &latentllm::util::pool::Pool::global(), &registry, cfg, &w, &cal,
        &cplan, Some(&StderrProgress))?;
    println!("compressed {model} with {} @ {:.0}% in {:.2}s",
             cplan.display_label(), cplan.ratio * 100.0,
             t0.elapsed().as_secs_f64());
    println!("  linear params {} -> {} (achieved ratio {:.3})",
             flops::human(rep.orig_linear_params as f64),
             flops::human(rep.new_linear_params as f64),
             rep.achieved_ratio());
    // convert to the requested execution layout (quantizes matmul
    // weights for int8; f32 just re-tags — packing happens at load)
    let out_w = if layout == Layout::DenseF64 {
        nw.clone()
    } else {
        let q = nw.repack(layout, args.usize_flag("chunk", 64))?;
        println!("  repacked to {} execution layout", layout.name());
        q
    };
    if let Some(out) = args.flags.get("out") {
        out_w.save(out)?;
        println!("  wrote {out} ({} layout)", out_w.layout().name());
    }
    // quick ppl check through the scoring program
    let engine = Engine::new(artifacts)?;
    let corpus = Corpus::load(artifacts.join("corpora.ltw"), "synthwiki",
                              "test")?;
    let r = eval::perplexity(&engine, &format!("score_{model}"), &out_w,
                             &corpus, 8, 128, 12)?;
    println!("  ppl(synthwiki) = {:.2}", r.ppl);
    if layout != Layout::DenseF64 {
        // drift of the typed execution layout vs the f64 reference the
        // plan produced — the accuracy side of the layout tradeoff
        let rf = eval::perplexity(&engine, &format!("score_{model}"), &nw,
                                  &corpus, 8, 128, 12)?;
        println!("  ppl drift vs f64 reference: {:+.4} ({:.2} -> {:.2})",
                 r.ppl - rf.ppl, rf.ppl, r.ppl);
    }
    Ok(())
}

fn eval_cmd(args: &Args, artifacts: &Path) -> Result<()> {
    let model = args.flag("model", "opt-mini-m");
    let corpus_name = args.flag("corpus", "synthwiki");
    let (_, base_w, _) = load_model(artifacts, &model)?;
    let w = match args.flags.get("weights") {
        Some(p) => Weights::load(p)?,
        None => base_w,
    };
    let engine = Engine::new(artifacts)?;
    let corpus = Corpus::load(artifacts.join("corpora.ltw"), &corpus_name,
                              "test")?;
    let r = eval::perplexity(&engine, &format!("score_{model}"), &w,
                             &corpus, 8, 128,
                             args.usize_flag("max-batches", 24))?;
    println!("ppl({corpus_name}) = {:.3}  (mean NLL {:.4}, {} sequences)",
             r.ppl, r.mean_nll, r.n_sequences);
    Ok(())
}

fn generate_cmd(args: &Args, artifacts: &Path) -> Result<()> {
    use latentllm::eval::generate::{generate, GenerateOpts};
    let model = args.flag("model", "opt-mini-m");
    let engine = Engine::new(artifacts)?;
    let vocab = engine.manifest().get("vocab")
        .and_then(|v| v.as_usize()).unwrap_or(512);
    let seq_len = engine.manifest().get("seq_len")
        .and_then(|v| v.as_usize()).unwrap_or(128);
    let batch = engine.manifest().get("score_batch")
        .and_then(|v| v.as_usize()).unwrap_or(8);
    let n_prompts = args.usize_flag("prompts", batch.min(8)).min(batch);
    let corpus = Corpus::load(artifacts.join("corpora.ltw"), "synthwiki",
                              "test")?;
    let prompts: Vec<Vec<i32>> = corpus.calibration(n_prompts, 16, 7);
    let opts = GenerateOpts {
        max_new: args.usize_flag("new", 32),
        temperature: args.f64_flag("temperature", 0.0),
        seed: 11,
        use_cache: !args.flags.contains_key("no-cache"),
    };
    // --weights FILE.ltw swaps in an external weight set (e.g. a
    // `compress --out` artifact); the stored layout tag travels with the
    // file, so int8/f32 artifacts automatically decode on their packed
    // kernels
    let (program, weights) = if args.flags.contains_key("latent") {
        let tag = engine.manifest().path(&["latent_demo", "tag"])
            .and_then(|v| v.as_str()).context("no latent demo artifact")?;
        let w = match args.flags.get("weights") {
            Some(p) => Weights::load(p)?,
            None => Weights::load(
                artifacts.join(format!("latent_model_{tag}.ltw")))?,
        };
        (format!("latent_step_{tag}"), w)
    } else {
        let w = match args.flags.get("weights") {
            Some(p) => Weights::load(p)?,
            None => Weights::load(
                artifacts.join(format!("model_{model}.ltw")))?,
        };
        (format!("step_{model}"), w)
    };
    if weights.layout() != Layout::DenseF64 {
        println!("weights execute in the {} layout",
                 weights.layout().name());
    }
    let res = generate(&engine, &program, &weights, &prompts, batch,
                       seq_len, vocab, &opts)?;
    for (i, s) in res.sequences.iter().enumerate() {
        let tail: Vec<i32> = s[s.len().saturating_sub(opts.max_new)..]
            .to_vec();
        println!("seq {i}: ...{tail:?}");
    }
    let mode = if opts.use_cache { "incremental KV-cached" }
               else { "full-window recompute" };
    println!("generated {} tokens in {:.2}s — {:.1} tok/s \
              (program {program}, {mode})",
             res.tokens_generated, res.seconds, res.tokens_per_sec);
    if opts.use_cache {
        println!("  peak cache: {} floats across {} lane(s)",
                 res.peak_cache_elements, res.sequences.len());
    }
    Ok(())
}

/// Write a complete synthetic artifacts directory (manifest + random
/// dense/latent weights + corpora + calibration) — the offline stand-in
/// for `make artifacts`, used by CI smoke runs and quick local demos.
fn synth_cmd(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.flag("out", "artifacts-synth"));
    let model = args.flag("model", "opt-mini-s");
    let cfg = mini_by_name(&model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let seed = args.usize_flag("seed", 7) as u64;
    let tag = latentllm::data::synth::write_test_artifacts(&out, cfg,
                                                           seed)?;
    println!("wrote synthetic artifacts for {model} (latent tag {tag}) \
              to {}", out.display());
    Ok(())
}

fn serve_cmd(args: &Args, artifacts: &Path) -> Result<()> {
    let file_cfg = match args.flags.get("config") {
        Some(p) => latentllm::config::Config::load(p)?,
        None => latentllm::config::Config::default(),
    };
    let model = args.flag("model", &file_cfg.serve.model);
    // --http ADDR (bare --http picks an ephemeral localhost port) or
    // the config's [http] addr turns the front door on; self-traffic
    // then defaults to zero so the process just serves
    let http_addr = match args.flags.get("http") {
        Some(a) if a == "true" => "127.0.0.1:0".to_string(),
        Some(a) => a.clone(),
        None => file_cfg.http.addr.clone(),
    };
    let http_on = !http_addr.is_empty();
    let n_requests =
        args.usize_flag("requests", if http_on { 0 } else { 64 });
    let policy = match args.flag("policy", "").as_str() {
        "rr" | "round_robin" => Policy::RoundRobin,
        "prefer_latent" => Policy::PreferLatent,
        "cache_aware" => Policy::CacheAware,
        _ => file_cfg.serve.policy,
    };
    let (cfg, weights, cal) = load_model(artifacts, &model)?;
    // latent variant: compress in-process with the [compress] plan. A
    // per-layer schedule in the config wins over serve.latent_ratio
    // (which then only sizes the KV-cache estimate below).
    let ratio = file_cfg.serve.latent_ratio;
    let cplan = if file_cfg.compress.layer_ratios.is_empty() {
        file_cfg.compress.clone().with_ratio(ratio)
    } else {
        file_cfg.compress.clone()
    };
    let (latent_w, rep) = plan::compress_plan(cfg, &weights, &cal, &cplan)?;
    println!("built latent variant with plan {} (achieved ratio {:.3})",
             cplan.display_label(), rep.achieved_ratio());
    // scheduler knobs: CLI over config over defaults; --no-sched falls
    // back to the sequential one-session-per-worker decode path
    let mut sched_cfg = file_cfg.serve.scheduler;
    sched_cfg.max_live =
        args.usize_flag("sched-live", sched_cfg.max_live).max(1);
    sched_cfg.block_tokens =
        args.usize_flag("sched-block", sched_cfg.block_tokens).max(1);
    sched_cfg.prefill_chunk =
        args.usize_flag("sched-chunk", sched_cfg.prefill_chunk).max(1);
    // fused step batch: CLI over config, default on ([serve] fused_step)
    if args.flags.contains_key("no-fused-step") {
        sched_cfg.fused = false;
    } else if args.flags.contains_key("fused-step") {
        sched_cfg.fused = true;
    }
    let use_sched = !args.flags.contains_key("no-sched")
        && file_cfg.serve.sched;
    // prefix cache: CLI over config, default on ([serve] prefix_cache)
    let use_prefix = if args.flags.contains_key("no-prefix-cache") {
        false
    } else if args.flags.contains_key("prefix-cache") {
        true
    } else {
        file_cfg.serve.prefix_cache
    };
    // request tracing: CLI over config, default on ([serve] trace)
    let use_trace = if args.flags.contains_key("no-trace") {
        false
    } else if args.flags.contains_key("trace") {
        true
    } else {
        file_cfg.serve.trace
    };
    // per-layer phase profiling is opt-in: either flag or config
    let profile_layers = args.flags.contains_key("profile-layers")
        || file_cfg.serve.profile_layers;
    let budget = match args.flags.get("kv-mb") {
        Some(v) => {
            let mb = v.parse::<f64>()
                .context("--kv-mb must be a number of MiB")?;
            // a negative/NaN value would cast-saturate to a 0-byte
            // pool and fail every request with a capacity error
            anyhow::ensure!(mb.is_finite() && mb > 0.0,
                            "--kv-mb must be a positive number of MiB \
                             (got {v})");
            (mb * (1 << 20) as f64) as usize
        }
        None => file_cfg.serve.kv_budget_bytes,
    };
    let r_lat = latentllm::compress::rank::local_rank(cfg.d, cfg.d,
                                                      1.0 - ratio, true);
    let bt = sched_cfg.block_tokens;
    let mut variants = vec![
        ModelVariant {
            name: "dense".into(),
            score_program: format!("score_{model}"),
            step_program: format!("step_{model}"),
            weights: std::sync::Arc::new(weights),
            cache: KvCacheManager::with_block_tokens(
                CacheKind::Dense { d: cfg.d }, cfg.n_layers, 2, budget,
                bt),
        },
        ModelVariant {
            name: "latent30".into(),
            score_program: format!("score_{model}"),
            step_program: format!("step_{model}"),
            weights: std::sync::Arc::new(latent_w),
            cache: KvCacheManager::with_block_tokens(
                CacheKind::Latent { rk: r_lat, rv: r_lat },
                cfg.n_layers, 2, budget, bt),
        },
    ];
    // --dense-only: a single-weights deployment — every request decodes
    // through the same model, so token streams depend only on (prompt,
    // seed), not on routing/scheduling order
    if args.flags.contains_key("dense-only") {
        variants.truncate(1);
    }
    if !use_prefix {
        for v in &mut variants {
            v.cache.set_prefix_cache(false);
        }
    }
    // the paged pool in one line: how many live sessions each variant's
    // budget holds (the latent/dense gap IS the paper's benefit (ii))
    for v in &variants {
        println!("  {}: {} blocks of {} B ({} tokens/page nominal)",
                 v.name, v.cache.total_blocks(), v.cache.block_bytes(),
                 bt);
    }
    let router = Router::new(variants, policy);
    let workers = args.usize_flag("workers", file_cfg.serve.workers).max(1);
    let server = Server::start(artifacts.to_path_buf(), router, ServerConfig {
        batcher: file_cfg.serve.batcher,
        policy,
        program_batch: file_cfg.serve.program_batch,
        seq_len: file_cfg.serve.seq_len,
        workers,
        sched: use_sched.then_some(sched_cfg),
        trace: use_trace,
    })?;
    if profile_layers {
        latentllm::runtime::profile::install(server.metrics.clone());
    }
    println!("observability: trace {}, layer profiling {}",
             if use_trace { "on" } else { "off" },
             if profile_layers { "on" } else { "off" });
    println!("serving with {} worker(s), scheduler {}, prefix cache {}",
             server.live_workers(),
             if use_sched {
                 format!("on (live={} block={} chunk={} fused={})",
                         sched_cfg.max_live, sched_cfg.block_tokens,
                         sched_cfg.prefill_chunk,
                         if sched_cfg.fused { "on" } else { "off" })
             } else {
                 "off (sequential sessions)".to_string()
             },
             if use_prefix { "on" } else { "off" });
    let corpus = Corpus::load(artifacts.join("corpora.ltw"), "synthwiki",
                              "test")?;
    let reqs = corpus.calibration(n_requests, file_cfg.serve.seq_len, 99);
    let n_generate =
        args.usize_flag("generate", if http_on { 0 } else { 8 });
    let mut gen_prompts = corpus.calibration(n_generate, 16, 101);
    // --gen-shared-prefix T: every generate prompt starts with the same
    // T deterministic tokens — a stand-in for a shared system prompt
    // that makes the prefix-cache reuse path observable from the CLI
    let shared = args.usize_flag("gen-shared-prefix", 0);
    if shared > 0 {
        let prefix: Vec<i32> =
            (0..shared).map(|j| ((j * 7 + 3) % cfg.vocab) as i32).collect();
        for p in &mut gen_prompts {
            let tail = std::mem::take(p);
            *p = prefix.iter().copied().chain(tail).collect();
        }
    }
    // the HTTP front door shares the coordinator with the in-process
    // self-traffic below (ids are server-minted, so they never collide)
    let server = std::sync::Arc::new(server);
    let http = if http_on {
        let hcfg = HttpConfig { addr: http_addr,
                                ..file_cfg.http.clone() };
        let h = HttpServer::start(server.clone(), hcfg)?;
        println!("http: listening on {}", h.local_addr());
        Some(h)
    } else {
        None
    };
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for tokens in reqs {
        rxs.push(server.submit_score(ScoreParams { tokens })?);
    }
    // decode traffic rides alongside the score batches: each request is
    // a full prefill+step session against the variant's KV budget
    let mut gen_rxs = Vec::with_capacity(n_generate);
    for (i, prompt) in gen_prompts.into_iter().enumerate() {
        gen_rxs.push(server.submit_generate(GenerateParams {
            prompt,
            max_new: args.usize_flag("new", 16),
            temperature: 0.0,
            seed: 13 + i as u64,
        })?);
    }
    let mut ok = 0;
    for rx in rxs {
        match rx.recv() {
            Ok(resp) if resp.result.is_ok() => ok += 1,
            _ => {}
        }
    }
    let mut gen_ok = 0;
    let mut gen_evicted = 0;
    // FNV-1a over every emitted token stream in submission order — the
    // cold-vs-warm equality check CI greps for ("generate digest:")
    let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
    for rx in gen_rxs {
        if let Ok(resp) = rx.recv() {
            match &resp.result {
                Ok(out) => {
                    gen_ok += 1;
                    for t in &out.tokens {
                        for b in t.to_le_bytes() {
                            digest = (digest ^ b as u64)
                                .wrapping_mul(0x100_0000_01b3);
                        }
                    }
                }
                Err(_) if resp.is_evicted() => gen_evicted += 1,
                Err(_) => {}
            }
        }
    }
    let dt = t0.elapsed();
    if let Some(h) = http {
        println!("http: serving until POST /admin/shutdown");
        h.wait();
    }
    let server = std::sync::Arc::try_unwrap(server).ok()
        .context("http workers still hold the server")?;
    let metrics = server.shutdown(Drain::Graceful);
    if http_on {
        println!("http: drained cleanly");
    }
    println!("served {ok}/{n_requests} score requests in {:.2}s \
              ({:.1} req/s, failed={})",
             dt.as_secs_f64(), ok as f64 / dt.as_secs_f64(),
             n_requests - ok);
    if n_generate > 0 {
        let gen_tokens = metrics.counter("gen_tokens");
        // batch occupancy: decode steps actually scheduled over the
        // batch slots the scheduler offered (continuous batching's
        // utilization number); sequential mode has no slots
        let occupancy = metrics.ratio_pct("sched_steps", "sched_slots");
        println!("generate: ok={gen_ok}/{n_generate} \
                  failed={} evicted={gen_evicted} requeued={} — \
                  {gen_tokens} tokens, {:.1} tok/s, occupancy={occupancy}, \
                  live_peak={}, queue_peak={}, peak cache {} bytes",
                 n_generate - gen_ok,
                 metrics.counter("gen_preemptions"),
                 gen_tokens as f64 / dt.as_secs_f64().max(1e-9),
                 metrics.gauge("live_sessions_peak"),
                 metrics.gauge("gen_queue_depth_peak"),
                 metrics.gauge("cache_bytes_peak"));
        println!("prefix: hits={} misses={} saved_tokens={} evictions={}",
                 metrics.counter("prefix_hits"),
                 metrics.counter("prefix_misses"),
                 metrics.counter("prefix_saved_tokens"),
                 metrics.counter("prefix_evictions"));
        // the step-fusion scorecard: how many iteration batches took the
        // shared-weight pass, how many sequence-rows rode along, and the
        // per-iteration step latency it bought
        let step_q = metrics.quantiles("step_us")
            .map(|(p50, p95, _)| format!("{p50:.0}/{p95:.0}us"))
            .unwrap_or_else(|| "n/a".to_string());
        println!("fused: batches={} rows={} step p50/p95={step_q}",
                 metrics.counter("fused_batches"),
                 metrics.counter("fused_step_rows"));
        println!("generate digest: {digest:016x}");
    }
    print!("{}", metrics.summary());
    Ok(())
}

fn report_cmd(args: &Args, artifacts: &Path) -> Result<()> {
    let what = args.positional.first().map(String::as_str).unwrap_or("all");
    let out_dir = PathBuf::from(args.flag("out", "reports"));
    std::fs::create_dir_all(&out_dir)?;
    let save = |name: &str, v: &latentllm::util::json::Value| -> Result<()> {
        let p = out_dir.join(format!("{name}.json"));
        std::fs::write(&p, v.to_string_pretty())?;
        println!("wrote {}", p.display());
        Ok(())
    };

    // artifact-free figures
    let d = args.usize_flag("dim", 48);
    match what {
        "fig7" => {
            let v = figs::fig7(d, 1);
            println!("{}", figs::render(&v));
            return save("fig7", &v);
        }
        "fig8" => {
            let v = figs::fig8(d, 2);
            println!("{}", figs::render(&v));
            return save("fig8", &v);
        }
        "fig9" => {
            let v = figs::fig9(d, 4, 3);
            println!("{}", figs::render(&v));
            return save("fig9", &v);
        }
        "fig10" => {
            let v = figs::fig10(d, 4, 4);
            println!("{}", figs::render(&v));
            return save("fig10", &v);
        }
        "fig11" | "fig16" => {
            let (f11, f16) = figs::fig11_16(d, 5);
            println!("{}", figs::render(&f11));
            println!("{}", figs::render(&f16));
            save("fig11", &f11)?;
            return save("fig16", &f16);
        }
        "fig12" => {
            let v = figs::fig12(args.usize_flag("dim", 96), 8, 6);
            println!("{}", figs::render(&v));
            return save("fig12", &v);
        }
        "fig13" => {
            let v = figs::fig13(d, 7);
            println!("{}", figs::render(&v));
            return save("fig13", &v);
        }
        "fig14" => {
            let v = figs::fig14(d, 8);
            println!("{}", figs::render(&v));
            return save("fig14", &v);
        }
        "fig15" => {
            let v = figs::fig15(d, 9);
            println!("{}", figs::render(&v));
            return save("fig15", &v);
        }
        "table3" => {
            return save("table3", &tables::table3());
        }
        _ => {}
    }

    // artifact-dependent reports
    let engine = Engine::new(artifacts)?;
    let ctx = tables::TableCtx {
        engine: &engine,
        artifacts: artifacts.to_path_buf(),
        max_batches: args.usize_flag("max-batches", 12),
        qk_iters: args.usize_flag("qk-iters", 8),
        ud_iters: args.usize_flag("ud-iters", 4),
    };
    match what {
        "all" => {
            tables::run_all(&ctx, &out_dir)?;
            // plus the artifact-free figure suite
            for (name, v) in [("fig7", figs::fig7(d, 1)),
                              ("fig8", figs::fig8(d, 2)),
                              ("fig9", figs::fig9(d, 4, 3)),
                              ("fig10", figs::fig10(d, 4, 4)),
                              ("fig13", figs::fig13(d, 7)),
                              ("fig14", figs::fig14(d, 8)),
                              ("fig15", figs::fig15(d, 9)),
                              ("fig12", figs::fig12(96, 8, 6))] {
                println!("{}", figs::render(&v));
                save(name, &v)?;
            }
            let (f11, f16) = figs::fig11_16(d, 5);
            println!("{}", figs::render(&f11));
            println!("{}", figs::render(&f16));
            save("fig11", &f11)?;
            save("fig16", &f16)?;
            Ok(())
        }
        "table2" => {
            let v = tables::table2(&ctx,
                                   &["opt-mini-s", "opt-mini-m",
                                     "opt-mini-l"],
                                   &[0.1, 0.2, 0.3, 0.4],
                                   &pipeline::table2_plans())?;
            save("table2", &v)
        }
        "table4" => {
            let ratios: Vec<f64> = args.flag("ratios",
                                             "0.3,0.6,0.8,0.9,0.95")
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            let v = tables::table4(&ctx, &ratios,
                                   &[Method::Plain.plan(),
                                     Method::AsvdRootCov.plan(),
                                     Method::LatentLlm.plan()])?;
            save("table4", &v)
        }
        "fig4" => {
            let v = tables::fig4(&ctx, &["opt-mini-m"],
                                 &[Method::AsvdRootCov.plan(),
                                   Method::LatentLlm.plan()])?;
            save("fig4", &v)
        }
        "fig5" => {
            let v = tables::fig5(&ctx, &["opt-mini-s", "opt-mini-m",
                                         "opt-mini-l"])?;
            save("fig5", &v)
        }
        "ablations" => {
            let v = latentllm::reports::ablations::run(
                &ctx, &args.flag("model", "opt-mini-s"),
                args.f64_flag("ratio", 0.3))?;
            save("ablations", &v)
        }
        other => bail!("unknown report {other:?}"),
    }
}
