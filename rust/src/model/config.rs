//! Model architecture configs: the trained opt-mini family plus the real
//! model rows of the paper's Tables 5–7 (used analytically by [`crate::flops`]
//! to regenerate Table 3 exactly).

/// OPT-style transformer config (pre-LN, ReLU MLP, learned pos-emb, biases).
#[derive(Clone, Debug, PartialEq)]
pub struct MiniConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_i: usize,
    pub max_len: usize,
}

impl MiniConfig {
    pub fn d_h(&self) -> usize {
        self.d / self.n_heads
    }

    /// Deterministic parameter order — must match python configs.param_names().
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string(), "pos_emb".to_string()];
        for i in 0..self.n_layers {
            let p = format!("layers.{i}.");
            for s in ["ln1.g", "ln1.b", "attn.wq", "attn.bq", "attn.wk",
                      "attn.bk", "attn.wv", "attn.bv", "attn.wo", "attn.bo",
                      "ln2.g", "ln2.b", "mlp.wu", "mlp.bu", "mlp.wd",
                      "mlp.bd"] {
                names.push(format!("{p}{s}"));
            }
        }
        names.push("lnf.g".to_string());
        names.push("lnf.b".to_string());
        names
    }

    /// Linear (compressible) parameter count per layer: 4d² + 2·d·d_i.
    pub fn linear_params_per_layer(&self) -> usize {
        4 * self.d * self.d + 2 * self.d * self.d_i
    }

    pub fn linear_params(&self) -> usize {
        self.n_layers * self.linear_params_per_layer()
    }
}

pub const OPT_MINI_S: MiniConfig = MiniConfig {
    name: "opt-mini-s", vocab: 512, d: 96, n_layers: 2, n_heads: 4,
    d_i: 384, max_len: 128,
};
pub const OPT_MINI_M: MiniConfig = MiniConfig {
    name: "opt-mini-m", vocab: 512, d: 128, n_layers: 4, n_heads: 4,
    d_i: 512, max_len: 128,
};
pub const OPT_MINI_L: MiniConfig = MiniConfig {
    name: "opt-mini-l", vocab: 512, d: 192, n_layers: 6, n_heads: 6,
    d_i: 768, max_len: 128,
};

pub const MINI_FAMILY: [&MiniConfig; 3] =
    [&OPT_MINI_S, &OPT_MINI_M, &OPT_MINI_L];

pub fn mini_by_name(name: &str) -> Option<&'static MiniConfig> {
    MINI_FAMILY.iter().find(|c| c.name == name).copied()
}

/// Real published-model config (paper Tables 5–7) for analytic accounting.
#[derive(Clone, Debug)]
pub struct RealConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_h: usize,
    pub d_i: usize,
    pub max_pos: usize,
    /// separate (untied) LM head
    pub untied_head: bool,
    /// learned positional embeddings contribute params (OPT: yes)
    pub learned_pos: bool,
}

/// OPT family (paper Table 5). vocab 50272, learned pos-emb (max 2048).
pub const OPT_FAMILY: [RealConfig; 9] = [
    RealConfig { name: "OPT-125M", vocab: 50272, d: 768, n_layers: 12,
        n_heads: 12, n_kv_heads: 12, d_h: 64, d_i: 3072, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-350M", vocab: 50272, d: 1024, n_layers: 24,
        n_heads: 16, n_kv_heads: 16, d_h: 64, d_i: 4096, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-1.3B", vocab: 50272, d: 2048, n_layers: 24,
        n_heads: 32, n_kv_heads: 32, d_h: 64, d_i: 8192, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-2.7B", vocab: 50272, d: 2560, n_layers: 32,
        n_heads: 32, n_kv_heads: 32, d_h: 80, d_i: 10240, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-6.7B", vocab: 50272, d: 4096, n_layers: 32,
        n_heads: 32, n_kv_heads: 32, d_h: 128, d_i: 16384, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-13B", vocab: 50272, d: 5120, n_layers: 40,
        n_heads: 40, n_kv_heads: 40, d_h: 128, d_i: 20480, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-30B", vocab: 50272, d: 7168, n_layers: 48,
        n_heads: 56, n_kv_heads: 56, d_h: 128, d_i: 28672, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-66B", vocab: 50272, d: 9216, n_layers: 64,
        n_heads: 72, n_kv_heads: 72, d_h: 128, d_i: 36864, max_pos: 2048,
        untied_head: false, learned_pos: true },
    RealConfig { name: "OPT-175B", vocab: 50272, d: 12288, n_layers: 96,
        n_heads: 96, n_kv_heads: 96, d_h: 128, d_i: 49152, max_pos: 2048,
        untied_head: false, learned_pos: true },
];

pub fn opt_by_name(name: &str) -> Option<&'static RealConfig> {
    OPT_FAMILY.iter().find(|c| c.name == name)
}

impl RealConfig {
    /// Total parameters (embeddings + linears + LN/bias terms).
    pub fn n_params(&self) -> usize {
        let d = self.d;
        let attn = d * self.d_h * self.n_heads * 2           // q, o
            + d * self.d_h * self.n_kv_heads * 2             // k, v
            + 4 * d;                                         // qkvo biases
        let mlp = 2 * d * self.d_i + self.d_i + d;
        let ln = 2 * (2 * d);
        let per_layer = attn + mlp + ln;
        let emb = self.vocab * d
            + if self.learned_pos { (self.max_pos + 2) * d } else { 0 }
            + if self.untied_head { self.vocab * d } else { 0 };
        emb + self.n_layers * per_layer + 2 * d
    }

    /// Compressible linear weights only.
    pub fn linear_params(&self) -> usize {
        let d = self.d;
        self.n_layers
            * (d * self.d_h * (2 * self.n_heads + 2 * self.n_kv_heads)
                + 2 * d * self.d_i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_names_match_python_convention() {
        let names = OPT_MINI_S.param_names();
        assert_eq!(names[0], "tok_emb");
        assert_eq!(names[2], "layers.0.ln1.g");
        assert_eq!(names.last().unwrap(), "lnf.b");
        assert_eq!(names.len(), 2 + 2 * 16 + 2);
    }

    /// Paper Table 3 anchor: OPT-6.7B has 6.66B params.
    #[test]
    fn opt_6_7b_param_count() {
        let c = opt_by_name("OPT-6.7B").unwrap();
        let n = c.n_params() as f64 / 1e9;
        assert!((n - 6.66).abs() < 0.03, "got {n}B");
    }

    #[test]
    fn opt_125m_param_count() {
        let c = opt_by_name("OPT-125M").unwrap();
        let n = c.n_params() as f64 / 1e6;
        assert!((n - 125.0).abs() < 2.0, "got {n}M");
    }

    #[test]
    fn linear_fraction_dominates() {
        for c in OPT_FAMILY.iter().skip(2) {
            let frac = c.linear_params() as f64 / c.n_params() as f64;
            assert!(frac > 0.85, "{}: {frac}", c.name);
        }
    }
}
