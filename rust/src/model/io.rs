//! LTW interchange reader/writer (DESIGN.md §5; python side:
//! python/compile/ltw.py). Little-endian. Two container versions:
//!
//! * LTW1 — magic "LTW1", u32 count, then per tensor: u16 name-len, name,
//!   u8 dtype (0=f32, 1=i32), u8 ndim, u32 dims…, raw data. What python
//!   emits and every pre-layout artifact holds.
//! * LTW2 — magic "LTW2", u8 execution-layout code ([`Layout::code`]),
//!   then the same count + entries with one more dtype: 2 = chunk-affine
//!   int8 (u32 chunk, u32 n_chunks, f32 scales, f32 zero-points, i8
//!   codes). Written only when needed (non-default layout or quantized
//!   tensors), so plain f64 maps keep byte-identical LTW1 files.
//!
//! Readers accept both — loading an old artifact transparently upgrades
//! it to `Layout::DenseF64`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Layout, PackedMat};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    /// Chunk-affine int8 in the [`PackedMat::QuantI8`] convention: flat
    /// chunks, `ŵ = q·scale + zero_point`.
    QuantI8 {
        shape: Vec<usize>,
        data: Vec<i8>,
        scales: Vec<f32>,
        zero_points: Vec<f32>,
        chunk: usize,
    },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I32 { shape, .. }
            | Tensor::QuantI8 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// 2-D tensor → f64 Matrix (quantized tensors dequantize — the dense
    /// view `compress/`, `eval/` and reports keep working against).
    pub fn to_matrix(&self) -> Result<crate::Matrix> {
        if let Tensor::QuantI8 { .. } = self {
            return Ok(self.to_packed(Layout::DenseF64)?.to_matrix());
        }
        let shape = self.shape();
        let data = self.as_f32()?;
        match shape.len() {
            2 => Ok(crate::Matrix::from_f32(shape[0], shape[1], data)),
            1 => Ok(crate::Matrix::from_f32(1, shape[0], data)),
            _ => bail!("to_matrix needs 1-D/2-D, got {shape:?}"),
        }
    }

    /// The tensor in its execution form. A stored `QuantI8` tensor is
    /// already an execution layout and wins over `layout`; an f32 tensor
    /// packs per the weight set's layout tag.
    pub fn to_packed(&self, layout: Layout) -> Result<PackedMat> {
        match self {
            Tensor::QuantI8 { shape, data, scales, zero_points, chunk } => {
                if shape.len() != 2 {
                    bail!("to_packed needs a 2-D quant tensor, got {shape:?}");
                }
                Ok(PackedMat::QuantI8 {
                    rows: shape[0],
                    cols: shape[1],
                    data: data.clone(),
                    scales: scales.clone(),
                    zero_points: zero_points.clone(),
                    chunk: *chunk,
                })
            }
            _ => {
                let m = self.to_matrix()?;
                Ok(match layout {
                    Layout::PackedF32 => PackedMat::pack_f32(&m),
                    _ => PackedMat::DenseF64(m),
                })
            }
        }
    }

    /// Storage form of a [`PackedMat`]. `PackedF32` persists as plain f32
    /// (the panel pack is a load-time memory layout, not a storage one) —
    /// its layout travels in the LTW2 container tag instead.
    pub fn from_packed(p: &PackedMat) -> Tensor {
        match p {
            PackedMat::QuantI8 { rows, cols, data, scales, zero_points,
                                 chunk } => Tensor::QuantI8 {
                shape: vec![*rows, *cols],
                data: data.clone(),
                scales: scales.clone(),
                zero_points: zero_points.clone(),
                chunk: *chunk,
            },
            _ => {
                let m = p.to_matrix();
                Tensor::F32 {
                    shape: vec![m.rows(), m.cols()],
                    data: m.to_f32(),
                }
            }
        }
    }
}

pub type TensorMap = BTreeMap<String, Tensor>;

const MAGIC: &[u8; 4] = b"LTW1";
const MAGIC2: &[u8; 4] = b"LTW2";

/// True when `map` needs the LTW2 container even at the default layout.
fn has_quant(map: &TensorMap) -> bool {
    map.values().any(|t| matches!(t, Tensor::QuantI8 { .. }))
}

pub fn read_ltw(path: impl AsRef<Path>) -> Result<TensorMap> {
    Ok(read_ltw_layout(path)?.0)
}

/// Read either container version; LTW1 files upgrade to
/// `Layout::DenseF64` transparently.
pub fn read_ltw_layout(path: impl AsRef<Path>)
                       -> Result<(TensorMap, Layout)> {
    let path = path.as_ref();
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    parse_ltw_layout(&buf)
        .with_context(|| format!("parse {}", path.display()))
}

pub fn parse_ltw(buf: &[u8]) -> Result<TensorMap> {
    Ok(parse_ltw_layout(buf)?.0)
}

pub fn parse_ltw_layout(buf: &[u8]) -> Result<(TensorMap, Layout)> {
    if buf.len() < 8 {
        bail!("bad LTW magic");
    }
    let (layout, mut off) = match &buf[..4] {
        m if m == MAGIC => (Layout::DenseF64, 4),
        m if m == MAGIC2 => {
            if buf.len() < 9 {
                bail!("truncated LTW2 header");
            }
            (Layout::from_code(buf[4])?, 5)
        }
        _ => bail!("bad LTW1/LTW2 magic"),
    };
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > buf.len() {
            bail!("truncated LTW file");
        }
        let s = &buf[*off..*off + n];
        *off += n;
        Ok(s)
    };
    let n = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
    let mut out = TensorMap::new();
    for _ in 0..n {
        let name_len =
            u16::from_le_bytes(take(&mut off, 2)?.try_into()?) as usize;
        let name = std::str::from_utf8(take(&mut off, name_len)?)?.to_string();
        let dtype = take(&mut off, 1)?[0];
        let ndim = take(&mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into()?)
                as usize);
        }
        let count: usize = shape.iter().product();
        let t = match dtype {
            0 => Tensor::F32 {
                shape,
                data: take(&mut off, count * 4)?
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            1 => Tensor::I32 {
                shape,
                data: take(&mut off, count * 4)?
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            2 => {
                let chunk =
                    u32::from_le_bytes(take(&mut off, 4)?.try_into()?)
                        as usize;
                let n_chunks =
                    u32::from_le_bytes(take(&mut off, 4)?.try_into()?)
                        as usize;
                if chunk == 0 || n_chunks != count.div_ceil(chunk) {
                    bail!("{name}: quant chunk grid {n_chunks}x{chunk} \
                           disagrees with {count} elements");
                }
                let scales: Vec<f32> = take(&mut off, n_chunks * 4)?
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let zero_points: Vec<f32> = take(&mut off, n_chunks * 4)?
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let data = take(&mut off, count)?
                    .iter()
                    .map(|&b| b as i8)
                    .collect();
                Tensor::QuantI8 { shape, data, scales, zero_points, chunk }
            }
            d => bail!("unknown dtype code {d}"),
        };
        out.insert(name, t);
    }
    Ok((out, layout))
}

pub fn write_ltw(path: impl AsRef<Path>, tensors: &TensorMap) -> Result<()> {
    write_ltw_layout(path, tensors, Layout::DenseF64)
}

/// Write the smallest container that can hold the map: LTW1 when the
/// layout is the default and nothing is quantized (bit-compatible with
/// the python reader), LTW2 otherwise.
pub fn write_ltw_layout(path: impl AsRef<Path>, tensors: &TensorMap,
                        layout: Layout) -> Result<()> {
    let v2 = layout != Layout::DenseF64 || has_quant(tensors);
    let mut buf = Vec::new();
    if v2 {
        buf.extend_from_slice(MAGIC2);
        buf.push(layout.code());
    } else {
        buf.extend_from_slice(MAGIC);
    }
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        let push_shape = |buf: &mut Vec<u8>, shape: &[usize]| {
            buf.push(shape.len() as u8);
            for &d in shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
        };
        match t {
            Tensor::F32 { shape, data } => {
                buf.push(0);
                push_shape(&mut buf, shape);
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Tensor::I32 { shape, data } => {
                buf.push(1);
                push_shape(&mut buf, shape);
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Tensor::QuantI8 { shape, data, scales, zero_points, chunk } => {
                buf.push(2);
                push_shape(&mut buf, shape);
                buf.extend_from_slice(&(*chunk as u32).to_le_bytes());
                buf.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                for v in scales {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                for v in zero_points {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                buf.extend(data.iter().map(|&b| b as u8));
            }
        }
    }
    let path = path.as_ref();
    std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?
        .write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = TensorMap::new();
        m.insert("a.w".into(), Tensor::F32 {
            shape: vec![2, 3],
            data: vec![1.0, -2.5, 3.0, 0.0, 1e-9, 7.25],
        });
        m.insert("tokens".into(), Tensor::I32 {
            shape: vec![4],
            data: vec![0, 1, -5, 511],
        });
        let dir = std::env::temp_dir().join("ltw_test_roundtrip.ltw");
        write_ltw(&dir, &m).unwrap();
        let back = read_ltw(&dir).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_ltw(b"NOPE\x00\x00\x00\x00").is_err());
        let mut m = TensorMap::new();
        m.insert("x".into(), Tensor::F32 { shape: vec![8], data: vec![0.0; 8] });
        let p = std::env::temp_dir().join("ltw_test_trunc.ltw");
        write_ltw(&p, &m).unwrap();
        let buf = std::fs::read(&p).unwrap();
        assert!(parse_ltw(&buf[..buf.len() - 5]).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_view() {
        let t = Tensor::F32 { shape: vec![2, 2], data: vec![1., 2., 3., 4.] };
        let m = t.to_matrix().unwrap();
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn plain_f64_maps_stay_ltw1() {
        // python-side compatibility: the default layout with no quantized
        // tensors must keep emitting byte-identical LTW1 containers
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::F32 { shape: vec![2], data: vec![1., 2.] });
        let p = std::env::temp_dir().join("ltw_test_v1_default.ltw");
        write_ltw_layout(&p, &m, Layout::DenseF64).unwrap();
        let buf = std::fs::read(&p).unwrap();
        assert_eq!(&buf[..4], MAGIC);
        let (back, layout) = parse_ltw_layout(&buf).unwrap();
        assert_eq!(back, m);
        assert_eq!(layout, Layout::DenseF64);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn ltw2_roundtrips_layout_and_quant_tensors() {
        let mut m = TensorMap::new();
        m.insert("q.w".into(), Tensor::QuantI8 {
            shape: vec![2, 3],
            data: vec![-128, -1, 0, 1, 64, 127],
            scales: vec![0.5, 0.0],
            zero_points: vec![0.25, -1.0],
            chunk: 4,
        });
        m.insert("b".into(), Tensor::F32 { shape: vec![3], data: vec![0.; 3] });
        let p = std::env::temp_dir().join("ltw_test_v2.ltw");
        for layout in [Layout::DenseF64, Layout::PackedF32, Layout::QuantI8] {
            write_ltw_layout(&p, &m, layout).unwrap();
            let buf = std::fs::read(&p).unwrap();
            assert_eq!(&buf[..4], MAGIC2, "quant tensors force LTW2");
            let (back, l2) = parse_ltw_layout(&buf).unwrap();
            assert_eq!(back, m, "save → load must be byte-faithful");
            assert_eq!(l2, layout);
            assert!(parse_ltw_layout(&buf[..buf.len() - 3]).is_err());
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn quant_tensor_dense_view_dequantizes() {
        let t = Tensor::QuantI8 {
            shape: vec![1, 2],
            data: vec![-128, 127],
            scales: vec![2.0],
            zero_points: vec![256.0],
            chunk: 2,
        };
        let m = t.to_matrix().unwrap();
        assert_eq!(m[(0, 0)], -128.0 * 2.0 + 256.0);
        assert_eq!(m[(0, 1)], 127.0 * 2.0 + 256.0);
        assert!(t.as_f32().is_err(), "raw f32 view must refuse, not lie");
    }
}
