//! LTW1 interchange reader/writer (DESIGN.md §5; python side:
//! python/compile/ltw.py). Little-endian: magic "LTW1", u32 count, then per
//! tensor: u16 name-len, name, u8 dtype (0=f32, 1=i32), u8 ndim, u32 dims…,
//! raw data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// 2-D f32 tensor → f64 Matrix.
    pub fn to_matrix(&self) -> Result<crate::Matrix> {
        let shape = self.shape();
        let data = self.as_f32()?;
        match shape.len() {
            2 => Ok(crate::Matrix::from_f32(shape[0], shape[1], data)),
            1 => Ok(crate::Matrix::from_f32(1, shape[0], data)),
            _ => bail!("to_matrix needs 1-D/2-D, got {shape:?}"),
        }
    }
}

pub type TensorMap = BTreeMap<String, Tensor>;

const MAGIC: &[u8; 4] = b"LTW1";

pub fn read_ltw(path: impl AsRef<Path>) -> Result<TensorMap> {
    let path = path.as_ref();
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    parse_ltw(&buf).with_context(|| format!("parse {}", path.display()))
}

pub fn parse_ltw(buf: &[u8]) -> Result<TensorMap> {
    if buf.len() < 8 || &buf[..4] != MAGIC {
        bail!("bad LTW1 magic");
    }
    let n = u32::from_le_bytes(buf[4..8].try_into()?) as usize;
    let mut off = 8;
    let mut out = TensorMap::new();
    for _ in 0..n {
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > buf.len() {
                bail!("truncated LTW file");
            }
            let s = &buf[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let name_len =
            u16::from_le_bytes(take(&mut off, 2)?.try_into()?) as usize;
        let name = std::str::from_utf8(take(&mut off, name_len)?)?.to_string();
        let dtype = take(&mut off, 1)?[0];
        let ndim = take(&mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into()?)
                as usize);
        }
        let count: usize = shape.iter().product();
        let raw = take(&mut off, count * 4)?;
        let t = match dtype {
            0 => Tensor::F32 {
                shape,
                data: raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            1 => Tensor::I32 {
                shape,
                data: raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            d => bail!("unknown dtype code {d}"),
        };
        out.insert(name, t);
    }
    Ok(out)
}

pub fn write_ltw(path: impl AsRef<Path>, tensors: &TensorMap) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        match t {
            Tensor::F32 { shape, data } => {
                buf.push(0);
                buf.push(shape.len() as u8);
                for &d in shape {
                    buf.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Tensor::I32 { shape, data } => {
                buf.push(1);
                buf.push(shape.len() as u8);
                for &d in shape {
                    buf.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let path = path.as_ref();
    std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?
        .write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = TensorMap::new();
        m.insert("a.w".into(), Tensor::F32 {
            shape: vec![2, 3],
            data: vec![1.0, -2.5, 3.0, 0.0, 1e-9, 7.25],
        });
        m.insert("tokens".into(), Tensor::I32 {
            shape: vec![4],
            data: vec![0, 1, -5, 511],
        });
        let dir = std::env::temp_dir().join("ltw_test_roundtrip.ltw");
        write_ltw(&dir, &m).unwrap();
        let back = read_ltw(&dir).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_ltw(b"NOPE\x00\x00\x00\x00").is_err());
        let mut m = TensorMap::new();
        m.insert("x".into(), Tensor::F32 { shape: vec![8], data: vec![0.0; 8] });
        let p = std::env::temp_dir().join("ltw_test_trunc.ltw");
        write_ltw(&p, &m).unwrap();
        let buf = std::fs::read(&p).unwrap();
        assert!(parse_ltw(&buf[..buf.len() - 5]).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_view() {
        let t = Tensor::F32 { shape: vec![2, 2], data: vec![1., 2., 3., 4.] };
        let m = t.to_matrix().unwrap();
        assert_eq!(m[(1, 0)], 3.0);
    }
}
