//! Model configurations, weight containers, and the LTW interchange IO.

pub mod config;
pub mod io;
pub mod weights;

pub use config::{MiniConfig, RealConfig, MINI_FAMILY, OPT_FAMILY};
pub use io::{read_ltw, write_ltw, Tensor};
pub use weights::Weights;
