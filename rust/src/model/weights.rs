//! Weight container: a named tensor map with matrix/bias accessors and the
//! ordered flattening used to feed the PJRT programs (parameter order comes
//! from the artifact manifest and must match python's `param_names`).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Context, Result};

use super::io::{Tensor, TensorMap};
use crate::tensor::{Layout, PackedMat};
use crate::Matrix;

/// Monotonic id source for [`Weights::cache_id`].
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct Weights {
    map: TensorMap,
    /// Execution layout the backends pack f32 matmul weights into
    /// (persisted in the LTW2 container tag; `QuantI8` *tensors* carry
    /// their own layout regardless).
    layout: Layout,
    /// Content-lineage id: assigned at construction, re-assigned by every
    /// mutating accessor; clones share the id until either side mutates.
    /// Equal ids therefore imply equal content — the invariant execution
    /// backends use to memoize per-weight-set state.
    id: u64,
}

impl Weights {
    pub fn new(map: TensorMap) -> Self {
        Weights { map, layout: Layout::DenseF64, id: fresh_id() }
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let (map, layout) = super::io::read_ltw_layout(path)?;
        Ok(Weights { map, layout, id: fresh_id() })
    }

    /// Persist with the layout tag (LTW1 for plain default-layout maps,
    /// LTW2 otherwise — see [`super::io::write_ltw_layout`]).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        super::io::write_ltw_layout(path, &self.map, self.layout)
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Re-tag the execution layout without touching tensor bytes (the
    /// packing happens at model-load time; quantization does not — use
    /// [`Weights::repack`] for that).
    pub fn set_layout(&mut self, layout: Layout) {
        if self.layout != layout {
            self.layout = layout;
            self.id = fresh_id();
        }
    }

    /// Cache key for backend-side memoization: two `Weights` with the same
    /// id are guaranteed to hold identical tensors (the converse is not
    /// required).
    pub fn cache_id(&self) -> u64 {
        self.id
    }

    pub fn map(&self) -> &TensorMap {
        &self.map
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// 2-D weight as f64 Matrix (paper convention W[out, in]).
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        self.tensor(name)?.to_matrix().context(name.to_string())
    }

    /// 1-D bias as f64 vector.
    pub fn bias(&self, name: &str) -> Result<Vec<f64>> {
        Ok(self.tensor(name)?.as_f32()?.iter().map(|&v| v as f64).collect())
    }

    /// 2-D weight in its execution layout: a stored `QuantI8` tensor
    /// executes quantized, anything else packs per the layout tag.
    pub fn packed(&self, name: &str) -> Result<PackedMat> {
        self.tensor(name)?.to_packed(self.layout).context(name.to_string())
    }

    /// Store a weight in its execution form (quantized tensors persist
    /// natively; dense/panel forms persist as f32).
    pub fn set_packed(&mut self, name: &str, p: &PackedMat) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), Tensor::from_packed(p));
    }

    /// Skip-list for [`Weights::repack`]: only 2-D f32 tensors that feed
    /// `matmul_bt`-shaped kernels are worth converting. Positional /
    /// patch-grid tables are gathered row-wise (never matmul'd) and the
    /// answer head runs through `matvec` — converting those would cost
    /// accuracy for zero kernel benefit.
    fn repackable(name: &str, t: &Tensor) -> bool {
        matches!(t, Tensor::F32 { shape, .. } if shape.len() == 2)
            && !name.contains("pos")
            && name != "ans.w"
    }

    /// A copy of this weight set converted to `layout`: every repackable
    /// tensor is quantized (`QuantI8`, on `chunk`-wide flat chunks) or
    /// left f32 with the tag flipped (`PackedF32` packs at load time).
    /// The fresh lineage id means backends rebuild their models — the
    /// converted weights never alias a cached dense model.
    pub fn repack(&self, layout: Layout, chunk: usize) -> Result<Weights> {
        let mut out = self.clone();
        out.id = fresh_id();
        out.layout = layout;
        if layout == Layout::QuantI8 {
            let names: Vec<String> = self.map.iter()
                .filter(|(n, t)| Self::repackable(n, t))
                .map(|(n, _)| n.clone())
                .collect();
            for name in names {
                let m = self.matrix(&name)?;
                let q = PackedMat::quantize_i8(&m, chunk);
                out.map.insert(name, Tensor::from_packed(&q));
            }
        }
        Ok(out)
    }

    /// Replace a 2-D weight (keeps f32 storage).
    pub fn set_matrix(&mut self, name: &str, m: &Matrix) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), Tensor::F32 {
            shape: vec![m.rows(), m.cols()],
            data: m.to_f32(),
        });
    }

    pub fn set_bias(&mut self, name: &str, b: &[f64]) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), Tensor::F32 {
            shape: vec![b.len()],
            data: b.iter().map(|&v| v as f32).collect(),
        });
    }

    pub fn set_tensor(&mut self, name: &str, t: Tensor) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), t);
    }

    /// Total element count.
    pub fn n_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Flatten in the given order (for PJRT program parameters).
    pub fn ordered<'a>(&'a self, names: &[String]) -> Result<Vec<&'a Tensor>> {
        names.iter().map(|n| self.tensor(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Weights {
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::F32 {
            shape: vec![2, 2], data: vec![1., 2., 3., 4.],
        });
        m.insert("b".into(), Tensor::F32 { shape: vec![2], data: vec![5., 6.] });
        Weights::new(m)
    }

    #[test]
    fn accessors() {
        let w = sample();
        assert_eq!(w.matrix("w").unwrap()[(0, 1)], 2.0);
        assert_eq!(w.bias("b").unwrap(), vec![5.0, 6.0]);
        assert!(w.matrix("nope").is_err());
        assert_eq!(w.n_elements(), 6);
    }

    #[test]
    fn cache_id_tracks_mutation_lineage() {
        let w = sample();
        let clone = w.clone();
        assert_eq!(w.cache_id(), clone.cache_id(),
                   "clones share content, so they may share the id");
        let mut diverged = w.clone();
        diverged.set_bias("b", &[9.0, 9.0]);
        assert_ne!(diverged.cache_id(), w.cache_id(),
                   "mutation must invalidate the id");
        assert_ne!(sample().cache_id(), sample().cache_id());
    }

    #[test]
    fn repack_quantizes_weights_and_artifact_roundtrips_exactly() {
        let mut m = TensorMap::new();
        let vals: Vec<f32> = (0..48).map(|i| (i as f32 * 0.37).sin()).collect();
        m.insert("layers.0.attn.wq".into(),
                 Tensor::F32 { shape: vec![6, 8], data: vals });
        m.insert("layers.0.attn.bq".into(),
                 Tensor::F32 { shape: vec![6], data: vec![0.1; 6] });
        m.insert("pos_emb".into(),
                 Tensor::F32 { shape: vec![4, 8], data: vec![0.5; 32] });
        let w = Weights::new(m);
        let q = w.repack(Layout::QuantI8, 16).unwrap();
        assert_eq!(q.layout(), Layout::QuantI8);
        assert_ne!(q.cache_id(), w.cache_id());
        let pq = q.packed("layers.0.attn.wq").unwrap();
        assert_eq!(pq.layout(), Layout::QuantI8);
        assert!(matches!(q.tensor("pos_emb").unwrap(), Tensor::F32 { .. }),
                "positional tables stay f32");
        assert!(q.bias("layers.0.attn.bq").is_ok(), "biases stay f32");

        // save → load → the execution form is byte-identical
        let p = std::env::temp_dir().join("weights_test_repack.ltw");
        q.save(&p).unwrap();
        let back = Weights::load(&p).unwrap();
        assert_eq!(back.layout(), Layout::QuantI8);
        assert_eq!(back.packed("layers.0.attn.wq").unwrap(), pq,
                   "PackedMat bytes must survive the artifact round-trip");
        assert_eq!(back.map(), q.map());
        std::fs::remove_file(p).ok();

        // f32 panel layout: tensors untouched, tag flips, packing at load
        let f = w.repack(Layout::PackedF32, 16).unwrap();
        assert_eq!(f.tensor("layers.0.attn.wq").unwrap(),
                   w.tensor("layers.0.attn.wq").unwrap());
        assert_eq!(f.packed("layers.0.attn.wq").unwrap().layout(),
                   Layout::PackedF32);
    }

    #[test]
    fn set_and_order() {
        let mut w = sample();
        w.set_matrix("w", &Matrix::eye(2));
        assert_eq!(w.matrix("w").unwrap()[(0, 0)], 1.0);
        assert_eq!(w.matrix("w").unwrap()[(0, 1)], 0.0);
        let ord = w.ordered(&["b".into(), "w".into()]).unwrap();
        assert_eq!(ord[0].shape(), &[2]);
        assert_eq!(ord[1].shape(), &[2, 2]);
    }
}
