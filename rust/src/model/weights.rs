//! Weight container: a named tensor map with matrix/bias accessors and the
//! ordered flattening used to feed the PJRT programs (parameter order comes
//! from the artifact manifest and must match python's `param_names`).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Context, Result};

use super::io::{Tensor, TensorMap};
use crate::Matrix;

/// Monotonic id source for [`Weights::cache_id`].
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn fresh_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct Weights {
    map: TensorMap,
    /// Content-lineage id: assigned at construction, re-assigned by every
    /// mutating accessor; clones share the id until either side mutates.
    /// Equal ids therefore imply equal content — the invariant execution
    /// backends use to memoize per-weight-set state.
    id: u64,
}

impl Weights {
    pub fn new(map: TensorMap) -> Self {
        Weights { map, id: fresh_id() }
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Weights::new(super::io::read_ltw(path)?))
    }

    /// Cache key for backend-side memoization: two `Weights` with the same
    /// id are guaranteed to hold identical tensors (the converse is not
    /// required).
    pub fn cache_id(&self) -> u64 {
        self.id
    }

    pub fn map(&self) -> &TensorMap {
        &self.map
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// 2-D weight as f64 Matrix (paper convention W[out, in]).
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        self.tensor(name)?.to_matrix().context(name.to_string())
    }

    /// 1-D bias as f64 vector.
    pub fn bias(&self, name: &str) -> Result<Vec<f64>> {
        Ok(self.tensor(name)?.as_f32()?.iter().map(|&v| v as f64).collect())
    }

    /// Replace a 2-D weight (keeps f32 storage).
    pub fn set_matrix(&mut self, name: &str, m: &Matrix) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), Tensor::F32 {
            shape: vec![m.rows(), m.cols()],
            data: m.to_f32(),
        });
    }

    pub fn set_bias(&mut self, name: &str, b: &[f64]) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), Tensor::F32 {
            shape: vec![b.len()],
            data: b.iter().map(|&v| v as f32).collect(),
        });
    }

    pub fn set_tensor(&mut self, name: &str, t: Tensor) {
        self.id = fresh_id();
        self.map.insert(name.to_string(), t);
    }

    /// Total element count.
    pub fn n_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Flatten in the given order (for PJRT program parameters).
    pub fn ordered<'a>(&'a self, names: &[String]) -> Result<Vec<&'a Tensor>> {
        names.iter().map(|n| self.tensor(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Weights {
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::F32 {
            shape: vec![2, 2], data: vec![1., 2., 3., 4.],
        });
        m.insert("b".into(), Tensor::F32 { shape: vec![2], data: vec![5., 6.] });
        Weights::new(m)
    }

    #[test]
    fn accessors() {
        let w = sample();
        assert_eq!(w.matrix("w").unwrap()[(0, 1)], 2.0);
        assert_eq!(w.bias("b").unwrap(), vec![5.0, 6.0]);
        assert!(w.matrix("nope").is_err());
        assert_eq!(w.n_elements(), 6);
    }

    #[test]
    fn cache_id_tracks_mutation_lineage() {
        let w = sample();
        let clone = w.clone();
        assert_eq!(w.cache_id(), clone.cache_id(),
                   "clones share content, so they may share the id");
        let mut diverged = w.clone();
        diverged.set_bias("b", &[9.0, 9.0]);
        assert_ne!(diverged.cache_id(), w.cache_id(),
                   "mutation must invalidate the id");
        assert_ne!(sample().cache_id(), sample().cache_id());
    }

    #[test]
    fn set_and_order() {
        let mut w = sample();
        w.set_matrix("w", &Matrix::eye(2));
        assert_eq!(w.matrix("w").unwrap()[(0, 0)], 1.0);
        assert_eq!(w.matrix("w").unwrap()[(0, 1)], 0.0);
        let ord = w.ordered(&["b".into(), "w".into()]).unwrap();
        assert_eq!(ord[0].shape(), &[2]);
        assert_eq!(ord[1].shape(), &[2, 2]);
    }
}
