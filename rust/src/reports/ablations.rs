//! Ablation studies for the design choices DESIGN.md calls out:
//!  * junction matrix: block identity vs dense factors at the SAME rank
//!    (identical loss, r² fewer params — §3.3) and at the same PARAMS
//!    (block identity buys a higher rank → lower ppl);
//!  * joint-VO vs split-V/O (paper Remark 11);
//!  * Algorithm 1 iteration count (paper used 8 for QK, 4 for UD);
//!  * calibration sample budget (paper: 64 × 2048 tokens);
//!  * per-layer ratio schedules (front/back-loaded compression plans).

use anyhow::Result;

use super::tables::TableCtx;
use crate::compress::asvd::{self, AsvdOpts};
use crate::compress::joint_qk::{self, JointQkOpts};
use crate::compress::junction::Junction;
use crate::compress::pipeline::Method;
use crate::compress::plan::compress_plan;
use crate::compress::precond::Precond;
use crate::data::{CalibSet, Corpus};
use crate::eval;
use crate::model::config::mini_by_name;
use crate::model::Weights;
use crate::util::json::Value;

pub fn run(ctx: &TableCtx, model: &str, ratio: f64) -> Result<Value> {
    let cfg = mini_by_name(model).expect("model");
    let weights = Weights::load(ctx.artifacts.join(
        format!("model_{model}.ltw")))?;
    let calib = CalibSet::load(ctx.artifacts.join(
        format!("calib_{model}.ltw")), cfg.n_layers)?;
    let corpus = Corpus::load(ctx.artifacts.join("corpora.ltw"),
                              "synthwiki", "test")?;
    let program = format!("score_{model}");
    let ppl_of = |w: &Weights| -> Result<f64> {
        Ok(eval::perplexity(ctx.engine, &program, w, &corpus, 8, 128,
                            ctx.max_batches)?.ppl)
    };
    let mut out = Vec::new();

    // ---- junction ablation (single layer, same rank): identical loss,
    // fewer params — the §3.3 claim in isolation.
    {
        let w = weights.matrix("layers.0.attn.wq")?;
        let x = calib.x(0, "attn_x");
        let r = cfg.d / 2;
        let left = asvd::compress(&w, r, &AsvdOpts {
            kind: Precond::RootCov, junction: Junction::Left,
            x: Some(x), ..Default::default() });
        let blockid = asvd::compress(&w, r, &AsvdOpts {
            kind: Precond::RootCov, junction: Junction::BlockId,
            x: Some(x), ..Default::default() });
        let rel = (left.loss - blockid.loss).abs()
            / left.loss.max(1e-12);
        out.push(Value::obj(vec![
            ("ablation", "junction_same_rank".into()),
            ("rank", r.into()),
            ("loss_dense", left.loss.into()),
            ("loss_blockid", blockid.loss.into()),
            ("loss_rel_diff", rel.into()),
            ("params_dense", left.params.into()),
            ("params_blockid", blockid.params.into()),
        ]));
        println!("junction @rank {r}: identical loss (rel diff {rel:.2e}), \
                  params {} -> {}", left.params, blockid.params);
    }

    // ---- joint-VO vs split-V/O (Remark 11)
    for (name, method) in [("split_vo", Method::LatentLlm),
                           ("joint_vo", Method::LatentLlmJointVo)] {
        let p = method.plan().with_ratio(ratio)
            .with_iters(ctx.qk_iters, ctx.ud_iters);
        let (nw, rep) = compress_plan(cfg, &weights, &calib, &p)?;
        let ppl = ppl_of(&nw)?;
        println!("{name}: ppl {ppl:.3} (achieved {:.3})",
                 rep.achieved_ratio());
        out.push(Value::obj(vec![
            ("ablation", "vo_strategy".into()),
            ("variant", name.into()),
            ("ppl", ppl.into()),
            ("achieved_ratio", rep.achieved_ratio().into()),
        ]));
    }

    // ---- Algorithm 1 iteration sweep (attention-map loss + ppl)
    for iters in [0usize, 1, 2, 4, 8] {
        let wq = weights.matrix("layers.0.attn.wq")?;
        let wk = weights.matrix("layers.0.attn.wk")?;
        let x = calib.x(0, "attn_x");
        let r = 3 * cfg.d / 4;
        let jq = joint_qk::compress(&wq, &wk, cfg.n_heads, cfg.d_h(), r, r,
                                    &JointQkOpts { kind: Precond::RootCov,
                                                   n_iter: iters.max(1),
                                                   x: Some(x),
                                                   ..Default::default() });
        let loss = if iters == 0 { jq.losses[0] }
                   else { *jq.losses.last().unwrap() };
        let p = Method::LatentLlm.plan().with_ratio(ratio)
            .with_iters(iters.max(1), ctx.ud_iters);
        let (nw, _) = compress_plan(cfg, &weights, &calib, &p)?;
        let ppl = ppl_of(&nw)?;
        println!("qk_iters={iters}: attn-loss {loss:.4e}  ppl {ppl:.3}");
        out.push(Value::obj(vec![
            ("ablation", "qk_iters".into()),
            ("iters", iters.into()),
            ("attn_loss", loss.into()),
            ("ppl", ppl.into()),
        ]));
    }

    // ---- calibration budget sweep
    for cols in [128usize, 384, 1024] {
        let cal_small = subsample(&calib, cfg.n_layers, cols);
        let p = Method::LatentLlm.plan().with_ratio(ratio)
            .with_iters(ctx.qk_iters, ctx.ud_iters);
        let (nw, _) = compress_plan(cfg, &weights, &cal_small, &p)?;
        let ppl = ppl_of(&nw)?;
        println!("calib_cols={cols}: ppl {ppl:.3}");
        out.push(Value::obj(vec![
            ("ablation", "calib_budget".into()),
            ("cols", cols.into()),
            ("ppl", ppl.into()),
        ]));
    }

    // ---- per-layer ratio schedule (plan-only scenario): front-loaded vs
    // back-loaded vs uniform at (approximately) the same global budget
    {
        let n = cfg.n_layers;
        let spread = (ratio * 0.5).min(1.0 - ratio - 0.01).max(0.0);
        let front: Vec<f64> = (0..n).map(|i| if i < n / 2 {
            ratio + spread
        } else {
            ratio - spread
        }).collect();
        let back: Vec<f64> = front.iter().rev().copied().collect();
        for (name, sched) in [("uniform", Vec::new()),
                              ("front_loaded", front),
                              ("back_loaded", back)] {
            let p = Method::LatentLlm.plan().with_ratio(ratio)
                .with_layer_ratios(sched)
                .with_iters(ctx.qk_iters, ctx.ud_iters);
            let (nw, rep) = compress_plan(cfg, &weights, &calib, &p)?;
            let ppl = ppl_of(&nw)?;
            println!("layer_schedule={name}: ppl {ppl:.3} (achieved \
                      {:.3})", rep.achieved_ratio());
            out.push(Value::obj(vec![
                ("ablation", "layer_schedule".into()),
                ("variant", name.into()),
                ("ppl", ppl.into()),
                ("achieved_ratio", rep.achieved_ratio().into()),
            ]));
        }
    }

    Ok(Value::obj(vec![("report", "ablations".into()),
                       ("model", model.into()),
                       ("ratio", ratio.into()),
                       ("entries", Value::Arr(out))]))
}

fn subsample(cal: &CalibSet, n_layers: usize, cols: usize) -> CalibSet {
    // deterministic stride subsample of the calibration columns
    let mut layers = Vec::new();
    for i in 0..n_layers {
        let mut m = std::collections::BTreeMap::new();
        for kind in ["attn_x", "o_x", "mlp_x"] {
            let x = cal.x(i, kind);
            let total = x.cols();
            let take = cols.min(total);
            let stride = (total / take).max(1);
            let idx: Vec<usize> =
                (0..take).map(|j| (j * stride) % total).collect();
            m.insert(kind.to_string(), x.select_cols(&idx));
        }
        layers.push(m);
    }
    CalibSet::from_layers(layers)
}
