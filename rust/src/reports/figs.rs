//! Appendix-figure generators (Figs 7–16): the paper's self-contained
//! synthetic studies on random weights with Wishart-sampled correlations
//! ("covariance of identity or off-diagonal decaying of 0.9 factor").
//! Pure rust — no artifacts needed. Sizes are scaled so the whole suite
//! runs in seconds; the *shapes* (who wins, where) are the reproduction
//! target (DESIGN.md §4).

use crate::compress::asvd::{self, AsvdOpts};
use crate::compress::junction::Junction;
use crate::compress::precond::Precond;
use crate::compress::{joint_qk, rope, sparse};
use crate::tensor::linalg::act_loss;
use crate::util::json::Value;
use crate::util::rng::{decaying_covariance, wishart, Rng};
use crate::Matrix;

fn db(loss: f64, ref_loss: f64) -> f64 {
    10.0 * (loss / ref_loss.max(1e-300)).log10()
}

fn series(name: &str, x: Vec<f64>, y: Vec<f64>) -> Value {
    Value::obj(vec![("name", name.into()), ("x", x.into()),
                    ("y", y.into())])
}

/// Fig 7: plain SVD vs CorDA (Cov) vs RootCorDA (RootCov) — activation
/// loss vs rank on random weights with Wishart(0.9-decay) correlation.
pub fn fig7(d: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let ranks: Vec<usize> = (1..=8).map(|i| i * d / 10).collect();
    let mut out = Vec::new();
    for kind in [Precond::Identity, Precond::Cov, Precond::RootCov] {
        let opts = AsvdOpts { kind, junction: Junction::Left,
                              ..Default::default() };
        let ys: Vec<f64> = ranks.iter().map(|&r| {
            let res = asvd::compress_with_cov(&w, r, &c, &vec![0.0; d],
                                              &opts);
            db(res.loss, ref_loss)
        }).collect();
        out.push(series(kind.name(), ranks.iter().map(|&r| r as f64)
                        .collect(), ys));
    }
    Value::obj(vec![("figure", "fig7".into()), ("d", d.into()),
                    ("ylabel", "relative loss (dB)".into()),
                    ("series", Value::Arr(out))])
}

/// Fig 8: joint-QKV (shared A) vs split-QKV at equal parameter budget.
pub fn fig8(d: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let wq = rng.normal_matrix(d, d);
    let wk = rng.normal_matrix(d, d);
    let wv = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss: f64 = [&wq, &wk, &wv].iter()
        .map(|w| w.matmul(&c).matmul_bt(w).trace()).sum();
    let opts = AsvdOpts { kind: Precond::RootCov, junction: Junction::Left,
                          ..Default::default() };
    let ranks: Vec<usize> = (1..=8).map(|i| i * d / 12).collect();
    let (mut split_y, mut joint_y, mut xs) = (vec![], vec![], vec![]);
    for &r in &ranks {
        let params = 3 * r * 2 * d;
        xs.push(params as f64);
        let mut split = 0.0;
        for w in [&wq, &wk, &wv] {
            split += asvd::compress_with_cov(w, r, &c, &vec![0.0; d],
                                             &opts).loss;
        }
        split_y.push(db(split, ref_loss));
        // joint rank at equal params: r_j (3d + d) = 3r·2d
        let r_j = (3 * r * 2 * d) / (4 * d);
        let stacked = Matrix::vstack(&[&wq, &wk, &wv]);
        let joint = asvd::compress_with_cov(&stacked, r_j.max(1), &c,
                                            &vec![0.0; d], &opts);
        joint_y.push(db(joint.loss, ref_loss));
    }
    Value::obj(vec![("figure", "fig8".into()), ("d", d.into()),
                    ("xlabel", "params".into()),
                    ("series", Value::Arr(vec![
                        series("split-qkv", xs.clone(), split_y),
                        series("joint-qkv", xs, joint_y)]))])
}

/// Fig 9: split-head vs joint-head compression.
pub fn fig9(d: usize, h: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let opts = AsvdOpts { kind: Precond::RootCov, junction: Junction::Left,
                          ..Default::default() };
    let ranks: Vec<usize> = (1..=6).map(|i| i * d / 8).collect();
    let (mut joint_y, mut split_y) = (vec![], vec![]);
    for &r in &ranks {
        joint_y.push(db(asvd::compress_with_cov(&w, r, &c, &vec![0.0; d],
                                                &opts).loss, ref_loss));
        // split-head: rank r/h per head slice, same covariance
        let dh = d / h;
        let rh = (r / h).max(1);
        let blocks: Vec<Matrix> = (0..h).map(|i| {
            asvd::compress_with_cov(&w.slice_rows(i * dh, (i + 1) * dh),
                                    rh, &c, &vec![0.0; d], &opts).w_hat
        }).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        let w_hat = Matrix::vstack(&refs);
        split_y.push(db(act_loss(&w, &w_hat, &c), ref_loss));
    }
    Value::obj(vec![("figure", "fig9".into()), ("d", d.into()),
                    ("series", Value::Arr(vec![
                        series("joint-head",
                               ranks.iter().map(|&r| r as f64).collect(),
                               joint_y),
                        series("split-head",
                               ranks.iter().map(|&r| r as f64).collect(),
                               split_y)]))])
}

/// Fig 10: attention-aware joint HOSVD vs activation-aware per-matrix ASVD
/// on the attention-map loss (random QK, Wishart 0.9 correlation; WandA =
/// diagonal correlation variant).
pub fn fig10(d: usize, h: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let dh = d / h;
    let wq = rng.normal_matrix(d, d);
    let wk = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let p = crate::tensor::sqrtm_psd(&c);
    let wq_w = wq.matmul(&p);
    let wk_w = wk.matmul(&p);
    let ref_loss: f64 = (0..h).map(|i| {
        wq_w.slice_rows(i * dh, (i + 1) * dh)
            .matmul_at(&wk_w.slice_rows(i * dh, (i + 1) * dh)).frob2()
    }).sum();
    let attn_loss = |wq_h: &Matrix, wk_h: &Matrix| -> f64 {
        (0..h).map(|i| {
            let g = wq_w.slice_rows(i * dh, (i + 1) * dh)
                .matmul_at(&wk_w.slice_rows(i * dh, (i + 1) * dh));
            let gh = wq_h.slice_rows(i * dh, (i + 1) * dh)
                .matmul_at(&wk_h.slice_rows(i * dh, (i + 1) * dh));
            g.sub(&gh).frob2()
        }).sum()
    };
    let ranks: Vec<usize> = (1..=6).map(|i| i * d / 8).collect();
    let (mut aware, mut act, mut wanda) = (vec![], vec![], vec![]);
    for &r in &ranks {
        let jq = joint_qk::compress(&wq_w, &wk_w, h, dh, r, r,
                                    &joint_qk::JointQkOpts {
                                        kind: Precond::Identity, n_iter: 8,
                                        ..Default::default() });
        aware.push(db(*jq.losses.last().unwrap(), ref_loss));
        let opts = AsvdOpts { kind: Precond::Identity,
                              junction: Junction::Left,
                              ..Default::default() };
        let rq = asvd::compress(&wq_w, r, &opts);
        let rk = asvd::compress(&wk_w, r, &opts);
        act.push(db(attn_loss(&rq.w_hat, &rk.w_hat), ref_loss));
        // WandA-style: diagonal correlation pre-conditioner on raw weights
        let dopts = AsvdOpts { kind: Precond::DiagL2,
                               junction: Junction::Left,
                               ..Default::default() };
        let wq_d = asvd::compress_with_cov(&wq, r, &c, &vec![0.0; d],
                                           &dopts);
        let wk_d = asvd::compress_with_cov(&wk, r, &c, &vec![0.0; d],
                                           &dopts);
        wanda.push(db(attn_loss(&wq_d.w_hat.matmul(&p),
                                &wk_d.w_hat.matmul(&p)), ref_loss));
    }
    let xs: Vec<f64> = ranks.iter().map(|&r| r as f64).collect();
    Value::obj(vec![("figure", "fig10".into()), ("d", d.into()),
                    ("series", Value::Arr(vec![
                        series("attention-aware (hosvd)", xs.clone(), aware),
                        series("activation-aware (asvd)", xs.clone(), act),
                        series("wanda-diag", xs, wanda)]))])
}

/// Fig 11 + Fig 16: sparse vs low-rank at equal parameter budget, and
/// full-C iterative vs diagonal-C one-shot.
pub fn fig11_16(d: usize, seed: u64) -> (Value, Value) {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let fracs = [0.1, 0.2, 0.3, 0.45, 0.6, 0.8];
    let (mut lr_y, mut sp_y, mut wd_y, mut fi_y, mut xs) =
        (vec![], vec![], vec![], vec![], vec![]);
    for &f in &fracs {
        let budget = (f * (d * d) as f64) as usize;
        xs.push(f);
        let r = (budget / (2 * d)).max(1);
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::Left,
                              ..Default::default() };
        lr_y.push(db(asvd::compress_with_cov(&w, r, &c, &vec![0.0; d],
                                             &opts).loss, ref_loss));
        let (_, sp) = sparse::projected_gd(&w, &c, budget, 50);
        sp_y.push(db(sp, ref_loss));
        let (_, wd) = sparse::wanda_diag(&w, &c, budget);
        wd_y.push(db(wd, ref_loss));
        let (_, fi) = sparse::fista(&w, &c, budget, 40);
        fi_y.push(db(fi, ref_loss));
    }
    let fig11 = Value::obj(vec![
        ("figure", "fig11".into()), ("d", d.into()),
        ("xlabel", "param fraction".into()),
        ("series", Value::Arr(vec![
            series("low-rank (rootcov)", xs.clone(), lr_y.clone()),
            series("sparse (hard/STE)", xs.clone(), sp_y.clone())]))]);
    let fig16 = Value::obj(vec![
        ("figure", "fig16".into()), ("d", d.into()),
        ("series", Value::Arr(vec![
            series("full-C iterative", xs.clone(), sp_y),
            series("fista", xs.clone(), fi_y),
            series("wanda diag-C one-shot", xs, wd_y)]))]);
    (fig11, fig16)
}

/// Fig 12: RoPE-aware vs RoPE-blind HOSVD under the 10-token-window loss
/// (θ = 1e4). Dimension is scaled from the paper's 768 for runtime; set
/// d higher via the CLI for the full-size run.
pub fn fig12(d: usize, h: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let dh = d / h;
    let wq = rng.normal_matrix(d, d);
    let wk = rng.normal_matrix(d, d);
    let c = Matrix::eye(d);
    let ranks: Vec<usize> = (1..=5).map(|i| i * d / 7).collect();
    let ref_loss = rope::rope_window_loss(&wq, &wk, h, dh,
                                          &Matrix::zeros(1, d),
                                          &Matrix::zeros(1, d), 10, 1e4,
                                          Precond::Identity, &c);
    let (mut aware, mut blind) = (vec![], vec![]);
    for &r in &ranks {
        let a = rope::compress_rope_aware(&wq, &wk, h, dh, r, r, 10, 1e4, 6,
                                          Precond::Identity, &c);
        aware.push(db(*a.losses.last().unwrap(), ref_loss));
        let b = rope::compress_rope_aware(&wq, &wk, h, dh, r, r, 1, 1e4, 6,
                                          Precond::Identity, &c);
        blind.push(db(rope::rope_window_loss(&wq, &wk, h, dh, &b.aq, &b.ak,
                                             10, 1e4, Precond::Identity,
                                             &c), ref_loss));
    }
    let xs: Vec<f64> = ranks.iter().map(|&r| r as f64).collect();
    Value::obj(vec![("figure", "fig12".into()), ("d", d.into()),
                    ("series", Value::Arr(vec![
                        series("rope-aware hosvd", xs.clone(), aware),
                        series("rope-blind hosvd", xs, blind)]))])
}

/// Fig 13: STE/hard-shrink vs soft-shrink vs FISTA across sparsity.
pub fn fig13(d: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let fracs = [0.1, 0.25, 0.4, 0.6, 0.8];
    let (mut hard, mut fista_y, mut xs) = (vec![], vec![], vec![]);
    for &f in &fracs {
        let k = (f * (d * d) as f64) as usize;
        xs.push(f);
        hard.push(db(sparse::projected_gd(&w, &c, k, 60).1, ref_loss));
        fista_y.push(db(sparse::fista(&w, &c, k, 50).1, ref_loss));
    }
    Value::obj(vec![("figure", "fig13".into()), ("d", d.into()),
                    ("series", Value::Arr(vec![
                        series("hardshrink/STE", xs.clone(), hard),
                        series("fista (softshrink)", xs, fista_y)]))])
}

/// Fig 14: low-rank+sparse vs sparse-alone vs low-rank-alone.
pub fn fig14(d: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let fracs = [0.2, 0.4, 0.6];
    let (mut mix, mut sp, mut lr, mut xs) = (vec![], vec![], vec![], vec![]);
    for &f in &fracs {
        let budget = (f * (d * d) as f64) as usize;
        xs.push(f);
        // mixed: half budget to rank, half to sparse
        let r = (budget / 2 / (2 * d)).max(1);
        let kappa = budget / 2;
        let (_, _, hist) = sparse::lowrank_plus_sparse(&w, &c, r, kappa, 4);
        mix.push(db(*hist.last().unwrap(), ref_loss));
        sp.push(db(sparse::projected_gd(&w, &c, budget, 50).1, ref_loss));
        let opts = AsvdOpts { kind: Precond::RootCov,
                              junction: Junction::Left,
                              ..Default::default() };
        lr.push(db(asvd::compress_with_cov(&w, (budget / (2 * d)).max(1),
                                           &c, &vec![0.0; d], &opts).loss,
                   ref_loss));
    }
    Value::obj(vec![("figure", "fig14".into()), ("d", d.into()),
                    ("series", Value::Arr(vec![
                        series("lowrank+sparse", xs.clone(), mix),
                        series("sparse-alone", xs.clone(), sp),
                        series("lowrank-alone", xs, lr)]))])
}

/// Fig 15: sparsifying the low-rank factors B/A.
pub fn fig15(d: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let w = rng.normal_matrix(d, d);
    let c = wishart(&mut rng, &decaying_covariance(d, 0.9), 2 * d);
    let ref_loss = w.matmul(&c).matmul_bt(&w).trace();
    let r = 2 * d / 3; // "rank 640/512 of 768" scale analogue
    let opts = AsvdOpts { kind: Precond::RootCov, junction: Junction::Left,
                          ..Default::default() };
    let base = asvd::compress_with_cov(&w, r, &c, &vec![0.0; d], &opts);
    let keeps = [1.0, 0.8, 0.6, 0.4, 0.25];
    let (mut ys, mut sp_ys, mut xs) = (vec![], vec![], vec![]);
    for &kf in &keeps {
        let params = (2.0 * (r * d) as f64 * kf) as usize;
        xs.push(params as f64 / (d * d) as f64);
        if kf >= 1.0 {
            ys.push(db(base.loss, ref_loss));
        } else {
            let (_, _, hist) = sparse::sparsify_factors(
                &base.factors.b, &base.factors.a, &w, &c, kf, 30);
            ys.push(db(*hist.last().unwrap(), ref_loss));
        }
        sp_ys.push(db(sparse::projected_gd(&w, &c, params, 40).1, ref_loss));
    }
    Value::obj(vec![("figure", "fig15".into()), ("d", d.into()),
                    ("xlabel", "param fraction".into()),
                    ("series", Value::Arr(vec![
                        series("sparsified B/A", xs.clone(), ys),
                        series("sparse-alone", xs, sp_ys)]))])
}

/// Render a figure Value as an aligned text block (series per row).
pub fn render(v: &Value) -> String {
    let mut out = String::new();
    let name = v.get("figure").and_then(|f| f.as_str()).unwrap_or("fig");
    out.push_str(&format!("== {name} ==\n"));
    if let Some(series) = v.get("series").and_then(|s| s.as_arr()) {
        for s in series {
            let nm = s.get("name").and_then(|n| n.as_str()).unwrap_or("?");
            let xs = s.get("x").and_then(|x| x.as_arr()).unwrap_or(&[]);
            let ys = s.get("y").and_then(|y| y.as_arr()).unwrap_or(&[]);
            out.push_str(&format!("  {nm:<28}"));
            for (x, y) in xs.iter().zip(ys) {
                out.push_str(&format!(" ({:.2},{:+.1}dB)",
                                      x.as_f64().unwrap_or(0.0),
                                      y.as_f64().unwrap_or(0.0)));
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn last_ys(v: &Value) -> Vec<(String, f64)> {
        v.get("series").unwrap().as_arr().unwrap().iter().map(|s| {
            let name = s.get("name").unwrap().as_str().unwrap().to_string();
            let ys = s.get("y").unwrap().as_arr().unwrap();
            (name, ys.last().unwrap().as_f64().unwrap())
        }).collect()
    }

    #[test]
    fn fig7_ordering_rootcov_best() {
        let v = fig7(24, 1);
        let ys = last_ys(&v);
        let get = |n: &str| ys.iter().find(|(k, _)| k == n).unwrap().1;
        assert!(get("rootcov") <= get("cov") + 1e-9);
        assert!(get("rootcov") <= get("identity") + 1e-9);
    }

    #[test]
    fn fig10_attention_aware_wins() {
        let v = fig10(24, 4, 2);
        let ys = last_ys(&v);
        let get = |n: &str| ys.iter().find(|(k, _)| k.starts_with(n))
            .unwrap().1;
        assert!(get("attention-aware") <= get("activation-aware") + 1e-6);
    }

    #[test]
    fn fig11_sparse_beats_lowrank() {
        let (f11, _) = fig11_16(20, 3);
        let ys = last_ys(&f11);
        let get = |n: &str| ys.iter().find(|(k, _)| k.starts_with(n))
            .unwrap().1;
        assert!(get("sparse") <= get("low-rank") + 1e-6);
    }

    #[test]
    fn render_is_nonempty() {
        let v = fig13(12, 4);
        let s = render(&v);
        assert!(s.contains("fig13"));
        assert!(s.contains("dB"));
    }
}
