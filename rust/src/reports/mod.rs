//! Report generators — one per paper table/figure (DESIGN.md §4 index).
//! Each writes aligned text to stdout and a JSON artifact under the report
//! output directory so the series can be re-plotted.

pub mod ablations;
pub mod figs;
pub mod table;
pub mod tables;

pub use table::TextTable;
