//! Aligned text-table rendering for report output.

pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    out.push_str(&format!("{:<w$}", c, w = widths[0]));
                } else {
                    out.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            out.push('\n');
        };
        line(&self.header, &mut out);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f1pct(v: f64) -> String {
    format!("{:.2}", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["method", "ppl"]);
        t.row(vec!["plain".into(), "65.17".into()]);
        t.row(vec!["latentllm".into(), "51.8".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("method"));
        assert!(lines[3].starts_with("latentllm"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_bad_row() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
