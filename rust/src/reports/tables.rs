//! Table/figure generators that need the artifacts (trained models,
//! calibration, PJRT programs): Table 2 (perplexity grid), Table 3
//! (FLOPs/MACs/params), Table 4 + Fig 6 (multimodal accuracy), Fig 4
//! (ppl vs ratio) and Fig 5 (ppl vs FLOPs).

use anyhow::{Context, Result};

use super::table::TextTable;
use crate::compress::pipeline::{self, Method};
use crate::compress::plan::{self, CompressionPlan};
use crate::data::{CalibSet, Corpus};
use crate::eval;
use crate::flops;
use crate::model::config::{mini_by_name, MiniConfig, OPT_FAMILY};
use crate::model::Weights;
use crate::runtime::Engine;
use crate::util::json::Value;
use crate::util::pool::Pool;

pub struct TableCtx<'a> {
    pub engine: &'a Engine,
    pub artifacts: std::path::PathBuf,
    /// eval batches cap (speed knob)
    pub max_batches: usize,
    pub qk_iters: usize,
    pub ud_iters: usize,
}

fn load_model(ctx: &TableCtx, cfg: &MiniConfig)
              -> Result<(Weights, CalibSet)> {
    let w = Weights::load(ctx.artifacts.join(
        format!("model_{}.ltw", cfg.name)))?;
    let cal = CalibSet::load(ctx.artifacts.join(
        format!("calib_{}.ltw", cfg.name)), cfg.n_layers)?;
    Ok((w, cal))
}

fn corpora(ctx: &TableCtx) -> Result<Vec<Corpus>> {
    ["synthwiki", "synthptb", "synthc4"].iter()
        .map(|n| Corpus::load(ctx.artifacts.join("corpora.ltw"), n, "test"))
        .collect()
}

/// Table 2: perplexity of each model size × plan × ratio on the three
/// synthetic corpora (paper: OPT family on WT2/PTB/C4 at 10–40%).
///
/// Plans come in as data (the historical method set is
/// `pipeline::table2_plans()`); each is re-targeted with
/// [`CompressionPlan::with_ratio`] and the ctx iteration budgets. The
/// compression sweep (the dominant cost) runs plan×ratio combos
/// concurrently on the global [`Pool`]; evaluation stays on this thread
/// (execution backends are not `Sync`) and rows emit in the same
/// deterministic plan-major order as the serial sweep.
pub fn table2(ctx: &TableCtx, sizes: &[&str], ratios: &[f64],
              plans: &[CompressionPlan]) -> Result<Value> {
    let (batch, seq_len) = score_dims(ctx.engine);
    let corp = corpora(ctx)?;
    let mut rows = Vec::new();
    let mut out = TextTable::new(&{
        let mut h = vec!["model", "method", "ratio"];
        h.extend(corp.iter().map(|c| c.name.as_str()));
        h
    });
    let (qk_iters, ud_iters) = (ctx.qk_iters, ctx.ud_iters);
    for size in sizes {
        let cfg = mini_by_name(size).context("unknown size")?;
        let (weights, cal) = load_model(ctx, cfg)?;
        let program = format!("score_{}", cfg.name);
        // baseline row (0%)
        let mut base = vec![];
        for c in &corp {
            let r = eval::perplexity(ctx.engine, &program, &weights, c,
                                     batch, seq_len, ctx.max_batches)?;
            base.push(r.ppl);
        }
        rows.push(row_value(size, "original", 0.0, &base));
        out.row(render_row(size, "original", 0.0, &base));
        let combos: Vec<(usize, f64)> = (0..plans.len())
            .flat_map(|p| ratios.iter().map(move |&r| (p, r)))
            .collect();
        // compress in pool-width waves: full parallel speedup but only
        // one wave of compressed Weights alive at a time (the whole grid
        // at once would scale peak memory with plans×ratios)
        let wave = Pool::global().threads().max(1);
        for chunk in combos.chunks(wave) {
            let compressed = Pool::global().run(chunk.len(), |ci| {
                let (pi, ratio) = chunk[ci];
                let p = plans[pi].clone().with_ratio(ratio)
                    .with_iters(qk_iters, ud_iters);
                plan::compress_plan(cfg, &weights, &cal, &p)
            });
            for ((pi, ratio), res) in chunk.iter().zip(compressed) {
                let label = plans[*pi].display_label();
                let (nw, _rep) = res.with_context(
                    || format!("compress {size} {label}@{ratio}"))?;
                let mut ppls = vec![];
                for c in &corp {
                    let r = eval::perplexity(ctx.engine, &program, &nw, c,
                                             batch, seq_len,
                                             ctx.max_batches)?;
                    ppls.push(r.ppl);
                }
                rows.push(row_value(size, label, *ratio, &ppls));
                out.row(render_row(size, label, *ratio, &ppls));
            }
        }
    }
    println!("{}", out.render());
    Ok(Value::obj(vec![("table", "table2".into()),
                       ("rows", Value::Arr(rows))]))
}

fn score_dims(engine: &Engine) -> (usize, usize) {
    let b = engine.manifest().get("score_batch")
        .and_then(|v| v.as_usize()).unwrap_or(8);
    let t = engine.manifest().get("seq_len")
        .and_then(|v| v.as_usize()).unwrap_or(128);
    (b, t)
}

fn row_value(model: &str, method: &str, ratio: f64, ppls: &[f64]) -> Value {
    Value::obj(vec![
        ("model", model.into()), ("method", method.into()),
        ("ratio", ratio.into()),
        ("ppl", ppls.to_vec().into()),
    ])
}

fn render_row(model: &str, method: &str, ratio: f64, ppls: &[f64])
              -> Vec<String> {
    let mut r = vec![model.to_string(), method.to_string(),
                     format!("{:.0}%", ratio * 100.0)];
    r.extend(ppls.iter().map(|p| format!("{p:.2}")));
    r
}

/// Table 3: analytic FLOPs/MACs/params for OPT-6.7B (exact reproduction)
/// plus the mini family, 0–90%.
pub fn table3() -> Value {
    let mut out = TextTable::new(&["model", "compression", "FLOPs", "MACs",
                                   "Parameters"]);
    let mut rows = Vec::new();
    let cfg = OPT_FAMILY.iter().find(|c| c.name == "OPT-6.7B").unwrap();
    for i in 0..10 {
        let ratio = i as f64 * 0.1;
        let c = flops::complexity(cfg, 128, ratio, false);
        out.row(vec![cfg.name.into(), format!("{:.0}%", ratio * 100.0),
                     flops::human_g(c.flops), flops::human_g(c.macs),
                     flops::human(c.params)]);
        rows.push(Value::obj(vec![
            ("model", cfg.name.into()), ("ratio", ratio.into()),
            ("flops", c.flops.into()), ("macs", c.macs.into()),
            ("params", c.params.into())]));
    }
    println!("{}", out.render());
    Value::obj(vec![("table", "table3".into()), ("rows", Value::Arr(rows))])
}

/// Fig 4 (ppl vs ratio, wide sweep) — reuses the Table 2 machinery.
pub fn fig4(ctx: &TableCtx, sizes: &[&str], plans: &[CompressionPlan])
            -> Result<Value> {
    let ratios: Vec<f64> = (1..=7).map(|i| i as f64 * 0.1).collect();
    let v = table2(ctx, sizes, &ratios, plans)?;
    Ok(Value::obj(vec![("figure", "fig4".into()),
                       ("data", v)]))
}

/// Fig 5: ppl vs FLOPs — maps the fig4 sweep onto the analytic FLOPs of
/// the corresponding real OPT configs (paper plots 125M..13B).
pub fn fig5(ctx: &TableCtx, sizes: &[&str]) -> Result<Value> {
    let ratios = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5];
    let (batch, seq_len) = score_dims(ctx.engine);
    let corp = Corpus::load(ctx.artifacts.join("corpora.ltw"), "synthwiki",
                            "test")?;
    let mut series = Vec::new();
    for size in sizes {
        let cfg = mini_by_name(size).context("size")?;
        let (weights, cal) = load_model(ctx, cfg)?;
        let program = format!("score_{}", cfg.name);
        let mini_linear = cfg.linear_params() as f64;
        let (mut xs, mut ys) = (vec![], vec![]);
        for &ratio in &ratios {
            let w = if ratio == 0.0 {
                weights.clone()
            } else {
                let p = Method::LatentLlm.plan().with_ratio(ratio)
                    .with_iters(ctx.qk_iters, ctx.ud_iters);
                plan::compress_plan(cfg, &weights, &cal, &p)?.0
            };
            let r = eval::perplexity(ctx.engine, &program, &w, &corp,
                                     batch, seq_len, ctx.max_batches)?;
            // x-axis: per-token MACs of this mini model at the ratio
            let macs = (1.0 - ratio) * mini_linear
                + (cfg.vocab * cfg.d) as f64;
            xs.push(macs * seq_len as f64 * 2.0); // FLOPs per sequence
            ys.push(r.ppl);
        }
        series.push(Value::obj(vec![
            ("name", (*size).into()), ("x", xs.into()), ("y", ys.into())]));
    }
    Ok(Value::obj(vec![("figure", "fig5".into()),
                       ("series", Value::Arr(series))]))
}

/// Table 4 + Fig 6: multimodal accuracy breakdown of llava-mini under each
/// method × ratio (paper: LLaVa on ScienceQA at 10–50%).
/// The llava-mini compression runs in python at artifact time for the
/// headline table; here we *evaluate* rust-compressed LM towers as well —
/// compressing both towers in rust requires the mm pipeline, which reuses
/// the per-tower MiniConfig path.
pub fn table4(ctx: &TableCtx, ratios: &[f64], plans: &[CompressionPlan])
              -> Result<Value> {
    use crate::model::io::read_ltw;
    let data = read_ltw(ctx.artifacts.join("mm_data.ltw"))?;
    let weights = Weights::load(ctx.artifacts.join("mm_model.ltw"))?;
    let calib = read_ltw(ctx.artifacts.join("mm_calib.ltw"))?;
    let mm_batch = ctx.engine.manifest().get("mm_batch")
        .and_then(|v| v.as_usize()).unwrap_or(16);
    let program = "mm_score_llava-mini";

    // tower configs from the manifest
    let man = ctx.engine.manifest();
    let lm_cfg = mini_from_manifest(man.path(&["mm", "config", "lm"])
        .context("mm lm config")?)?;
    let vit_cfg = vit_from_manifest(man.path(&["mm", "config", "vision"])
        .context("mm vision config")?)?;

    let mut out = TextTable::new(&["method", "compression", "NAT", "SOC",
                                   "LAN", "TXT", "IMG", "NO", "G1-6",
                                   "G7-12", "Avg"]);
    let mut rows = Vec::new();
    let base = eval::evaluate_mm(ctx.engine, program, &weights, &data,
                                 mm_batch)?;
    push_mm_row(&mut out, &mut rows, "Original un-compressed", 0.0, &base);

    for &ratio in ratios {
        for base_plan in plans {
            let p = base_plan.clone().with_ratio(ratio)
                .with_iters(ctx.qk_iters, ctx.ud_iters);
            let mut nw = weights.clone();
            for (tower, cfg) in [("vit", &vit_cfg), ("lm", &lm_cfg)] {
                let sub = tower_weights(&weights, tower)?;
                let cal = CalibSet::from_map(&calib,
                                             &format!("{tower}."),
                                             cfg.n_layers)?;
                let (cw, _) = plan::compress_plan(cfg, &sub, &cal, &p)?;
                for name in cw.names() {
                    nw.set_tensor(&format!("{tower}.{name}"),
                                  cw.tensor(name)?.clone());
                }
            }
            let r = eval::evaluate_mm(ctx.engine, program, &nw, &data,
                                      mm_batch)?;
            push_mm_row(&mut out, &mut rows, base_plan.display_label(),
                        ratio, &r);
        }
    }
    println!("{}", out.render());
    Ok(Value::obj(vec![("table", "table4".into()),
                       ("rows", Value::Arr(rows))]))
}

fn tower_weights(w: &Weights, tower: &str) -> Result<Weights> {
    let mut map = crate::model::io::TensorMap::new();
    let prefix = format!("{tower}.");
    for name in w.names() {
        if let Some(rest) = name.strip_prefix(&prefix) {
            map.insert(rest.to_string(), w.tensor(name)?.clone());
        }
    }
    Ok(Weights::new(map))
}

fn push_mm_row(out: &mut TextTable, rows: &mut Vec<Value>, label: &str,
               ratio: f64, r: &eval::MmBreakdown) {
    let mut cells = vec![label.to_string(),
                         format!("{:.0}%", ratio * 100.0)];
    cells.extend(r.row().iter().map(|v| format!("{:.2}", v * 100.0)));
    out.row(cells);
    rows.push(Value::obj(vec![
        ("method", label.into()), ("ratio", ratio.into()),
        ("acc", r.row().into())]));
}

fn mini_from_manifest(v: &Value) -> Result<MiniConfig> {
    let g = |k: &str| -> Result<usize> {
        v.get(k).and_then(|x| x.as_usize())
            .context(format!("mm config field {k}"))
    };
    Ok(MiniConfig {
        name: "llava-mini-lm",
        vocab: g("vocab")?,
        d: g("d")?,
        n_layers: g("n_layers")?,
        n_heads: g("n_heads")?,
        d_i: g("d_i")?,
        max_len: g("max_len")?,
    })
}

fn vit_from_manifest(v: &Value) -> Result<MiniConfig> {
    let g = |k: &str| -> Result<usize> {
        v.get(k).and_then(|x| x.as_usize())
            .context(format!("vit config field {k}"))
    };
    Ok(MiniConfig {
        name: "llava-mini-vit",
        vocab: 1,
        d: g("d")?,
        n_layers: g("n_layers")?,
        n_heads: g("n_heads")?,
        d_i: g("d_i")?,
        max_len: 16,
    })
}

/// Run every artifact-dependent report; used by `latentllm report all`.
pub fn run_all(ctx: &TableCtx, out_dir: &std::path::Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let save = |name: &str, v: &Value| -> Result<()> {
        std::fs::write(out_dir.join(format!("{name}.json")),
                       v.to_string_pretty())?;
        Ok(())
    };
    println!("=== Table 3 (analytic; exact paper anchor) ===");
    save("table3", &table3())?;
    println!("=== Table 2 (perplexity grid) ===");
    let t2 = table2(ctx, &["opt-mini-s", "opt-mini-m", "opt-mini-l"],
                    &[0.1, 0.2, 0.3, 0.4], &pipeline::table2_plans())?;
    save("table2", &t2)?;
    println!("=== Fig 4 (ppl vs ratio, latentllm + rootcov) ===");
    let f4 = fig4(ctx, &["opt-mini-m"],
                  &[Method::AsvdRootCov.plan(), Method::LatentLlm.plan()])?;
    save("fig4", &f4)?;
    println!("=== Fig 5 (ppl vs FLOPs) ===");
    let f5 = fig5(ctx, &["opt-mini-s", "opt-mini-m", "opt-mini-l"])?;
    save("fig5", &f5)?;
    println!("=== Table 4 / Fig 6 (multimodal) ===");
    // llava-mini is overparameterized for the synthetic task, so the
    // capacity-binding regime (where the paper's degradation ordering
    // appears) sits at deeper ratios than the paper's 10-50% — sweep
    // through the transition (see EXPERIMENTS.md).
    let t4 = table4(ctx, &[0.3, 0.6, 0.8, 0.9, 0.95],
                    &[Method::Plain.plan(), Method::AsvdRootCov.plan(),
                      Method::LatentLlm.plan()])?;
    save("table4", &t4)?;
    Ok(())
}

#[allow(unused)]
pub fn ratios_default() -> Vec<f64> {
    vec![0.1, 0.2, 0.3, 0.4]
}
