//! Pluggable execution backend for the artifact programs.
//!
//! A [`Backend`] turns a manifest program name into an [`Executable`];
//! [`crate::runtime::Engine`] owns one backend plus the compile cache and
//! stays agnostic of *how* a program runs. Two implementations exist:
//!
//! * [`crate::runtime::RefBackend`] — pure-rust interpreter over the
//!   [`crate::tensor`] substrate (default; always available, offline);
//! * `PjrtBackend` (`--features pjrt`) — loads the AOT-compiled HLO text
//!   through the `xla` crate and executes on the CPU PJRT client.

use std::path::Path;

use anyhow::Result;

use super::literal::ParamValue;
use crate::model::Weights;
use crate::util::json::Value;

/// Everything a backend needs to materialize one program.
pub struct ProgramCtx<'a> {
    /// manifest program name (e.g. `score_opt-mini-m`, `step_opt-mini-m`,
    /// `latent_score_<tag>`, `mm_score_llava-mini`)
    pub name: &'a str,
    /// artifacts directory (HLO files live here for the PJRT backend)
    pub artifacts: &'a Path,
    /// parsed artifacts manifest (program table, model configs)
    pub manifest: &'a Value,
    /// manifest-declared parameter names, in call order
    pub param_order: &'a [String],
}

/// A compiled/loaded program ready to execute. `weight_order` is the
/// manifest parameter order *minus* the leading inputs, so the backend can
/// marshal weights positionally (PJRT) or look them up by name (reference).
pub trait Executable {
    fn execute(&self, leading: &[ParamValue], weights: &Weights,
               weight_order: &[String]) -> Result<Vec<f32>>;
}

/// Compiles manifest programs into executables.
pub trait Backend {
    /// Stable short name for logs/metrics ("ref", "pjrt").
    fn name(&self) -> &'static str;

    fn compile(&self, ctx: &ProgramCtx) -> Result<Box<dyn Executable>>;
}
