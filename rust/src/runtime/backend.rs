//! Pluggable execution backend for the artifact programs.
//!
//! A [`Backend`] turns a manifest program name into an [`Executable`];
//! [`crate::runtime::Engine`] owns one backend plus the compile cache and
//! stays agnostic of *how* a program runs. Two implementations exist:
//!
//! * [`crate::runtime::RefBackend`] — pure-rust interpreter over the
//!   [`crate::tensor`] substrate (default; always available, offline);
//! * `PjrtBackend` (`--features pjrt`) — loads the AOT-compiled HLO text
//!   through the `xla` crate and executes on the CPU PJRT client.

use std::path::Path;

use anyhow::{bail, Result};

use super::decode::{CacheKind, PrefixSnapshot};
use super::literal::ParamValue;
use crate::model::Weights;
use crate::util::json::Value;

/// Everything a backend needs to materialize one program.
pub struct ProgramCtx<'a> {
    /// manifest program name (e.g. `score_opt-mini-m`, `step_opt-mini-m`,
    /// `latent_score_<tag>`, `mm_score_llava-mini`)
    pub name: &'a str,
    /// artifacts directory (HLO files live here for the PJRT backend)
    pub artifacts: &'a Path,
    /// parsed artifacts manifest (program table, model configs)
    pub manifest: &'a Value,
    /// manifest-declared parameter names, in call order
    pub param_order: &'a [String],
}

/// A compiled/loaded program ready to execute. `weight_order` is the
/// manifest parameter order *minus* the leading inputs, so the backend can
/// marshal weights positionally (PJRT) or look them up by name (reference).
pub trait Executable {
    fn execute(&self, leading: &[ParamValue], weights: &Weights,
               weight_order: &[String]) -> Result<Vec<f32>>;

    /// Open a stateful incremental-decode session over this program's
    /// model with the given weights. Meaningful for the decode families
    /// (`step_*`, `latent_step_*`); backends without an incremental path
    /// keep this default error and callers fall back to the full-window
    /// recompute loop.
    fn open_session(&self, _weights: &Weights)
                    -> Result<Box<dyn DecodeSession>> {
        bail!("this backend does not support incremental decode sessions")
    }
}

/// A stateful autoregressive decode over one sequence: prefill the prompt
/// once, then extend one token at a time against per-layer cache tensors
/// ([`crate::runtime::decode::DecodeState`]). Each step is O(d·T) — prior
/// tokens' K/V (dense) or latents (MLA) are read from the cache, never
/// recomputed — versus the O(T²)-per-token full-window re-execution.
///
/// Sessions are single-sequence and not required to be `Send` (the PJRT
/// client is `Rc`-based); server workers create and drive them on their
/// own thread.
pub trait DecodeSession {
    /// Feed the whole prompt through every layer, populating the caches.
    /// Returns the next-token logits ([vocab]). Errors on an empty
    /// prompt, a second prefill, or a prompt longer than the model's
    /// positional table.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Append one token and return the next-token logits ([vocab]).
    /// Errors before prefill or past the positional table (incremental
    /// decode is windowless — it extends absolute positions rather than
    /// sliding, so the table bounds the session length).
    fn step(&mut self, token: i32) -> Result<Vec<f32>>;

    /// Append `tokens` in order, returning the next-token logits after
    /// *each* of them (`tokens.len()` rows of [vocab]). Semantically —
    /// and by default literally — repeated [`DecodeSession::step`];
    /// backends override it with one multi-row forward per call (the
    /// scheduler's prefill chunks and batched iterations), which stays
    /// bit-identical because every row's arithmetic depends only on the
    /// cache contents at positions before it. An empty slice is a no-op.
    fn step_many(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        tokens.iter().map(|&t| self.step(t)).collect()
    }

    /// [`DecodeSession::step`] writing the logits into a caller-owned
    /// buffer (cleared and refilled) instead of a fresh `Vec` — the hot
    /// loop's twin, so a scheduler that recycles per-sequence buffers
    /// stops paying one allocation per decoded token. Identical results
    /// and errors to `step` by construction.
    fn step_into(&mut self, token: i32, out: &mut Vec<f32>) -> Result<()> {
        *out = self.step(token)?;
        Ok(())
    }

    /// Opt-in seam for backend-level *fused* multi-session stepping: a
    /// backend whose sessions can share one weight-side pass across live
    /// sequences returns `Some(self)` so
    /// [`crate::runtime::decode::BatchedDecodeState`] can downcast the
    /// batch and hand it to that backend's fused kernel. The default opts
    /// out and callers keep the per-session loop.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }

    /// Tokens currently held in the caches.
    fn cached_tokens(&self) -> usize;

    /// Hard capacity of this session in tokens (the model's positional
    /// table): prefill + steps whose cached positions would exceed it
    /// error. Callers reject `prompt + max_new - 1 > max_tokens()`
    /// up front instead of paying a prefill that must fail mid-decode.
    fn max_tokens(&self) -> usize;

    /// Footprint descriptor for admission accounting (layer-0 ranks when
    /// latent ranks vary per layer; [`DecodeSession::cache_elements`] is
    /// exact).
    fn cache_kind(&self) -> CacheKind;

    /// Attention layers holding cache state.
    fn n_layers(&self) -> usize;

    /// Exact cached floats across all layers.
    fn cache_elements(&self) -> usize;

    /// Copy out the first `tokens` cache rows of every layer so the
    /// prefix cache can serve them to a later identical prompt. Backends
    /// whose cache tensors live off-host keep the default error; the
    /// scheduler then simply never donates from their sessions.
    fn export_prefix(&self, _tokens: usize) -> Result<PrefixSnapshot> {
        bail!("this backend does not export prefix cache blocks")
    }

    /// Seed a *fresh* session (no prefill yet) from a cached prefix, so
    /// the first feed continues at position `prefix.tokens`. Backends
    /// keep the default error to opt out; callers fall back to a cold
    /// full prefill.
    fn adopt_prefix(&mut self, _prefix: &PrefixSnapshot) -> Result<()> {
        bail!("this backend does not adopt prefix cache blocks")
    }
}

/// Compiles manifest programs into executables.
pub trait Backend {
    /// Stable short name for logs/metrics ("ref", "pjrt").
    fn name(&self) -> &'static str;

    fn compile(&self, ctx: &ProgramCtx) -> Result<Box<dyn Executable>>;
}
