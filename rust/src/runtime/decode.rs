//! Per-layer decode cache state — the tensors behind an incremental
//! [`crate::runtime::backend::DecodeSession`].
//!
//! The paper's serving benefit (ii) made real: a dense MHA layer caches
//! the projected K/V rows (`2·d` floats per token per layer) while a
//! latent MLA layer caches only the compressed latent vectors (`r_k +
//! r_v` floats per token per layer). The coordinator's
//! [`crate::coordinator::kvcache::KvCacheManager`] budgets admission
//! against exactly these footprints ([`CacheKind`] lives here so the
//! runtime that *holds* the state and the coordinator that *accounts* it
//! agree by construction).

use anyhow::{anyhow, bail, ensure, Result};
use std::sync::Arc;

use super::backend::DecodeSession;
use super::profile;
use crate::Matrix;

/// Cache-footprint descriptor for one model variant's attention layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// dense MHA: 2·d per token per layer
    Dense { d: usize },
    /// MLA: r_k + r_v per token per layer
    Latent { rk: usize, rv: usize },
}

impl CacheKind {
    pub fn bytes_per_token_layer(&self, bytes_per_el: usize) -> usize {
        self.elements_per_token() * bytes_per_el
    }

    /// Cached floats per token per layer (the paper's footprint).
    pub fn elements_per_token(&self) -> usize {
        match self {
            CacheKind::Dense { d } => 2 * d,
            CacheKind::Latent { rk, rv } => rk + rv,
        }
    }
}

/// One attention layer's cache tensors, one row per cached token.
#[derive(Clone, PartialEq)]
pub enum LayerCache {
    /// projected K/V rows: `k`/`v` are [t, d]
    Dense { k: Matrix, v: Matrix },
    /// compressed latents: `ck` is [t, r_k], `cv` is [t, r_v] — the
    /// decompressors stay in the weights, never in the cache
    Latent { ck: Matrix, cv: Matrix },
}

impl LayerCache {
    pub fn dense(d: usize) -> LayerCache {
        LayerCache::Dense { k: Matrix::zeros(0, d), v: Matrix::zeros(0, d) }
    }

    pub fn latent(rk: usize, rv: usize) -> LayerCache {
        LayerCache::Latent {
            ck: Matrix::zeros(0, rk),
            cv: Matrix::zeros(0, rv),
        }
    }

    /// Tokens currently cached in this layer.
    pub fn tokens(&self) -> usize {
        match self {
            LayerCache::Dense { k, .. } => k.rows(),
            LayerCache::Latent { ck, .. } => ck.rows(),
        }
    }

    /// Cached floats per token (2·d dense, r_k + r_v latent).
    pub fn elements_per_token(&self) -> usize {
        match self {
            LayerCache::Dense { k, v } => k.cols() + v.cols(),
            LayerCache::Latent { ck, cv } => ck.cols() + cv.cols(),
        }
    }

    /// Copy out the cache rows for token positions `[t0, t1)`.
    pub fn slice_tokens(&self, t0: usize, t1: usize) -> LayerCache {
        match self {
            LayerCache::Dense { k, v } => LayerCache::Dense {
                k: k.slice_rows(t0, t1),
                v: v.slice_rows(t0, t1),
            },
            LayerCache::Latent { ck, cv } => LayerCache::Latent {
                ck: ck.slice_rows(t0, t1),
                cv: cv.slice_rows(t0, t1),
            },
        }
    }

    /// Append `other`'s rows to this layer's cache. Variant and widths
    /// must agree (a dense layer can't adopt latent rows and vice versa).
    pub fn append(&mut self, other: &LayerCache) -> Result<()> {
        match (self, other) {
            (LayerCache::Dense { k, v }, LayerCache::Dense { k: ok, v: ov }) => {
                ensure!(k.cols() == ok.cols() && v.cols() == ov.cols(),
                        "dense cache width mismatch: [{}, {}] vs [{}, {}]",
                        k.cols(), v.cols(), ok.cols(), ov.cols());
                k.push_rows(ok);
                v.push_rows(ov);
                Ok(())
            }
            (LayerCache::Latent { ck, cv },
             LayerCache::Latent { ck: ok, cv: ov }) => {
                ensure!(ck.cols() == ok.cols() && cv.cols() == ov.cols(),
                        "latent cache rank mismatch: [{}, {}] vs [{}, {}]",
                        ck.cols(), cv.cols(), ok.cols(), ov.cols());
                ck.push_rows(ok);
                cv.push_rows(ov);
                Ok(())
            }
            _ => bail!("cache kind mismatch: dense layer vs latent rows"),
        }
    }
}

/// An immutable copy of the first `tokens` cache rows of every layer —
/// the unit the prefix cache stores per block and the payload a fresh
/// session adopts instead of re-running prefill. Rows are exactly what
/// the donor's forward pass produced, so adoption is token-identical to
/// recomputation by construction (causal rows depend only on the rows
/// before them).
#[derive(Clone, PartialEq)]
pub struct PrefixSnapshot {
    pub tokens: usize,
    pub layers: Vec<LayerCache>,
}

impl PrefixSnapshot {
    /// Copy out token positions `[t0, t1)` of every layer (used to split
    /// a donated prefix into per-block cache entries).
    pub fn slice_tokens(&self, t0: usize, t1: usize) -> PrefixSnapshot {
        PrefixSnapshot {
            tokens: t1 - t0,
            layers: self.layers.iter().map(|l| l.slice_tokens(t0, t1)).collect(),
        }
    }

    /// Stitch per-block snapshots back into one contiguous prefix, in
    /// order. Every part must have the same layer structure.
    pub fn concat(parts: &[Arc<PrefixSnapshot>]) -> Result<PrefixSnapshot> {
        let first = parts.first()
            .ok_or_else(|| anyhow!("prefix concat: no blocks"))?;
        let mut out = PrefixSnapshot {
            tokens: first.tokens,
            layers: first.layers.clone(),
        };
        for p in &parts[1..] {
            ensure!(p.layers.len() == out.layers.len(),
                    "prefix concat: {} layers vs {}",
                    p.layers.len(), out.layers.len());
            for (mine, theirs) in out.layers.iter_mut().zip(&p.layers) {
                mine.append(theirs)?;
            }
            out.tokens += p.tokens;
        }
        Ok(out)
    }

    /// Total floats held (all layers).
    pub fn cache_elements(&self) -> usize {
        self.layers.iter()
            .map(|l| l.tokens() * l.elements_per_token())
            .sum()
    }
}

/// Whole-model decode state: one [`LayerCache`] per attention layer plus
/// the absolute token position (which indexes the positional table).
pub struct DecodeState {
    pub layers: Vec<LayerCache>,
    tokens: usize,
}

impl DecodeState {
    pub fn new(layers: Vec<LayerCache>) -> DecodeState {
        DecodeState { layers, tokens: 0 }
    }

    /// Tokens fed through prefill + step so far (the next token's
    /// absolute position).
    pub fn cached_tokens(&self) -> usize {
        self.tokens
    }

    /// Record that `n` more tokens were appended to every layer cache.
    pub fn advance(&mut self, n: usize) {
        self.tokens += n;
    }

    /// Total cached floats across all layers (exact, even when latent
    /// ranks differ per layer).
    pub fn cache_elements(&self) -> usize {
        self.layers.iter()
            .map(|l| l.tokens() * l.elements_per_token())
            .sum()
    }

    /// Seed an *empty* state from a cached prefix: append the snapshot's
    /// rows to every layer and advance the position past them. The next
    /// fed token then continues at position `snap.tokens`, exactly as if
    /// those tokens had been prefilled here.
    pub fn adopt_prefix(&mut self, snap: &PrefixSnapshot) -> Result<()> {
        ensure!(self.tokens == 0,
                "adopt_prefix: session already holds {} tokens", self.tokens);
        ensure!(snap.layers.len() == self.layers.len(),
                "adopt_prefix: prefix has {} layers, session has {}",
                snap.layers.len(), self.layers.len());
        for (mine, theirs) in self.layers.iter_mut().zip(&snap.layers) {
            ensure!(theirs.tokens() == snap.tokens,
                    "adopt_prefix: layer holds {} tokens, snapshot says {}",
                    theirs.tokens(), snap.tokens);
            mine.append(theirs)?;
        }
        self.tokens = snap.tokens;
        Ok(())
    }
}

/// Multi-sequence decode state: the live session set one scheduler
/// iteration steps as a single mixed batch. Slots are stable small
/// integers (freed slots are reused lowest-first) so the coordinator can
/// refer to a sequence across iterations without holding the session.
///
/// [`BatchedDecodeState::step_many`] is the backend fusion seam: when
/// every stepped slot is a reference-backend session over the *same*
/// loaded model, the batch runs as ONE fused forward — each layer's
/// weight-side GEMMs once over all N stacked token rows, only the
/// attention cache phase fanned out per sequence
/// ([`crate::runtime::refbackend`]'s fused step). Otherwise — mixed
/// models, foreign backends, un-prefilled slots, or the kill switch
/// ([`BatchedDecodeState::set_fused`]) — it drives each named slot's
/// [`DecodeSession::step`] in the caller's order. Both paths are
/// bit-identical *by construction* (each session owns its own cache
/// tensors; no cross-sequence state exists, and every weight-side kernel
/// computes rows independently in a fixed k-order).
///
/// Not `Send` (sessions may hold `Rc`-based backend clients): it lives
/// and dies on one worker thread, like the sessions themselves.
pub struct BatchedDecodeState {
    slots: Vec<Option<SeqSlot>>,
    /// kill switch: `false` forces the per-session fallback loop
    fused: bool,
    /// backend-owned scratch reused across fused iterations (opaque so
    /// this module stays backend-agnostic)
    workspace: Option<Box<dyn std::any::Any>>,
    fused_batches: u64,
    fused_rows: u64,
}

struct SeqSlot {
    seq: u64,
    session: Box<dyn DecodeSession>,
}

impl Default for BatchedDecodeState {
    fn default() -> BatchedDecodeState {
        BatchedDecodeState::new()
    }
}

impl BatchedDecodeState {
    pub fn new() -> BatchedDecodeState {
        BatchedDecodeState {
            slots: Vec::new(),
            fused: true,
            workspace: None,
            fused_batches: 0,
            fused_rows: 0,
        }
    }

    /// Toggle the fused step (`--no-fused-step` lands here). Off means
    /// every batch takes the per-session loop.
    pub fn set_fused(&mut self, on: bool) {
        self.fused = on;
    }

    /// Lifetime totals: `(fused batches, rows stepped fused)` — the
    /// scheduler diffs these across an iteration to feed its metrics.
    pub fn fused_stats(&self) -> (u64, u64) {
        (self.fused_batches, self.fused_rows)
    }

    /// Adopt a prepared session for sequence `seq`; returns its slot.
    pub fn insert(&mut self, seq: u64, session: Box<dyn DecodeSession>)
                  -> usize {
        let entry = SeqSlot { seq, session };
        match self.slots.iter().position(Option::is_none) {
            Some(i) => {
                self.slots[i] = Some(entry);
                i
            }
            None => {
                self.slots.push(Some(entry));
                self.slots.len() - 1
            }
        }
    }

    /// Adopt a session seeded from a cached prefix: the snapshot's rows
    /// are installed before the slot is handed out, so the scheduler's
    /// first feed starts at position `prefix.tokens` instead of 0. With
    /// `None` this is exactly [`BatchedDecodeState::insert`].
    pub fn insert_prefilled(&mut self, seq: u64,
                            mut session: Box<dyn DecodeSession>,
                            prefix: Option<&PrefixSnapshot>)
                            -> Result<usize> {
        if let Some(p) = prefix {
            session.adopt_prefix(p)?;
        }
        Ok(self.insert(seq, session))
    }

    /// Drop a slot (the session's cache tensors go with it — this IS
    /// preemption's memory release). Returns the sequence id it held.
    pub fn remove(&mut self, slot: usize) -> Option<u64> {
        self.slots.get_mut(slot)?.take().map(|e| e.seq)
    }

    pub fn seq(&self, slot: usize) -> Option<u64> {
        self.slots.get(slot)?.as_ref().map(|e| e.seq)
    }

    /// Direct session access (prefill chunks are fed outside the step
    /// batch).
    pub fn session_mut(&mut self, slot: usize)
                       -> Option<&mut dyn DecodeSession> {
        match self.slots.get_mut(slot)? {
            Some(e) => Some(e.session.as_mut()),
            None => None,
        }
    }

    /// One scheduler iteration's mixed batch: step each `(slot, token)`
    /// pair in order, returning that sequence's next-token logits in the
    /// same order. Failures are per-slot — one sequence erroring (or a
    /// stale slot id) must not poison its batch-mates. Thin wrapper over
    /// [`BatchedDecodeState::step_many_into`] for callers without
    /// recyclable buffers.
    pub fn step_many(&mut self, steps: &[(usize, i32)])
                     -> Vec<Result<Vec<f32>>> {
        let mut outs = vec![Vec::new(); steps.len()];
        let res = self.step_many_into(steps, &mut outs);
        res.into_iter()
            .zip(outs)
            .map(|(r, o)| r.map(|()| o))
            .collect()
    }

    /// [`BatchedDecodeState::step_many`] with caller-owned logits
    /// buffers (one per step, cleared and refilled): the scheduler
    /// recycles each sequence's previous logits vec here, so
    /// steady-state decoding allocates nothing per token. Tries the
    /// fused one-GEMM-pass-per-layer step first; falls back to the
    /// per-session loop whenever the batch cannot fuse (which also
    /// keeps all error reporting on the unfused path).
    pub fn step_many_into(&mut self, steps: &[(usize, i32)],
                          outs: &mut [Vec<f32>]) -> Vec<Result<()>> {
        assert_eq!(steps.len(), outs.len(),
                   "step_many_into: {} steps, {} buffers",
                   steps.len(), outs.len());
        let t0 = profile::phase_start();
        if self.fused && self.try_fused(steps, outs).is_some() {
            self.fused_batches += 1;
            self.fused_rows += steps.len() as u64;
            profile::step_path(true, steps.len(), t0);
            return steps.iter().map(|_| Ok(())).collect();
        }
        let res: Vec<Result<()>> = steps.iter()
            .zip(outs.iter_mut())
            .map(|(&(slot, token), out)| match self.session_mut(slot) {
                Some(s) => s.step_into(token, out),
                None => Err(anyhow!("batched decode: slot {slot} is empty")),
            })
            .collect();
        profile::step_path(false, steps.len(), t0);
        res
    }

    /// Collect distinct live sessions for `steps` and hand them to the
    /// backend's fused kernel. `None` (nothing mutated) when any slot is
    /// empty or repeated, the batch is trivially small, or the backend
    /// declines (mixed models, un-prefilled, at capacity).
    fn try_fused(&mut self, steps: &[(usize, i32)],
                 outs: &mut [Vec<f32>]) -> Option<()> {
        if steps.len() < 2 {
            return None;
        }
        // taking each slot's &mut out of a side table enforces
        // distinctness: a repeated slot would double-append to one cache
        let mut by_slot: Vec<Option<&mut dyn DecodeSession>> = self.slots
            .iter_mut()
            .map(|s| s.as_mut()
                .map(|e| e.session.as_mut() as &mut dyn DecodeSession))
            .collect();
        let mut sessions: Vec<&mut dyn DecodeSession> =
            Vec::with_capacity(steps.len());
        for &(slot, _) in steps {
            sessions.push(by_slot.get_mut(slot)?.take()?);
        }
        let tokens: Vec<i32> = steps.iter().map(|&(_, t)| t).collect();
        super::refbackend::fused_step_sessions(
            &mut sessions, &tokens, outs, &mut self.workspace)
    }

    /// Live sequences.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(Option::is_none)
    }

    /// Exact cached floats across every live session.
    pub fn cache_elements(&self) -> usize {
        self.slots.iter().flatten().map(|e| e.session.cache_elements()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprints_match_the_paper() {
        // benefit (ii): dense caches 2d, latent caches rk+rv per
        // token-layer — the latent/dense ratio IS (rk+rv)/(2d).
        assert_eq!(CacheKind::Dense { d: 128 }.elements_per_token(), 256);
        assert_eq!(CacheKind::Latent { rk: 16, rv: 16 }.elements_per_token(),
                   32);
        assert_eq!(CacheKind::Dense { d: 128 }.bytes_per_token_layer(2), 512);
    }

    #[test]
    fn state_tracks_growth() {
        let mut st = DecodeState::new(vec![
            LayerCache::dense(8),
            LayerCache::latent(3, 2),
        ]);
        assert_eq!(st.cached_tokens(), 0);
        assert_eq!(st.cache_elements(), 0);
        let grow = Matrix::zeros(4, 8);
        match &mut st.layers[0] {
            LayerCache::Dense { k, v } => {
                k.push_rows(&grow);
                v.push_rows(&grow);
            }
            _ => unreachable!(),
        }
        match &mut st.layers[1] {
            LayerCache::Latent { ck, cv } => {
                ck.push_rows(&Matrix::zeros(4, 3));
                cv.push_rows(&Matrix::zeros(4, 2));
            }
            _ => unreachable!(),
        }
        st.advance(4);
        assert_eq!(st.cached_tokens(), 4);
        // 4 tokens × (2·8 dense + (3+2) latent)
        assert_eq!(st.cache_elements(), 4 * (16 + 5));
    }

    /// Deterministic stand-in session: logits echo (id, fed token,
    /// position) so batched stepping is checkable without a model.
    struct StubSession {
        id: f32,
        fed: Vec<i32>,
    }

    impl DecodeSession for StubSession {
        fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
            self.fed.extend_from_slice(tokens);
            Ok(vec![self.id, 0.0, self.fed.len() as f32])
        }
        fn step(&mut self, token: i32) -> Result<Vec<f32>> {
            if self.fed.len() >= 8 {
                return Err(anyhow!("stub capacity"));
            }
            self.fed.push(token);
            Ok(vec![self.id, token as f32, self.fed.len() as f32])
        }
        fn cached_tokens(&self) -> usize {
            self.fed.len()
        }
        fn max_tokens(&self) -> usize {
            8
        }
        fn cache_kind(&self) -> CacheKind {
            CacheKind::Dense { d: 1 }
        }
        fn n_layers(&self) -> usize {
            1
        }
        fn cache_elements(&self) -> usize {
            2 * self.fed.len()
        }
    }

    fn stub(id: f32) -> Box<dyn DecodeSession> {
        Box::new(StubSession { id, fed: vec![] })
    }

    #[test]
    fn batched_state_slots_are_stable_and_reused() {
        let mut b = BatchedDecodeState::new();
        let s0 = b.insert(100, stub(0.0));
        let s1 = b.insert(101, stub(1.0));
        let s2 = b.insert(102, stub(2.0));
        assert_eq!((s0, s1, s2), (0, 1, 2));
        assert_eq!(b.len(), 3);
        assert_eq!(b.remove(s1), Some(101));
        assert_eq!(b.seq(s1), None);
        assert_eq!(b.seq(s2), Some(102), "later slots must not shift");
        // freed slot is reused lowest-first
        assert_eq!(b.insert(103, stub(3.0)), s1);
        assert_eq!(b.len(), 3);
        assert!(b.remove(99).is_none(), "out-of-range slot is None");
    }

    #[test]
    fn batched_step_many_is_per_slot_and_order_preserving() {
        let mut b = BatchedDecodeState::new();
        let a = b.insert(7, stub(7.0));
        let c = b.insert(9, stub(9.0));
        b.session_mut(a).unwrap().prefill(&[1, 2]).unwrap();
        b.session_mut(c).unwrap().prefill(&[3]).unwrap();
        // mixed batch: results come back in the caller's order, one per
        // (slot, token) pair, each from its own session's state
        let out = b.step_many(&[(c, 40), (a, 50)]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().unwrap(), &vec![9.0, 40.0, 2.0]);
        assert_eq!(out[1].as_ref().unwrap(), &vec![7.0, 50.0, 3.0]);
        assert_eq!(b.cache_elements(), 2 * (3 + 2));
        // a stale slot fails that entry alone, not its batch-mates
        b.remove(c);
        let out = b.step_many(&[(c, 1), (a, 60)]);
        assert!(out[0].is_err());
        assert_eq!(out[1].as_ref().unwrap(), &vec![7.0, 60.0, 4.0]);
    }

    fn numbered(rows: usize, cols: usize, base: f64) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| base + (r * cols + c) as f64)
    }

    #[test]
    fn prefix_snapshot_slices_and_concats_roundtrip() {
        let snap = PrefixSnapshot {
            tokens: 4,
            layers: vec![
                LayerCache::Dense { k: numbered(4, 3, 0.0),
                                    v: numbered(4, 3, 100.0) },
                LayerCache::Latent { ck: numbered(4, 2, 200.0),
                                     cv: numbered(4, 1, 300.0) },
            ],
        };
        // split into two 2-token blocks, then stitch back together
        let a = Arc::new(snap.slice_tokens(0, 2));
        let b = Arc::new(snap.slice_tokens(2, 4));
        assert_eq!(a.tokens, 2);
        assert_eq!(a.cache_elements(), 2 * (6 + 3));
        let whole = PrefixSnapshot::concat(&[a, b]).unwrap();
        assert_eq!(whole.tokens, 4);
        for (orig, got) in snap.layers.iter().zip(&whole.layers) {
            match (orig, got) {
                (LayerCache::Dense { k, v }, LayerCache::Dense { k: gk, v: gv }) => {
                    assert_eq!(k, gk);
                    assert_eq!(v, gv);
                }
                (LayerCache::Latent { ck, cv },
                 LayerCache::Latent { ck: gk, cv: gv }) => {
                    assert_eq!(ck, gk);
                    assert_eq!(cv, gv);
                }
                _ => panic!("layer kind changed in roundtrip"),
            }
        }
        assert!(PrefixSnapshot::concat(&[]).is_err());
    }

    #[test]
    fn adopt_prefix_seeds_empty_state_only() {
        let snap = PrefixSnapshot {
            tokens: 3,
            layers: vec![LayerCache::Dense { k: numbered(3, 2, 0.0),
                                             v: numbered(3, 2, 50.0) }],
        };
        let mut st = DecodeState::new(vec![LayerCache::dense(2)]);
        st.adopt_prefix(&snap).unwrap();
        assert_eq!(st.cached_tokens(), 3);
        assert_eq!(st.cache_elements(), 3 * 4);
        // adopted rows are bit-identical to the donor's
        match &st.layers[0] {
            LayerCache::Dense { k, .. } => assert_eq!(k.row(2)[1], 5.0),
            _ => unreachable!(),
        }
        // a second adoption (non-empty state) must refuse
        assert!(st.adopt_prefix(&snap).is_err());
        // kind mismatch refuses without panicking
        let mut lat = DecodeState::new(vec![LayerCache::latent(2, 2)]);
        assert!(lat.adopt_prefix(&snap).is_err());
        // width mismatch refuses too
        let mut wide = DecodeState::new(vec![LayerCache::dense(3)]);
        assert!(wide.adopt_prefix(&snap).is_err());
    }

    #[test]
    fn default_step_many_loops_step() {
        let mut s = StubSession { id: 5.0, fed: vec![] };
        s.prefill(&[1]).unwrap();
        let rows = s.step_many(&[10, 11, 12]).unwrap();
        assert_eq!(rows, vec![vec![5.0, 10.0, 2.0],
                              vec![5.0, 11.0, 3.0],
                              vec![5.0, 12.0, 4.0]]);
        assert!(s.step_many(&[]).unwrap().is_empty());
        // capacity error surfaces from the failing step
        s.step_many(&[0, 0, 0, 0]).unwrap();
        assert!(s.step_many(&[1]).is_err());
    }
}
