//! Per-layer decode cache state — the tensors behind an incremental
//! [`crate::runtime::backend::DecodeSession`].
//!
//! The paper's serving benefit (ii) made real: a dense MHA layer caches
//! the projected K/V rows (`2·d` floats per token per layer) while a
//! latent MLA layer caches only the compressed latent vectors (`r_k +
//! r_v` floats per token per layer). The coordinator's
//! [`crate::coordinator::kvcache::KvCacheManager`] budgets admission
//! against exactly these footprints ([`CacheKind`] lives here so the
//! runtime that *holds* the state and the coordinator that *accounts* it
//! agree by construction).

use crate::Matrix;

/// Cache-footprint descriptor for one model variant's attention layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// dense MHA: 2·d per token per layer
    Dense { d: usize },
    /// MLA: r_k + r_v per token per layer
    Latent { rk: usize, rv: usize },
}

impl CacheKind {
    pub fn bytes_per_token_layer(&self, bytes_per_el: usize) -> usize {
        self.elements_per_token() * bytes_per_el
    }

    /// Cached floats per token per layer (the paper's footprint).
    pub fn elements_per_token(&self) -> usize {
        match self {
            CacheKind::Dense { d } => 2 * d,
            CacheKind::Latent { rk, rv } => rk + rv,
        }
    }
}

/// One attention layer's cache tensors, one row per cached token.
pub enum LayerCache {
    /// projected K/V rows: `k`/`v` are [t, d]
    Dense { k: Matrix, v: Matrix },
    /// compressed latents: `ck` is [t, r_k], `cv` is [t, r_v] — the
    /// decompressors stay in the weights, never in the cache
    Latent { ck: Matrix, cv: Matrix },
}

impl LayerCache {
    pub fn dense(d: usize) -> LayerCache {
        LayerCache::Dense { k: Matrix::zeros(0, d), v: Matrix::zeros(0, d) }
    }

    pub fn latent(rk: usize, rv: usize) -> LayerCache {
        LayerCache::Latent {
            ck: Matrix::zeros(0, rk),
            cv: Matrix::zeros(0, rv),
        }
    }

    /// Tokens currently cached in this layer.
    pub fn tokens(&self) -> usize {
        match self {
            LayerCache::Dense { k, .. } => k.rows(),
            LayerCache::Latent { ck, .. } => ck.rows(),
        }
    }

    /// Cached floats per token (2·d dense, r_k + r_v latent).
    pub fn elements_per_token(&self) -> usize {
        match self {
            LayerCache::Dense { k, v } => k.cols() + v.cols(),
            LayerCache::Latent { ck, cv } => ck.cols() + cv.cols(),
        }
    }
}

/// Whole-model decode state: one [`LayerCache`] per attention layer plus
/// the absolute token position (which indexes the positional table).
pub struct DecodeState {
    pub layers: Vec<LayerCache>,
    tokens: usize,
}

impl DecodeState {
    pub fn new(layers: Vec<LayerCache>) -> DecodeState {
        DecodeState { layers, tokens: 0 }
    }

    /// Tokens fed through prefill + step so far (the next token's
    /// absolute position).
    pub fn cached_tokens(&self) -> usize {
        self.tokens
    }

    /// Record that `n` more tokens were appended to every layer cache.
    pub fn advance(&mut self, n: usize) {
        self.tokens += n;
    }

    /// Total cached floats across all layers (exact, even when latent
    /// ranks differ per layer).
    pub fn cache_elements(&self) -> usize {
        self.layers.iter()
            .map(|l| l.tokens() * l.elements_per_token())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprints_match_the_paper() {
        // benefit (ii): dense caches 2d, latent caches rk+rv per
        // token-layer — the latent/dense ratio IS (rk+rv)/(2d).
        assert_eq!(CacheKind::Dense { d: 128 }.elements_per_token(), 256);
        assert_eq!(CacheKind::Latent { rk: 16, rv: 16 }.elements_per_token(),
                   32);
        assert_eq!(CacheKind::Dense { d: 128 }.bytes_per_token_layer(2), 512);
    }

    #[test]
    fn state_tracks_growth() {
        let mut st = DecodeState::new(vec![
            LayerCache::dense(8),
            LayerCache::latent(3, 2),
        ]);
        assert_eq!(st.cached_tokens(), 0);
        assert_eq!(st.cache_elements(), 0);
        let grow = Matrix::zeros(4, 8);
        match &mut st.layers[0] {
            LayerCache::Dense { k, v } => {
                k.push_rows(&grow);
                v.push_rows(&grow);
            }
            _ => unreachable!(),
        }
        match &mut st.layers[1] {
            LayerCache::Latent { ck, cv } => {
                ck.push_rows(&Matrix::zeros(4, 3));
                cv.push_rows(&Matrix::zeros(4, 2));
            }
            _ => unreachable!(),
        }
        st.advance(4);
        assert_eq!(st.cached_tokens(), 4);
        // 4 tokens × (2·8 dense + (3+2) latent)
        assert_eq!(st.cache_elements(), 4 * (16 + 5));
    }
}
