//! The execution engine: manifest program name → [`Program`] through a
//! pluggable [`Backend`], with a per-name compile cache. The default
//! backend is the pure-rust [`RefBackend`]; builds with `--features pjrt`
//! can select the PJRT/HLO path via `LATENTLLM_BACKEND=pjrt`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{Backend, Executable, ProgramCtx};
use super::literal::ParamValue;
use super::refbackend::RefBackend;
use crate::model::io::Tensor;
use crate::model::Weights;
use crate::util::json::{self, Value};

/// A loaded program plus its parameter-order metadata.
pub struct Program {
    pub name: String,
    /// manifest-declared parameter names, in call order
    pub param_order: Vec<String>,
    exe: Box<dyn Executable>,
}

impl Program {
    /// Execute with explicit leading inputs (tokens, lens, images, …)
    /// followed by the weight tensors in manifest order. Returns the
    /// flattened f32 outputs.
    pub fn run_f32(&self, leading: &[ParamValue], weights: &Weights)
                   -> Result<Vec<f32>> {
        if leading.len() > self.param_order.len() {
            bail!("program {}: {} leading inputs exceed the {}-parameter \
                   signature", self.name, leading.len(),
                  self.param_order.len());
        }
        let weight_order = &self.param_order[leading.len()..];
        self.exe
            .execute(leading, weights, weight_order)
            .with_context(|| format!("execute program {}", self.name))
    }

    /// Open a stateful incremental-decode session (prefill once, then
    /// step token by token against per-layer KV/latent caches). Only the
    /// decode program families support this; score/multimodal programs
    /// and backends without an incremental path return an error — callers
    /// fall back to the full-window recompute loop.
    pub fn decode_session(&self, weights: &Weights)
                          -> Result<Box<dyn super::backend::DecodeSession>> {
        self.exe
            .open_session(weights)
            .with_context(|| format!("decode session for program {}",
                                     self.name))
    }
}

/// Engine with a compile cache keyed by program name, generic over the
/// execution [`Backend`].
pub struct Engine {
    backend: Box<dyn Backend>,
    artifacts: PathBuf,
    manifest: Value,
    cache: Mutex<HashMap<String, Arc<Program>>>,
}

/// Pick the backend for [`Engine::new`]: the reference interpreter unless
/// `LATENTLLM_BACKEND=pjrt` is set (which requires `--features pjrt`).
fn default_backend() -> Result<Box<dyn Backend>> {
    match std::env::var("LATENTLLM_BACKEND").as_deref() {
        Ok("pjrt") => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(super::pjrt::PjrtBackend::new()?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                bail!("LATENTLLM_BACKEND=pjrt but this binary was built \
                       without the `pjrt` feature (cargo build --features \
                       pjrt)")
            }
        }
        Ok("ref") | Ok("") | Err(_) => Ok(Box::new(RefBackend::new())),
        Ok(other) => bail!("unknown LATENTLLM_BACKEND {other:?} \
                            (expected \"ref\" or \"pjrt\")"),
    }
}

impl Engine {
    /// Engine over the default backend (see [`default_backend`]).
    pub fn new(artifacts: impl AsRef<Path>) -> Result<Self> {
        Engine::with_backend(artifacts, default_backend()?)
    }

    /// Engine over an explicit backend.
    pub fn with_backend(artifacts: impl AsRef<Path>,
                        backend: Box<dyn Backend>) -> Result<Self> {
        let artifacts = artifacts.as_ref().to_path_buf();
        let manifest_text =
            std::fs::read_to_string(artifacts.join("manifest.json"))
                .context("read manifest.json (run `make artifacts`)")?;
        let manifest = json::parse(&manifest_text)?;
        Ok(Engine {
            backend,
            artifacts,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn manifest(&self) -> &Value {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    /// Parameter order for a program from the manifest
    /// (`programs.<name>` is a list of names).
    fn param_order(&self, prog: &str) -> Result<Vec<String>> {
        let programs = self.manifest.get("programs")
            .ok_or_else(|| anyhow!("manifest missing programs"))?;
        let entry = programs.get(prog)
            .ok_or_else(|| anyhow!("manifest has no program {prog:?}"))?;
        let arr = entry.as_arr()
            .ok_or_else(|| anyhow!("program {prog:?} entry not a list"))?;
        arr.iter()
            .map(|v| v.as_str().map(String::from)
                .ok_or_else(|| anyhow!("bad param name")))
            .collect()
    }

    /// Load/compile (or fetch from cache) a program by name. Repeated
    /// calls return the same `Arc` — the compile cache the serving loop
    /// and the eval paths rely on. The cache lock is poison-tolerant
    /// ([`crate::util::lock_unpoisoned`]): engines are shared across
    /// server worker threads, and one worker panicking must not cascade
    /// a `PoisonError` unwrap through every sibling's compile-cache hit.
    pub fn program(&self, name: &str) -> Result<Arc<Program>> {
        if let Some(p) = crate::util::lock_unpoisoned(&self.cache).get(name) {
            return Ok(p.clone());
        }
        let param_order = self.param_order(name)?;
        let ctx = ProgramCtx {
            name,
            artifacts: &self.artifacts,
            manifest: &self.manifest,
            param_order: &param_order,
        };
        let exe = self.backend.compile(&ctx)
            .with_context(|| format!("backend {} compile {name:?}",
                                     self.backend.name()))?;
        let prog = Arc::new(Program {
            name: name.to_string(),
            param_order,
            exe,
        });
        crate::util::lock_unpoisoned(&self.cache)
            .insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Number of programs currently in the compile cache.
    pub fn cached_programs(&self) -> usize {
        crate::util::lock_unpoisoned(&self.cache).len()
    }

    /// Convenience: i32 leading input from a flat buffer.
    pub fn i32_input(shape: &[usize], data: Vec<i32>) -> ParamValue {
        ParamValue::I32 { shape: shape.to_vec(), data }
    }

    pub fn f32_input(shape: &[usize], data: Vec<f32>) -> ParamValue {
        ParamValue::F32 { shape: shape.to_vec(), data }
    }

    /// Leading-input count heuristic from manifest naming: entries that are
    /// not weight tensors ("tokens", "lens", "images").
    pub fn leading_count(order: &[String]) -> usize {
        order.iter()
            .take_while(|n| matches!(n.as_str(),
                                     "tokens" | "lens" | "images"))
            .count()
    }

    /// Weights view for a tensor map (helper for tests).
    pub fn weights_from_map(map: crate::model::io::TensorMap) -> Weights {
        Weights::new(map)
    }

    /// Batch-of-sequences helper: flatten Vec<Vec<i32>> into one i32 input.
    pub fn tokens_input(batch: &[Vec<i32>]) -> ParamValue {
        let b = batch.len();
        let t = batch.first().map(|s| s.len()).unwrap_or(0);
        let mut flat = Vec::with_capacity(b * t);
        for s in batch {
            assert_eq!(s.len(), t, "ragged batch");
            flat.extend_from_slice(s);
        }
        ParamValue::I32 { shape: vec![b, t], data: flat }
    }
}

/// Pure helper used by tests without an engine.
pub fn tensor_param(t: &Tensor) -> ParamValue {
    ParamValue::from_tensor(t)
}
