//! The PJRT engine: HLO-text → compile → execute, with a program cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::literal::ParamValue;
use crate::model::io::Tensor;
use crate::model::Weights;
use crate::util::json::{self, Value};

/// A compiled PJRT executable plus its parameter-order metadata.
pub struct Program {
    pub name: String,
    /// manifest-declared parameter names, in call order
    pub param_order: Vec<String>,
    exe: xla::PjRtLoadedExecutable,
}

impl Program {
    /// Execute with explicit leading inputs (tokens, lens, images, …)
    /// followed by the weight tensors in manifest order. Returns the
    /// flattened f32 outputs of the 1-tuple result.
    pub fn run_f32(&self, leading: &[ParamValue], weights: &Weights)
                   -> Result<Vec<f32>> {
        let lit = self.execute(leading, weights)?;
        let out = lit.to_tuple1().context("program output tuple")?;
        Ok(out.to_vec::<f32>()?)
    }

    fn execute(&self, leading: &[ParamValue], weights: &Weights)
               -> Result<xla::Literal> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(
            self.param_order.len());
        for p in leading {
            args.push(p.to_literal()?);
        }
        let weight_names = &self.param_order[leading.len()..];
        for name in weight_names {
            let t = weights.tensor(name)
                .with_context(|| format!("program {}", self.name))?;
            args.push(super::literal::tensor_to_literal(t)?);
        }
        let result = self.exe.execute::<xla::Literal>(&args)?;
        Ok(result[0][0].to_literal_sync()?)
    }
}

/// PJRT CPU engine with a compile cache keyed by program name.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    manifest: Value,
    cache: Mutex<HashMap<String, std::sync::Arc<Program>>>,
}

impl Engine {
    pub fn new(artifacts: impl AsRef<Path>) -> Result<Self> {
        let artifacts = artifacts.as_ref().to_path_buf();
        let manifest_text =
            std::fs::read_to_string(artifacts.join("manifest.json"))
                .context("read manifest.json (run `make artifacts`)")?;
        let manifest = json::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            artifacts,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Value {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    /// Parameter order for a program from the manifest
    /// (`programs.<name>.<kind>` is a list of names).
    fn param_order(&self, prog: &str) -> Result<Vec<String>> {
        // manifest["programs"] maps e.g. "score_opt-mini-m" -> [names...]
        let programs = self.manifest.get("programs")
            .ok_or_else(|| anyhow!("manifest missing programs"))?;
        let entry = programs.get(prog)
            .ok_or_else(|| anyhow!("manifest has no program {prog:?}"))?;
        let arr = entry.as_arr()
            .ok_or_else(|| anyhow!("program {prog:?} entry not a list"))?;
        arr.iter()
            .map(|v| v.as_str().map(String::from)
                .ok_or_else(|| anyhow!("bad param name")))
            .collect()
    }

    /// Load + compile (or fetch from cache) a program by name; the HLO file
    /// is `<name>.hlo.txt` under the artifacts directory.
    pub fn program(&self, name: &str) -> Result<std::sync::Arc<Program>> {
        if let Some(p) = self.cache.lock().unwrap().get(name) {
            return Ok(p.clone());
        }
        let path = self.artifacts.join(format!("{name}.hlo.txt"));
        let param_order = self.param_order(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?)
            .map_err(|e| anyhow!("load {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let prog = std::sync::Arc::new(Program {
            name: name.to_string(),
            param_order,
            exe,
        });
        self.cache.lock().unwrap().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Convenience: i32 leading input from a flat buffer.
    pub fn i32_input(shape: &[usize], data: Vec<i32>) -> ParamValue {
        ParamValue::I32 { shape: shape.to_vec(), data }
    }

    pub fn f32_input(shape: &[usize], data: Vec<f32>) -> ParamValue {
        ParamValue::F32 { shape: shape.to_vec(), data }
    }

    /// Leading-input count heuristic from manifest naming: entries that are
    /// not weight tensors ("tokens", "lens", "images").
    pub fn leading_count(order: &[String]) -> usize {
        order.iter()
            .take_while(|n| matches!(n.as_str(),
                                     "tokens" | "lens" | "images"))
            .count()
    }

    /// Weights view for a tensor map (helper for tests).
    pub fn weights_from_map(map: crate::model::io::TensorMap) -> Weights {
        Weights::new(map)
    }

    /// Batch-of-sequences helper: flatten Vec<Vec<i32>> into one i32 input.
    pub fn tokens_input(batch: &[Vec<i32>]) -> ParamValue {
        let b = batch.len();
        let t = batch.first().map(|s| s.len()).unwrap_or(0);
        let mut flat = Vec::with_capacity(b * t);
        for s in batch {
            assert_eq!(s.len(), t, "ragged batch");
            flat.extend_from_slice(s);
        }
        ParamValue::I32 { shape: vec![b, t], data: flat }
    }
}

/// Pure helper used by tests without a PJRT client.
pub fn tensor_param(t: &Tensor) -> ParamValue {
    ParamValue::from_tensor(t)
}
