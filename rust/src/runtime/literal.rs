//! Program input values: typed shape+buffer pairs marshalled by whichever
//! backend executes the program (flattened into `xla::Literal`s on the
//! PJRT path, interpreted directly by the reference backend).

use crate::model::io::Tensor;

/// An input value for a program parameter.
#[derive(Clone, Debug)]
pub enum ParamValue {
    I32 { shape: Vec<usize>, data: Vec<i32> },
    F32 { shape: Vec<usize>, data: Vec<f32> },
}

impl ParamValue {
    pub fn from_tensor(t: &Tensor) -> ParamValue {
        match t {
            Tensor::F32 { shape, data } => ParamValue::F32 {
                shape: shape.clone(), data: data.clone(),
            },
            Tensor::I32 { shape, data } => ParamValue::I32 {
                shape: shape.clone(), data: data.clone(),
            },
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            ParamValue::I32 { shape, .. } | ParamValue::F32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_tensor_preserves_shape() {
        let t = Tensor::I32 { shape: vec![2, 3], data: vec![0; 6] };
        let p = ParamValue::from_tensor(&t);
        assert_eq!(p.shape(), &[2, 3]);
        assert_eq!(p.len(), 6);
        assert!(!p.is_empty());
    }
}
