//! Marshalling between LTW tensors / rust buffers and xla Literals.

use anyhow::Result;

use crate::model::io::Tensor;

/// An input value for a PJRT program parameter.
#[derive(Clone, Debug)]
pub enum ParamValue {
    I32 { shape: Vec<usize>, data: Vec<i32> },
    F32 { shape: Vec<usize>, data: Vec<f32> },
}

impl ParamValue {
    pub fn from_tensor(t: &Tensor) -> ParamValue {
        match t {
            Tensor::F32 { shape, data } => ParamValue::F32 {
                shape: shape.clone(), data: data.clone(),
            },
            Tensor::I32 { shape, data } => ParamValue::I32 {
                shape: shape.clone(), data: data.clone(),
            },
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            ParamValue::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            ParamValue::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    ParamValue::from_tensor(t).to_literal()
}
