//! PJRT runtime: loads the AOT-compiled HLO-text programs emitted by
//! python/compile/aot.py and executes them on the CPU PJRT client through
//! the `xla` crate. One compiled executable per program signature, cached.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod literal;

pub use engine::{Engine, Program};
pub use literal::{tensor_to_literal, ParamValue};
