//! Program runtime with a pluggable execution backend.
//!
//! [`Engine`] resolves manifest program names to compiled [`Program`]s
//! through a [`Backend`] and caches them. Decode programs additionally
//! open stateful [`DecodeSession`]s (`Program::decode_session`): prefill
//! once, then step token by token against per-layer cache tensors
//! ([`decode`]) — dense layers cache K/V rows, latent layers only the
//! compressed latents. Two backends exist:
//!
//! * [`RefBackend`] (default) — pure-rust interpreter over the
//!   [`crate::tensor`] substrate; mirrors the python reference kernels so
//!   scoring, decode, latent/MLA, and multimodal programs run end-to-end
//!   offline with no artifacts beyond `manifest.json` + weights;
//! * `PjrtBackend` (`--features pjrt`, `LATENTLLM_BACKEND=pjrt`) — loads
//!   the AOT-compiled HLO-text programs through the `xla` crate on the CPU
//!   PJRT client. Offline builds type-gate against the vendored stub in
//!   rust/vendor/xla.

pub mod backend;
pub mod decode;
pub mod engine;
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod profile;
pub mod refbackend;

pub use backend::{Backend, DecodeSession, Executable, ProgramCtx};
pub use decode::{BatchedDecodeState, CacheKind, DecodeState, LayerCache};
pub use engine::{tensor_param, Engine, Program};
pub use literal::ParamValue;
pub use refbackend::RefBackend;
