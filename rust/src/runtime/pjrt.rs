//! PJRT execution backend (`--features pjrt`): loads the AOT-compiled
//! HLO-text programs emitted by python/compile/aot.py and executes them on
//! the CPU PJRT client through the `xla` crate.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! In this offline tree the `xla` dependency is the vendored type-gating
//! stub (rust/vendor/xla): the module compiles and the backend constructs
//! errors at runtime. Swap the path dependency for a real xla/PJRT crate
//! to execute HLO for real.

use anyhow::{anyhow, Context, Result};

use super::backend::{Backend, Executable, ProgramCtx};
use super::literal::ParamValue;
use crate::model::io::Tensor;
use crate::model::Weights;

/// Backend over the CPU PJRT client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, ctx: &ProgramCtx) -> Result<Box<dyn Executable>> {
        let path = ctx.artifacts.join(format!("{}.hlo.txt", ctx.name));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?)
            .map_err(|e| anyhow!("load {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", ctx.name))?;
        Ok(Box::new(PjrtExecutable { name: ctx.name.to_string(), exe }))
    }
}

struct PjrtExecutable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable for PjrtExecutable {
    fn execute(&self, leading: &[ParamValue], weights: &Weights,
               weight_order: &[String]) -> Result<Vec<f32>> {
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(leading.len() + weight_order.len());
        for p in leading {
            args.push(to_literal(p)?);
        }
        for name in weight_order {
            let t = weights.tensor(name)
                .with_context(|| format!("program {}", self.name))?;
            args.push(tensor_to_literal(t)?);
        }
        let result = self.exe.execute(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1().context("program output tuple")?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Marshal a [`ParamValue`] into an `xla::Literal`.
pub fn to_literal(p: &ParamValue) -> Result<xla::Literal> {
    let lit = match p {
        ParamValue::F32 { shape, data } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims)?
        }
        ParamValue::I32 { shape, data } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims)?
        }
    };
    Ok(lit)
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    to_literal(&ParamValue::from_tensor(t))
}
