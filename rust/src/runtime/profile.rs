//! Opt-in per-layer phase profiler (`serve --profile-layers`).
//!
//! RefBackend's decode step splits every layer into three phases —
//! attention weight phase (shared GEMMs), attention cache phase
//! (per-sequence KV/latent attention), finish phase (output projection
//! + MLP) — and the fused batched path runs the same three phases over
//! N stacked rows. When profiling is enabled, each phase call feeds a
//! labeled histogram (`layer_phase_us{kind,phase,layout}`) on the
//! installed [`Metrics`] sink, giving a per-layer breakdown of where a
//! decode step's time actually goes per weight layout.
//!
//! Off (the default) the hooks are a single relaxed atomic load: no
//! clocks are read, nothing locks, decode is untouched. The recorder is
//! process-global because sessions and layers hold no handle to the
//! coordinator; `install` is idempotent and `disable` detaches the
//! sink.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::util::lock_unpoisoned;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Arc<Metrics>>> = Mutex::new(None);

/// Metric name the phase histograms land under.
pub const PHASE_METRIC: &str = "layer_phase_us";

/// Install a sink and turn profiling on.
pub fn install(sink: Arc<Metrics>) {
    *lock_unpoisoned(&SINK) = Some(sink);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn profiling off and drop the sink.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
    *lock_unpoisoned(&SINK) = None;
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start a phase timer — `None` (free) when profiling is off.
#[inline]
pub fn phase_start() -> Option<Instant> {
    if enabled() { Some(Instant::now()) } else { None }
}

/// Close a phase timer opened by [`phase_start`] into the labeled
/// histogram. `kind` is the layer kind ("dense"/"latent"), `phase` one
/// of "attn_weight"/"attn_cache"/"finish", `layout` the `PackedMat`
/// layout name of the layer's attention weights.
pub fn phase_end(t0: Option<Instant>, kind: &str, phase: &str,
                 layout: &str) {
    let Some(t0) = t0 else { return };
    let d = t0.elapsed();
    let sink = lock_unpoisoned(&SINK).clone();
    if let Some(m) = sink {
        m.observe_with(PHASE_METRIC,
                       &[("kind", kind), ("phase", phase),
                         ("layout", layout)],
                       d);
    }
}

/// Record which path a batched step took (fused one-GEMM-pass vs the
/// per-session loop) and how long it ran — the step-level companion to
/// the per-phase breakdown.
pub fn step_path(fused: bool, rows: usize, t0: Option<Instant>) {
    let Some(t0) = t0 else { return };
    let d = t0.elapsed();
    let sink = lock_unpoisoned(&SINK).clone();
    if let Some(m) = sink {
        let path = if fused { "fused" } else { "per_seq" };
        m.observe_with("batched_step_path_us", &[("path", path)], d);
        m.incr_with("batched_step_path_rows", &[("path", path)],
                    rows as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_is_free_and_enabled_profiler_records() {
        // default off: timers are None and recording is a no-op
        disable();
        assert!(!enabled());
        assert!(phase_start().is_none());
        phase_end(None, "dense", "attn_weight", "f64");

        let m = Arc::new(Metrics::new());
        install(m.clone());
        assert!(enabled());
        let t0 = phase_start();
        assert!(t0.is_some());
        phase_end(t0, "dense", "attn_weight", "f64");
        phase_end(phase_start(), "dense", "attn_weight", "f64");
        phase_end(phase_start(), "latent", "finish", "int8");
        step_path(true, 4, phase_start());
        disable();
        // post-disable observations go nowhere
        phase_end(phase_start(), "dense", "attn_weight", "f64");

        // `>=`: other tests in this binary may legitimately run decode
        // phases during the enabled window — the sink is process-global
        let labels = [("kind", "dense"), ("phase", "attn_weight"),
                      ("layout", "f64")];
        let (_, n) = m.sum_count_with(PHASE_METRIC, &labels).unwrap();
        assert!(n >= 2, "both explicit observations must land (n={n})");
        let latent = [("kind", "latent"), ("phase", "finish"),
                      ("layout", "int8")];
        assert!(m.sum_count_with(PHASE_METRIC, &latent).is_some());
        assert!(m.counter_with("batched_step_path_rows",
                               &[("path", "fused")]) >= 4);
        let text = m.render_prometheus();
        assert!(text.contains("latentllm_layer_phase_us_bucket{"),
                "phase histogram must expose natively:\n{text}");
    }
}
